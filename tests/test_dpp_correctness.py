"""Correctness of fast greedy DPP MAP inference (Algorithm 1) against the
naive determinant-based greedy (paper eq. (8)) and the paper's theorems."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (
    build_kernel_dense,
    build_kernel_dense_raw,
    dpp_greedy_dense,
    dpp_greedy_dense_batch,
    dpp_greedy_lowrank,
    dpp_greedy_lowrank_batch,
    greedy_map_naive,
    log_det_objective,
    map_relevance,
    normalize_columns,
    scaled_features,
    similarity_from_features,
    top_n_select,
)


def make_problem(seed, M=120, D=24, alpha=None):
    """Paper §5.1 synthetic setup: uniform relevance, S = F^T F."""
    rng = np.random.default_rng(seed)
    r = rng.uniform(size=M)
    F = normalize_columns(jnp.asarray(rng.uniform(size=(D, M))))
    S = similarity_from_features(F)
    if alpha is None:
        L = build_kernel_dense_raw(jnp.asarray(r), S)  # eq. (5)
    else:
        L = build_kernel_dense(jnp.asarray(r), S, alpha)  # eq. (22)
    return r, F, S, L


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("k", [1, 5, 20])
def test_fast_equals_naive_selection(seed, k):
    """The acceleration is exact: same items, same order as eq. (8)."""
    _, _, _, L = make_problem(seed)
    fast = dpp_greedy_dense(L, k, eps=1e-10)
    naive_idx, naive_gain = greedy_map_naive(np.asarray(L), k, eps=1e-10)
    np.testing.assert_array_equal(np.asarray(fast.indices), naive_idx[:k])
    # determinant identity (12): det(L_Y) = prod d^2
    np.testing.assert_allclose(
        np.asarray(fast.d_hist) ** 2, naive_gain[:k], rtol=2e-4, atol=1e-9
    )


@pytest.mark.parametrize("seed", [5, 6])
def test_lowrank_equals_dense(seed):
    """Implicit L = V^T V path selects identically to the dense path."""
    r, F, S, _ = make_problem(seed, M=200, D=32)
    alpha = 3.0
    L = build_kernel_dense(jnp.asarray(r), S, alpha)
    V = scaled_features(F, jnp.asarray(r), alpha)
    a = dpp_greedy_dense(L, 15)
    b = dpp_greedy_lowrank(V, 15)
    np.testing.assert_array_equal(np.asarray(a.indices), np.asarray(b.indices))
    np.testing.assert_allclose(
        np.asarray(a.d_hist), np.asarray(b.d_hist), rtol=3e-4, atol=1e-6
    )


def test_theorem_4_1_monotone_nonincreasing():
    """Thm 4.1: d^0 >= d^1 >= ... > 0 while N <= rank(L)."""
    _, _, _, L = make_problem(7, M=150, D=40)
    res = dpp_greedy_dense(L, 30, eps=1e-12)
    d = np.asarray(res.d_hist)[: int(res.n_selected)]
    assert (d > 0).all()
    assert (np.diff(d) <= 1e-5).all(), d  # non-increasing (fp tolerance)


def test_eps_stop_rank_deficient():
    """Candidates of rank D < k: selection must stop at ~D items (eq. 20)."""
    M, D = 60, 8
    rng = np.random.default_rng(11)
    F = normalize_columns(jnp.asarray(rng.uniform(size=(D, M))))
    S = similarity_from_features(F)
    L = build_kernel_dense_raw(jnp.ones(M), S)
    # f32 noise floor after rank exhaustion is ~1e-4..1e-3 (this is the
    # paper's §4.3 instability scenario); eps=1e-3 is the f32-appropriate
    # tolerance.
    res = dpp_greedy_dense(L, 20, eps=1e-3)
    n = int(res.n_selected)
    assert n <= D
    assert (np.asarray(res.indices)[n:] == -1).all()
    assert (np.asarray(res.d_hist)[n:] == 0).all()


def test_theorem_4_2_alpha_recovers_top_n():
    """Thm 4.2: alpha above the bound (23) -> the top-N relevance set has
    the highest probability, and greedy recovers it.

    The bound (det S_Y)^(-1/(2 (r_MIN - r_max))) is only floating-point
    representable when there is a real relevance gap and the top items are
    not nearly collinear, so we construct such a problem: high-dimensional
    (near-orthogonal) item features and a 0.2 relevance gap.
    """
    rng = np.random.default_rng(13)
    M, D, k = 80, 2048, 10
    r = np.concatenate([rng.uniform(0.6, 1.0, size=k), rng.uniform(0.0, 0.4, size=M - k)])
    perm = rng.permutation(M)
    r = r[perm]
    F = normalize_columns(jnp.asarray(rng.normal(size=(D, M))))
    S = similarity_from_features(F)
    top = top_n_select(r, k)
    # theorem bound (23): alpha > det(S_Y) ** (-1 / (2 * (r_MIN - r_max)))
    detSY = np.exp(log_det_objective(np.asarray(S, np.float64), top))
    gap = 0.2  # by construction
    bound = detSY ** (-1.0 / (2 * gap))
    alpha = max(10.0, 2 * bound)
    L = build_kernel_dense(jnp.asarray(r), S, alpha=alpha)
    res = dpp_greedy_dense(L, k)
    assert set(np.asarray(res.indices).tolist()) == set(top.tolist())
    # Direct check of (24): P(X) < P(Y) for random non-top sets X.
    L64 = np.asarray(L, np.float64)
    pY = log_det_objective(L64, top)
    for _ in range(20):
        X = rng.choice(M, size=k, replace=False)
        if set(X.tolist()) == set(top.tolist()):
            continue
        assert log_det_objective(L64, X) < pY


def test_alpha_one_is_pure_similarity():
    """alpha=1: kernel == S (paper §4.4) — relevance is ignored."""
    r, F, S, _ = make_problem(17, M=60, D=20)
    L1 = build_kernel_dense(jnp.asarray(r), S, alpha=1.0)
    np.testing.assert_allclose(np.asarray(L1), np.asarray(S), rtol=1e-6)


def test_alpha_tradeoff_monotone_relevance():
    """Larger alpha must not decrease the summed relevance of the slate."""
    r, F, S, _ = make_problem(19, M=100, D=25)
    k = 10
    rel_sums = []
    for alpha in [1.0, 4.0, 64.0, 1e5]:
        res = dpp_greedy_dense(build_kernel_dense(jnp.asarray(r), S, alpha), k)
        sel = np.asarray(res.indices)
        rel_sums.append(r[sel[sel >= 0]].sum())
    assert all(b >= a - 1e-3 for a, b in zip(rel_sums, rel_sums[1:])), rel_sums


def test_profile_mask_excluded():
    """Profile items P_u must never be selected (eq. (7) constraint)."""
    _, _, _, L = make_problem(23, M=90)
    mask = np.ones(90, bool)
    profile = [3, 10, 42, 77]
    mask[profile] = False
    res = dpp_greedy_dense(L, 12, mask=jnp.asarray(mask))
    sel = np.asarray(res.indices)
    assert not set(sel[sel >= 0].tolist()) & set(profile)


def test_batched_matches_single():
    B, M, D, k = 4, 80, 16, 8
    rng = np.random.default_rng(29)
    Vs, Ls = [], []
    for b in range(B):
        r = rng.uniform(size=M)
        F = normalize_columns(jnp.asarray(rng.uniform(size=(D, M))))
        Vs.append(scaled_features(F, jnp.asarray(r), 2.0))
        Ls.append(build_kernel_dense(jnp.asarray(r), similarity_from_features(F), 2.0))
    V = jnp.stack(Vs)
    L = jnp.stack(Ls)
    rb = dpp_greedy_lowrank_batch(V, k)
    rd = dpp_greedy_dense_batch(L, k)
    for b in range(B):
        single = dpp_greedy_lowrank(V[b], k)
        np.testing.assert_array_equal(np.asarray(rb.indices[b]), np.asarray(single.indices))
        np.testing.assert_array_equal(np.asarray(rd.indices[b]), np.asarray(single.indices))


def test_greedy_beats_or_matches_objective_of_baselines():
    """Greedy MAP should reach a higher log-det than relevance-only Top-N."""
    r, F, S, L = make_problem(31, M=100, D=40)
    k = 10
    res = dpp_greedy_dense(L, k)
    ours = log_det_objective(np.asarray(L), np.asarray(res.indices))
    top = log_det_objective(np.asarray(L), top_n_select(r, k))
    assert ours >= top - 1e-9
