"""End-to-end behaviour tests for the paper's system: the full serving
pipeline (score -> shortlist -> Div-DPP re-rank) and the trade-off
protocol, exercised through the public API."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import (
    mean_slate_diversity,
    recall_at_n,
    top_n_select,
)
from repro.data import candidates_and_relevance, item_similarity, load_preset
from repro.models import recsys as recsys_mod
from repro.serving.reranker import DPPRerankConfig
from conftest import serve_rerank


def test_serving_pipeline_end_to_end():
    """CTR model -> candidate scores -> DPP slate, jitted end to end."""
    cfg = get_arch("deepfm").reduced()
    params = recsys_mod.init_params(jax.random.PRNGKey(0), cfg)
    M = cfg.vocab_sizes[cfg.item_field]
    cand = jnp.arange(M, dtype=jnp.int32)
    rng = np.random.default_rng(0)
    user = jnp.asarray(rng.integers(0, 10, size=(1, cfg.n_fields, 1)), jnp.int32)

    @jax.jit
    def serve(params, user):
        ids = jnp.broadcast_to(user, (M, cfg.n_fields, 1)).astype(jnp.int32)
        ids = jnp.concatenate(
            [ids[:, :cfg.item_field], cand[:, None, None],
             ids[:, cfg.item_field + 1:]], axis=1)
        scores = recsys_mod.serve_scores(params, ids, cfg)
        feats = recsys_mod.item_embeddings(params, cand, cfg)
        return serve_rerank(scores, feats,
                            DPPRerankConfig(slate_size=8, shortlist=32,
                                            alpha=2.0))

    slate, dh = serve(params, user)
    slate = np.asarray(slate)
    valid = slate[slate >= 0]
    assert len(valid) == 8
    assert len(set(valid.tolist())) == 8  # unique items
    d = np.asarray(dh)
    d = d[d > 0]
    assert (np.diff(d) <= 1e-4).all()  # Thm 4.1 inside the jitted graph


def test_dpp_slate_beats_topn_on_min_dissimilarity():
    """On clustered data the DPP slate must improve the paper's headline
    metric (min dissimilarity) vs pure Top-N at small relevance cost."""
    ds = load_preset("movielens-like", seed=1)
    S = item_similarity(ds)
    cands = candidates_and_relevance(ds, S, top_k_similar=60)
    wins, total = 0, 0
    for u in range(0, ds.n_users, 5):
        cand, rel = cands[u]
        if cand.size < 20:
            continue
        rel_n = (rel - rel.min()) / max(rel.max() - rel.min(), 1e-9)
        feats = np.linalg.cholesky(
            S[np.ix_(cand, cand)] + 1e-4 * np.eye(cand.size)
        ).astype(np.float32)  # factor so S = F F^T
        slate, _ = serve_rerank(
            jnp.asarray(rel_n), jnp.asarray(feats),
            DPPRerankConfig(slate_size=8, shortlist=int(cand.size), alpha=1.5),
        )
        slate = np.asarray(slate)
        top = top_n_select(rel_n, 8)
        Ssub = S[np.ix_(cand, cand)]
        m_dpp = mean_slate_diversity(slate[None], Ssub)["min"]
        m_top = mean_slate_diversity(top[None], Ssub)["min"]
        wins += m_dpp >= m_top
        total += 1
    assert total >= 10
    assert wins / total > 0.7, (wins, total)


def test_batched_rerank_shapes():
    rng = np.random.default_rng(3)
    B, M, D = 4, 64, 8
    scores = jnp.asarray(rng.uniform(size=(B, M)), jnp.float32)
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    slates, dh = serve_rerank(scores, jnp.asarray(feats),
                              DPPRerankConfig(slate_size=6, shortlist=32))
    assert slates.shape == (B, 6)
    for b in range(B):
        v = np.asarray(slates[b])
        v = v[v >= 0]
        assert len(set(v.tolist())) == len(v)
