"""LM transformer family: reduced-config smoke tests + decode consistency."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.moe import MoEConfig
from repro.models.transformer import (
    TransformerConfig,
    decode_step,
    forward_hidden,
    init_cache,
    init_params,
    prefill,
    train_loss,
)

TINY_DENSE = TransformerConfig(
    name="tiny-dense", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, dtype=jnp.float32, chunk_q=16,
)
TINY_QKVBIAS = TransformerConfig(
    name="tiny-qkvbias", n_layers=2, d_model=48, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=128, qkv_bias=True, dtype=jnp.float32, chunk_q=16,
)
TINY_MIXED = TransformerConfig(
    name="tiny-mixed", n_layers=6, d_model=32, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=64, window=8, global_every=3, dtype=jnp.float32, chunk_q=16,
)
TINY_MOE = TransformerConfig(
    name="tiny-moe", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
    d_ff=48, vocab=64, moe=MoEConfig(n_experts=4, top_k=2, d_ff=48),
    dtype=jnp.float32, chunk_q=16,
)
TINY_MOE_RES = TransformerConfig(
    name="tiny-moe-res", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
    d_ff=48, vocab=64, moe=MoEConfig(n_experts=4, top_k=2, d_ff=24),
    moe_dense_residual=True, dtype=jnp.float32, chunk_q=16,
)

ALL = [TINY_DENSE, TINY_QKVBIAS, TINY_MIXED, TINY_MOE, TINY_MOE_RES]


@pytest.mark.parametrize("cfg", ALL, ids=lambda c: c.name)
def test_forward_and_loss(cfg):
    rng = jax.random.PRNGKey(0)
    params = init_params(rng, cfg)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    hidden, aux, _ = forward_hidden(params, tokens, cfg)
    assert hidden.shape == (B, S, cfg.d_model)
    assert not np.isnan(np.asarray(hidden, np.float32)).any()
    loss = train_loss(params, {"tokens": tokens}, cfg)
    assert np.isfinite(float(loss))
    # a fresh model should be near ln(vocab) CE
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.0


@pytest.mark.parametrize("cfg", ALL, ids=lambda c: c.name)
def test_grads_finite(cfg):
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    g = jax.grad(lambda p: train_loss(p, {"tokens": tokens}, cfg))(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(a, np.float32)).all() for a in flat)


@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_MIXED], ids=lambda c: c.name)
def test_prefill_then_decode_matches_forward(cfg):
    """prefill(S) + decode steps == forward over the full sequence."""
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, extra, max_seq = 2, 24, 4, 64
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S + extra), 0, cfg.vocab)

    logits_p, cache = prefill(params, tokens[:, :S], cfg, max_seq)
    # oracle: full forward logits at each position
    hidden, _, _ = forward_hidden(params, tokens, cfg)
    logits_full = np.asarray(
        (hidden @ params["unembed"]).astype(jnp.float32)
    )
    np.testing.assert_allclose(
        np.asarray(logits_p), logits_full[:, S - 1], rtol=2e-3, atol=2e-3
    )
    for t in range(extra):
        logits_d, cache = decode_step(params, cache, tokens[:, S + t : S + t + 1], cfg)
        np.testing.assert_allclose(
            np.asarray(logits_d), logits_full[:, S + t], rtol=2e-3, atol=2e-3
        )


def test_decode_ring_buffer_smaller_than_context():
    """Mixed arch with context longer than the window: ring cache works."""
    cfg = TINY_MIXED  # window=8
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, extra, max_seq = 1, 20, 3, 40
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S + extra), 0, cfg.vocab)
    logits_p, cache = prefill(params, tokens[:, :S], cfg, max_seq)
    hidden, _, _ = forward_hidden(params, tokens, cfg)
    logits_full = np.asarray((hidden @ params["unembed"]).astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(logits_p), logits_full[:, S - 1], rtol=3e-3, atol=3e-3
    )
    for t in range(extra):
        logits_d, cache = decode_step(params, cache, tokens[:, S + t : S + t + 1], cfg)
        np.testing.assert_allclose(
            np.asarray(logits_d), logits_full[:, S + t], rtol=3e-3, atol=3e-3
        )


def test_param_count_matches_config():
    for cfg in ALL:
        params = init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))
        expected = cfg.param_count()
        # qkv biases and router weights are small; allow 2% slack
        assert abs(actual - expected) / expected < 0.02, (cfg.name, actual, expected)


def test_init_cache_groups():
    cache = init_cache(TINY_MIXED, batch=2, max_seq=32)
    # window=8 local layers + full(32) global layers -> two groups
    assert set(cache["groups"].keys()) == {"8", "32"}
    assert cache["groups"]["8"]["k"].shape == (4, 2, 8, 2, 8)
    assert cache["groups"]["32"]["k"].shape == (2, 2, 32, 2, 8)
