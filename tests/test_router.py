"""Continuous-batching router (repro.serving.router) differential suite.

The core guarantee: R concurrent heterogeneous requests — different M,
k, mask, arrival time — coalesced into one slot-batched micro-batch
produce slates **index-for-index equal** to a per-request
``Reranker.rerank`` on the same inputs, whatever order they arrive and
interleave in (a hypothesis property over arrival schedules, plus
seeded deterministic coverage for environments without hypothesis).
Around it: eps-stopped lanes free their slot for queued requests,
deadline eviction returns the partial slate with ``timed_out=True``,
admission is FIFO under a full queue (no starvation), overflow is
refused with ``RouterQueueFull``, and the stats hook sees the gauges
move.

Slow lane: the same differential on an 8-host-device mesh (sharded
backend) in a subprocess, per the dry-run isolation contract.
"""
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.serving import (
    DPPRerankConfig,
    Reranker,
    RerankRequest,
    RouterConfig,
)
from repro.serving.router import RouterQueueFull

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def make_request(seed, M, k=None, masked=False, D=8, **kw):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(M, D)).astype(np.float32)
    f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    s = rng.uniform(0.1, 1.0, size=M).astype(np.float32)
    mask = None
    if masked:
        m = np.ones(M, bool)
        m[rng.choice(M, size=M // 4, replace=False)] = False
        mask = jnp.asarray(m)
    return RerankRequest(scores=jnp.asarray(s), feats=jnp.asarray(f),
                         slate_size=k, mask=mask, **kw)


def session(slots=2, chunk=3, bucket=32, k=8, window=None, use_kernel=False,
            max_queue=32, **cfg_kw):
    cfg = DPPRerankConfig(slate_size=k, shortlist=bucket, alpha=3.0,
                          window=window, use_kernel=use_kernel,
                          chunk_size=chunk, **cfg_kw)
    return Reranker(cfg, router_config=RouterConfig(
        slots=slots, chunk_size=chunk, max_candidates=bucket,
        max_queue=max_queue,
    ))


def assert_router_matches_rerank(rr, reqs, schedule=None):
    """Submit ``reqs`` interleaved with pumps per ``schedule`` (pumps
    to run after each submit; None = all up front), drain, and compare
    every slate to the per-request path."""
    expect = [tuple(np.asarray(x) for x in rr.rerank(r)) for r in reqs]
    handles = []
    for i, r in enumerate(reqs):
        handles.append(rr.submit(r))
        for _ in range(schedule[i] if schedule else 0):
            rr.router.pump()
    rr.router.drain()
    for h, (ei, ed), r in zip(handles, expect, reqs):
        gi, gd = h.result()
        k = r.slate_size if r.slate_size is not None else rr.cfg.slate_size
        assert len(gi) == k and not h.timed_out
        np.testing.assert_array_equal(gi, ei)
        np.testing.assert_allclose(gd, ed, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Differential parity, heterogeneous and interleaved
# ---------------------------------------------------------------------------


def test_concurrent_heterogeneous_parity():
    rr = session(slots=3, chunk=3, bucket=32, k=8)
    reqs = [
        make_request(1, 40, k=8),
        make_request(2, 24, k=5),
        make_request(3, 48, k=7, masked=True),
        make_request(4, 16, k=3),
        make_request(5, 32, k=8, masked=True),
    ]
    assert_router_matches_rerank(rr, reqs)
    st = rr.router.stats
    assert st.completed == 5 and st.slot_occupancy == 0
    assert st.fill_ratio > 0


@pytest.mark.parametrize("seed", range(4))
def test_interleaved_arrivals_seeded(seed):
    """Deterministic arrival-order coverage: random pump interleaving
    between submits must not change any slate."""
    rng = np.random.default_rng(seed)
    rr = session(slots=2, chunk=2, bucket=24, k=6)
    reqs = [
        make_request(100 + seed * 10 + i, int(rng.choice([16, 20, 24])),
                     k=int(rng.integers(2, 7)), masked=bool(rng.integers(2)))
        for i in range(5)
    ]
    schedule = [int(rng.integers(0, 4)) for _ in reqs]
    assert_router_matches_rerank(rr, reqs, schedule)


def test_interleaved_arrivals_property():
    hyp = pytest.importorskip("hypothesis")  # noqa: F841
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**16),
        ms=st.lists(st.sampled_from([16, 24, 32]), min_size=2, max_size=5),
        pumps=st.lists(st.integers(0, 4), min_size=5, max_size=5),
    )
    def check(seed, ms, pumps):
        rng = np.random.default_rng(seed)
        rr = session(slots=2, chunk=2, bucket=32, k=6)
        reqs = [
            make_request(seed + i, m, k=int(rng.integers(2, 7)),
                         masked=bool(rng.integers(2)))
            for i, m in enumerate(ms)
        ]
        assert_router_matches_rerank(rr, reqs, pumps[: len(reqs)])

    check()


@pytest.mark.parametrize("window", [None, 3])
def test_pallas_router_parity(window):
    rr = session(slots=2, chunk=3, bucket=48, k=6, window=window,
                 use_kernel=True)
    reqs = [make_request(20 + i, 40 + 4 * i, k=6 - (i % 2), masked=(i == 1))
            for i in range(3)]
    assert_router_matches_rerank(rr, reqs, schedule=[0, 2, 1])


def test_windowed_router_parity_jnp():
    rr = session(slots=2, chunk=2, bucket=24, k=6, window=3)
    reqs = [make_request(30 + i, 24, k=6) for i in range(3)]
    assert_router_matches_rerank(rr, reqs)


# ---------------------------------------------------------------------------
# Slot lifecycle: eps-stop reuse, deadlines, backpressure, starvation
# ---------------------------------------------------------------------------


def _rank1_request(seed, M=24, k=8):
    """All-identical features: the DPP eps-stops after one pick."""
    rng = np.random.default_rng(seed)
    f = np.tile(rng.normal(size=(1, 8)), (M, 1)).astype(np.float32)
    f /= np.linalg.norm(f, axis=1, keepdims=True)
    s = rng.uniform(0.5, 1.0, size=M).astype(np.float32)
    return RerankRequest(scores=jnp.asarray(s), feats=jnp.asarray(f),
                         slate_size=k)


def test_eps_stop_frees_slot_for_queued_request():
    rr = session(slots=1, chunk=2, bucket=24, k=8, eps=1e-3)
    stopper = _rank1_request(0)
    follower = make_request(1, 24, k=8)
    exp_stop = np.asarray(rr.rerank(stopper)[0])
    exp_follow = np.asarray(rr.rerank(follower)[0])
    h1, h2 = rr.submit(stopper), rr.submit(follower)
    gi1, _ = h1.result()
    gi2, _ = h2.result()
    np.testing.assert_array_equal(gi1, exp_stop)
    np.testing.assert_array_equal(gi2, exp_follow)
    # the stopper kept the whole-slate contract: length k, -1 fill
    assert len(gi1) == 8 and (gi1 == -1).sum() >= 6
    st = rr.router.stats
    assert st.eps_stopped >= 1 and st.completed == 2
    # the single slot served both: the eps-stop freed it mid-flight
    assert rr.router.rcfg.slots == 1


def test_deadline_eviction_partial_slate():
    rr = session(slots=1, chunk=2, bucket=32, k=10)
    h = rr.submit(make_request(2, 32, k=10, deadline=1e-9))
    rr.router.pump()  # admits + launches the first chunk
    time.sleep(0.005)
    rr.router.drain()
    gi, gd = h.result()
    assert h.timed_out
    assert len(gi) < 10  # partial, not -1-padded to k
    assert len(gi) == len(gd)
    assert rr.router.stats.timed_out == 1


def test_deadline_expires_in_queue():
    rr = session(slots=1, chunk=2, bucket=24, k=6)
    blocker = rr.submit(make_request(3, 24, k=6))
    queued = rr.submit(make_request(4, 24, k=6, deadline=1e-9))
    time.sleep(0.005)
    rr.router.drain()
    assert not blocker.timed_out and len(blocker.result()[0]) == 6
    assert queued.timed_out and len(queued.result()[0]) == 0


def test_backpressure_and_counters():
    rr = session(slots=1, chunk=2, bucket=16, k=4, max_queue=2)
    reqs = [make_request(10 + i, 16, k=4) for i in range(3)]
    hs = [rr.submit(r) for r in reqs[:2]]
    with pytest.raises(RouterQueueFull):
        rr.submit(reqs[2])
    assert rr.router.stats.rejected == 1
    assert rr.router.stats.queue_depth == 2
    rr.router.drain()
    assert all(h.done for h in hs)
    # after draining there is room again
    h3 = rr.submit(reqs[2])
    rr.router.drain()
    assert h3.done and not h3.timed_out


def test_no_starvation_fifo_under_full_queue():
    """Every request admitted under a persistently full queue completes,
    and first-come requests never finish after later arrivals that
    queued behind them on the same slot."""
    rr = session(slots=1, chunk=2, bucket=16, k=4, max_queue=8)
    reqs = [make_request(40 + i, 16, k=4, rid=i) for i in range(8)]
    handles = [rr.submit(r) for r in reqs]
    finish_order = []
    while not all(h.done for h in handles):
        rr.router.pump()
        for h in handles:
            if h.done and h.rid not in finish_order:
                finish_order.append(h.rid)
    assert finish_order == sorted(finish_order)  # FIFO through one slot
    assert rr.router.stats.completed == 8


def test_submit_validation():
    rr = session(slots=1, chunk=2, bucket=16, k=4)
    s, f = np.ones((2, 16), np.float32), np.ones((16, 8), np.float32)
    with pytest.raises(ValueError, match="single requests"):
        rr.submit(RerankRequest(scores=jnp.asarray(s), feats=jnp.asarray(f)))
    with pytest.raises(ValueError, match="slot capacity"):
        rr.submit(make_request(0, 16, k=9))
    with pytest.raises(ValueError, match="bucket"):
        rr.submit(make_request(0, 64, k=4, shortlist=64))
    rr.submit(make_request(0, 16, k=4))
    with pytest.raises(ValueError, match="feature dim"):
        rr.submit(make_request(0, 16, k=4, D=12))
    rr.router.drain()


def test_metrics_hook_sees_gauges():
    seen = []
    cfg = DPPRerankConfig(slate_size=4, shortlist=16, chunk_size=2)
    rr = Reranker(cfg, router_config=RouterConfig(
        slots=2, chunk_size=2, max_candidates=16,
        metrics_hook=lambda snap: seen.append(
            (snap.slot_occupancy, snap.queue_depth, snap.fill_ratio)
        ),
    ))
    hs = [rr.submit(make_request(50 + i, 16)) for i in range(3)]
    rr.router.drain()
    assert all(h.done for h in hs)
    assert any(occ == 2 for occ, _, _ in seen)  # both slots were busy
    assert seen[-1][0] == 0  # and the hook saw the drain
    assert all(h.ttfc is not None and h.ttfc >= 0 for h in hs)
    st = rr.router.stats
    assert st.ttfc_count == len(hs)
    assert st.mean_ttfc == pytest.approx(
        np.mean([h.ttfc for h in hs]), rel=1e-6
    )


def test_router_ttfc_beats_serial_burst():
    """The acceptance ordering on a heterogeneous burst: continuous
    batching must not serve first chunks slower than request-at-a-time
    streaming.  Serial streaming folds each request's k into the
    compiled state geometry (request i also waits for slates 0..i-1);
    the router's fixed slot capacity serves every k from one compiled
    geometry — per-request knobs stay in data (fig7 gates the same
    ordering end-to-end)."""
    rr = session(slots=4, chunk=4, bucket=128, k=16)
    ks = [16, 13, 14, 11, 9, 15, 10, 12]  # heterogeneous slate lengths
    reqs = [make_request(60 + i, 256, k=k, D=16) for i, k in enumerate(ks)]
    # warm both paths on the FIRST request's geometry only — the point
    # under test is how each path serves the shapes it has not seen
    for c, _ in rr.stream(reqs[0]):
        c.block_until_ready()
    rr.submit(reqs[0]).result()
    t0 = time.perf_counter()
    serial = []
    for r in reqs:
        first = None
        for c, _ in rr.stream(r):
            c.block_until_ready()
            if first is None:
                first = time.perf_counter() - t0
        serial.append(first)
    handles = [rr.submit(r) for r in reqs]
    rr.router.drain()
    routed = [h.ttfc for h in handles]
    assert np.mean(routed) <= np.mean(serial)


# ---------------------------------------------------------------------------
# Multi-device router parity (subprocess, slow lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_router_multidevice_sharded_parity():
    """The router on an 8-device mesh: heterogeneous k/mask requests on
    sharded slot states match per-request sharded rerank."""
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=8"
        )
        import numpy as np
        import jax.numpy as jnp
        from repro.distributed.context import make_mesh_compat
        from repro.serving import (
            DPPRerankConfig, Reranker, RerankRequest, RouterConfig,
        )

        mesh = make_mesh_compat((8,), ("data",))
        M = 64  # bucket: every request padded to the full sharded width
        cfg = DPPRerankConfig(slate_size=6, shortlist=48, alpha=3.0,
                              mesh=mesh, chunk_size=2)
        rr = Reranker(cfg, router_config=RouterConfig(
            slots=2, chunk_size=2, max_candidates=M))

        def req(seed, m, k, masked):
            rng = np.random.default_rng(seed)
            f = rng.normal(size=(m, 8)).astype(np.float32)
            f /= np.linalg.norm(f, axis=1, keepdims=True)
            s = rng.uniform(0.1, 1.0, size=m).astype(np.float32)
            mask = None
            if masked:
                mm = np.ones(m, bool); mm[::3] = False
                mask = jnp.asarray(mm)
            return RerankRequest(scores=jnp.asarray(s),
                                 feats=jnp.asarray(f), slate_size=k,
                                 mask=mask)

        reqs = [req(0, 64, 6, False), req(1, 48, 4, True),
                req(2, 64, 5, False), req(3, 56, 6, True)]
        expect = [tuple(np.asarray(x) for x in rr.rerank(r)) for r in reqs]
        handles = [rr.submit(r) for r in reqs]
        rr.router.drain()
        for h, (ei, ed), r in zip(handles, expect, reqs):
            gi, gd = h.result()
            assert len(gi) == r.slate_size
            np.testing.assert_array_equal(gi, ei)
            np.testing.assert_allclose(gd, ed, rtol=1e-4, atol=1e-6)
        assert rr.router.stats.completed == 4
        print("ok")
    """)
