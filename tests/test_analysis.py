"""Tests for ``repro.analysis`` — the static checker is itself checked
from both sides:

* **negative oracle** — the seeded-violation corpus in
  tests/fixtures/analysis/ must make every AST rule fire at exactly the
  planted lines (golden findings), suppressions must silence exactly
  their rule, and a typo'd rule id must be a finding rather than a
  silent no-op;
* **positive oracle** — the whole repo (src/, benchmarks/, examples/)
  must come back with zero findings, the kernel contract sweep must
  cover every family, and the router geometry proof must report exactly
  one reachable compiled geometry (the static fig8 counterpart);
* **kernel rules** — driven through corrupted seams: a broken
  ``index_map`` must surface as pallas-coverage-gap, a non-dividing
  block as pallas-block-divisibility, removing the interpret guard as
  pallas-revisit-gap, and a stale/undercounting VMEM model as
  pallas-vmem-model / pallas-vmem-budget;
* **fix regressions** — the two real findings this PR fixed stay
  fixed: the fused chunk kernels refuse to compile multi-tile, and the
  chunked VMEM model counts the state write-back stream.
"""
import ast
import dataclasses
from pathlib import Path

import pytest

from repro.analysis import RULES, run_analysis
from repro.analysis import jitgeo
from repro.analysis import kernels as ak
from repro.analysis.cli import main as cli_main
from repro.analysis.findings import (
    Finding,
    apply_suppressions,
    scan_suppressions,
)

ROOT = Path(__file__).resolve().parents[1]
FIXTURES = Path(__file__).resolve().parent / "fixtures" / "analysis"

AST_RULES = {
    "trace-cast", "trace-pyif", "host-sync-hot", "obs-nonstatic",
    "dead-shim", "jit-static-missing", "jit-static-unhashable",
    "router-geometry", "session-geometry", "bad-suppression",
}
KERNEL_RULES = {
    "pallas-coverage-gap", "pallas-block-divisibility",
    "pallas-revisit-gap", "pallas-vmem-budget", "pallas-vmem-model",
    "autotune-cache-invalid",
}

# the corpus' planted violations: (fixture file, line, rule)
GOLDEN = {
    ("fx_dead_shim.py", 2, "dead-shim"),
    ("fx_dead_shim.py", 3, "dead-shim"),
    ("fx_dead_shim.py", 11, "dead-shim"),
    ("fx_host_sync.py", 8, "host-sync-hot"),
    ("fx_host_sync.py", 9, "host-sync-hot"),
    ("fx_jit_static.py", 9, "jit-static-missing"),
    ("fx_jit_static.py", 9, "jit-static-unhashable"),
    ("fx_jit_static.py", 17, "jit-static-unhashable"),
    ("fx_obs_nonstatic.py", 6, "obs-nonstatic"),
    ("fx_obs_nonstatic.py", 8, "obs-nonstatic"),
    ("fx_router_geometry.py", 13, "router-geometry"),
    ("fx_router_geometry.py", 20, "router-geometry"),
    ("fx_router_geometry.py", 26, "router-geometry"),
    ("fx_session_geometry.py", 16, "session-geometry"),
    ("fx_session_geometry.py", 22, "session-geometry"),
    ("fx_suppressed.py", 15, "bad-suppression"),
    ("fx_suppressed.py", 15, "trace-pyif"),
    ("fx_trace_cast.py", 9, "trace-cast"),
    ("fx_trace_cast.py", 14, "trace-cast"),
    ("fx_trace_cast.py", 18, "trace-cast"),
    ("fx_trace_pyif.py", 7, "trace-pyif"),
    ("fx_trace_pyif.py", 15, "trace-pyif"),
}


@pytest.fixture(scope="module")
def corpus():
    findings, summary = run_analysis([str(FIXTURES)], kernel_checks=False)
    return findings, summary


# --------------------------------------------------------------------------
# Negative oracle: the seeded corpus
# --------------------------------------------------------------------------


def test_rule_catalog_is_complete():
    assert set(RULES) == AST_RULES | KERNEL_RULES


def test_corpus_matches_golden_findings(corpus):
    findings, _ = corpus
    got = {(Path(f.path).name, f.line, f.rule) for f in findings}
    assert got == GOLDEN


def test_every_ast_rule_fires_on_the_corpus(corpus):
    findings, _ = corpus
    assert {f.rule for f in findings} == AST_RULES


def test_cli_exits_nonzero_on_corpus(capsys):
    rc = cli_main([str(FIXTURES), "--no-kernel-checks",
                   "--error-on-findings"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "finding(s)" in out


def test_clean_file_exits_zero(tmp_path, capsys):
    clean = tmp_path / "clean.py"
    clean.write_text("import jax\n\n\n@jax.jit\ndef f(x):\n    return x\n")
    assert cli_main([str(clean)]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


def test_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in RULES:
        assert rule in out


def test_suppression_silences_only_its_line(corpus):
    findings, _ = corpus
    suppressed = [f for f in findings
                  if Path(f.path).name == "fx_suppressed.py"]
    # line 8 (`tolerated`) is validly suppressed: nothing anchors there
    assert all(f.line != 8 for f in suppressed)
    # the typo'd suppression on line 15 silences nothing and is itself
    # a finding
    assert {(f.line, f.rule) for f in suppressed} == {
        (15, "bad-suppression"), (15, "trace-pyif"),
    }


def test_unknown_rule_id_is_rejected():
    supp, bad = scan_suppressions(
        "x.py", "a = 1  # repro: ignore[no-such-rule]\n"
    )
    assert supp == {}
    assert [f.rule for f in bad] == ["bad-suppression"]
    assert "no-such-rule" in bad[0].message


def test_suppression_in_docstring_is_not_a_suppression():
    supp, bad = scan_suppressions(
        "x.py", '"""docs mention # repro: ignore[trace-cast]"""\n'
    )
    assert supp == {} and bad == []


def test_bad_suppression_cannot_be_suppressed():
    f = Finding("x.py", 3, "bad-suppression", "typo")
    kept = apply_suppressions([f], {"x.py": {3: {"bad-suppression"}}})
    assert kept == [f]


# --------------------------------------------------------------------------
# Positive oracle: the repo itself is clean
# --------------------------------------------------------------------------


def test_whole_repo_has_zero_findings(tmp_path, monkeypatch):
    # hermetic vs developer machines: a stale tuned cache in the
    # per-user default location is not a property of this repo
    monkeypatch.setenv("DPP_AUTOTUNE_CACHE", str(tmp_path / "absent.json"))
    paths = [str(ROOT / p) for p in ("src", "benchmarks", "examples")
             if (ROOT / p).exists()]
    findings, summary = run_analysis(paths)
    assert findings == [], "\n".join(f.format() for f in findings)
    kc = summary["kernel_contracts"]
    assert kc is not None
    assert sorted(kc["families"]) == [
        "chunk_exact", "chunk_windowed", "step_exact", "step_windowed",
    ]
    assert kc["geometries"] == (len(ak.SWEEP_D) * len(ak.SWEEP_R)
                                * len(kc["families"]))


def test_router_geometry_proof():
    src = ROOT / "src" / "repro" / "serving" / "router.py"
    tree = ast.parse(src.read_text(), filename=str(src))
    summaries = [s for s in (
        jitgeo.router_geometry_summary(n) for n in ast.walk(tree)
        if isinstance(n, ast.ClassDef)
    ) if s is not None]
    assert len(summaries) == 1
    proof = summaries[0]
    assert proof["violations"] == []
    assert proof["launch_sites"] == 1
    assert proof["reachable_geometries"] == 1


def test_corpus_router_summaries(corpus):
    _, summary = corpus
    by_class = {s["class"]: s for s in summary["router_geometry"]}
    assert by_class["WobblyRouter"]["reachable_geometries"] is None
    assert by_class["WobblyRouter"]["launch_sites"] == 2
    assert by_class["SteadyRouter"]["reachable_geometries"] == 1


def test_session_geometry_proof():
    src = ROOT / "src" / "repro" / "serving" / "session.py"
    tree = ast.parse(src.read_text(), filename=str(src))
    summaries = [s for s in (
        jitgeo.session_geometry_summary(n) for n in ast.walk(tree)
        if isinstance(n, ast.ClassDef)
    ) if s is not None]
    assert len(summaries) == 1
    proof = summaries[0]
    assert proof["class"] == "RerankSession"
    assert proof["violations"] == []
    assert proof["launch_sites"] == {
        "greedy_chunk": 1,
        "greedy_state_extend": 1,
        "greedy_state_rescore": 1,
    }
    assert proof["reachable_geometries"] == 1


def test_corpus_session_summaries(corpus):
    _, summary = corpus
    by_class = {s["class"]: s for s in summary["session_geometry"]}
    assert by_class["WobblySession"]["reachable_geometries"] is None
    assert by_class["WobblySession"]["launch_sites"]["greedy_state_extend"] == 2
    assert by_class["SteadySession"]["reachable_geometries"] == 1
    assert by_class["SteadySession"]["geometry_attrs"] == ["spec"]


# --------------------------------------------------------------------------
# Kernel contract rules, driven through corrupted seams
# --------------------------------------------------------------------------


def _drive(monkeypatch, family="chunk_exact", D=64, R=48, corrupt=None):
    """Drive one kernel family with the recorder patched in (and an
    optional corruption applied first); returns the recorded seam."""
    from repro.kernels.dpp_greedy import tiled

    if corrupt is not None:
        corrupt(tiled, monkeypatch)
    rec = ak._Recorder()
    monkeypatch.setattr(tiled.pl, "pallas_call", rec)
    return ak._drive_family(tiled, family, D, R, rec)


def test_intact_seams_are_clean(monkeypatch):
    for family in ("step_exact", "step_windowed", "chunk_exact",
                   "chunk_windowed"):
        seam = _drive(monkeypatch, family=family)
        assert ak.check_launch_geometry(seam) == []
        assert ak.check_vmem_contract(seam) == []


def test_corrupted_index_map_is_a_coverage_gap(monkeypatch):
    """The end-to-end corrupted-index_map test: pin every streamed tile
    to block 0 and the checker must see that block 1 of a 2-tile sweep
    is never visited."""
    from jax.experimental import pallas as pl

    def corrupt(tiled, mp):
        mp.setattr(tiled, "_tile_spec", lambda rows, tile_m: pl.BlockSpec(
            (None, rows, tile_m), lambda b, i: (b, 0, 0)))

    seam = _drive(monkeypatch, family="step_exact", corrupt=corrupt)
    findings = ak.check_launch_geometry(seam)
    assert "pallas-coverage-gap" in {f.rule for f in findings}
    assert any("never visited" in f.message for f in findings)


def test_non_dividing_block_fires(monkeypatch):
    from jax.experimental import pallas as pl

    spec = pl.BlockSpec((None, 8, 100), lambda b, i: (b, 0, i))
    rec = ak.RecordedCall(
        name="synthetic", grid=(1, 2), in_specs=(spec,), out_specs=(),
        in_shapes=((1, 8, 256),), out_shapes=(), interpret=True,
    )
    seam = ak.DrivenSeam(
        call=rec, family="synthetic", D=8, state_rows=8, windowed=False,
        chunked=False, path="synthetic.py", line=1,
    )
    rules = {f.rule for f in ak.check_launch_geometry(seam)}
    assert "pallas-block-divisibility" in rules


def test_unguarded_revisit_gap_fires(monkeypatch):
    """Remove the interpret guard and the fused chunk kernels' cross-
    step state in non-consecutively revisited blocks becomes a
    finding — the checker proves the guard is what makes them safe."""
    from repro.kernels.dpp_greedy import tiled

    def corrupt(tiled_mod, mp):
        mp.setattr(tiled_mod, "_require_interpret_for_multitile",
                   lambda *a, **k: None)

    for family in ("chunk_exact", "chunk_windowed"):
        seam = _drive(monkeypatch, family=family, corrupt=corrupt)
        rules = {f.rule for f in ak.check_launch_geometry(seam)}
        assert "pallas-revisit-gap" in rules, family
    assert tiled._require_interpret_for_multitile is not None


def test_stale_vmem_model_fires(monkeypatch):
    """Re-create the pre-fix bug: account a chunk seam with the
    per-step model (chunked=False) and the state write-back stream is
    undercounted."""
    seam = _drive(monkeypatch, family="chunk_exact", D=64, R=48)
    stale = dataclasses.replace(seam, chunked=False)
    assert "pallas-vmem-model" in {
        f.rule for f in ak.check_vmem_contract(stale)
    }
    assert ak.check_vmem_contract(seam) == []


def test_undercounting_model_breaks_the_budget(monkeypatch):
    """If tile_vmem_bytes undercounted the streams, TilePolicy would
    pick a tile whose recorded working set overflows VMEM — the budget
    rule catches it from the BlockSpec actuals."""
    from repro.kernels.dpp_greedy import tiling

    seam = _drive(monkeypatch, family="chunk_exact", D=64, R=48)
    monkeypatch.setattr(
        tiling, "tile_vmem_bytes",
        lambda D, tile_m=0, state_rows=0, windowed=False, chunked=False:
        8 * tile_m,
    )
    rules = {f.rule for f in ak.check_vmem_contract(seam)}
    assert "pallas-vmem-budget" in rules


# --------------------------------------------------------------------------
# Autotune cache validation (rule autotune-cache-invalid)
# --------------------------------------------------------------------------


def test_autotune_cache_fixture_fires_every_violation():
    """The seeded over-budget cache fixture: each planted entry fires
    its intended facet of autotune-cache-invalid."""
    fx = FIXTURES / "fx_autotune_cache.json"
    findings, summary = ak.check_autotune_cache(str(fx))
    assert summary == {
        "path": str(fx), "present": True, "entries": 4, "checked": 4,
    }
    assert findings and {f.rule for f in findings} == {
        "autotune-cache-invalid"
    }
    msgs = "\n".join(f.message for f in findings)
    assert "over the" in msgs and "VMEM" in msgs          # over-budget
    assert "not a positive multiple" in msgs              # non-LANE tile
    assert "does not reproduce from its own fields" in msgs  # hand-edit
    assert "compiled (interpret=false) fused-chunk" in msgs  # revisit gap
    assert all(f.path == str(fx) for f in findings)


def test_autotune_cache_missing_and_valid_are_clean(tmp_path):
    from repro.kernels.dpp_greedy.autotune import AutotuneCache

    missing = tmp_path / "absent.json"
    findings, summary = ak.check_autotune_cache(str(missing))
    assert findings == [] and summary["present"] is False

    cache = AutotuneCache(str(tmp_path / "good.json"), {})
    cache.put(D=64, M_bucket=65536, state_rows=8, windowed=True,
              chunked=False, tile_m=512, best_us=10.0,
              candidates={512: 10.0}, interpret=True,
              device=("dev", "cpu", "cpu"))
    cache.save()
    findings, summary = ak.check_autotune_cache(cache.path)
    assert findings == [], "\n".join(f.format() for f in findings)
    assert summary["checked"] == 1


def test_autotune_cache_corrupt_file_fires(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    findings, _ = ak.check_autotune_cache(str(bad))
    assert [f.rule for f in findings] == ["autotune-cache-invalid"]
    assert "not parseable" in findings[0].message

    foreign = tmp_path / "foreign.json"
    foreign.write_text('{"schema": 99, "entries": {}}')
    findings, _ = ak.check_autotune_cache(str(foreign))
    assert [f.rule for f in findings] == ["autotune-cache-invalid"]
    assert "schema" in findings[0].message


def test_run_analysis_validates_the_active_cache(tmp_path, monkeypatch):
    """The CLI wiring: with $DPP_AUTOTUNE_CACHE pointing at a bad
    cache, a whole-repo run surfaces the finding; the corpus run
    (kernel_checks=False) never touches the cache."""
    import shutil

    bad = tmp_path / "cache.json"
    shutil.copy(FIXTURES / "fx_autotune_cache.json", bad)
    monkeypatch.setenv("DPP_AUTOTUNE_CACHE", str(bad))
    src = ROOT / "src"
    findings, summary = run_analysis([str(src)])
    assert "autotune-cache-invalid" in {f.rule for f in findings}
    assert summary["autotune_cache"]["present"] is True
    findings, summary = run_analysis([str(src)], kernel_checks=False)
    assert findings == []
    assert summary["autotune_cache"] is None


# --------------------------------------------------------------------------
# Regressions for the real findings this PR fixed
# --------------------------------------------------------------------------


def test_fused_chunk_refuses_to_compile_multitile():
    """Fix regression (pallas-revisit-gap): compiled Mosaic does not
    preserve non-consecutively revisited output blocks, so the fused
    chunk kernels must refuse interpret=False with nt > 1."""
    import jax.numpy as jnp

    from repro.kernels.dpp_greedy import tiled

    B, D, R, Mp = 1, 8, 8, 256
    V = jnp.zeros((B, D, Mp), jnp.float32)
    C = jnp.zeros((B, R, Mp), jnp.float32)
    d2 = jnp.zeros((B, Mp), jnp.float32)
    stopped = jnp.zeros((B,), bool)
    with pytest.raises(NotImplementedError, match="single whole-M tile"):
        tiled.fused_chunk_exact.__wrapped__(
            V, C, d2, 0, stopped, chunk=2, eps=1e-3, tile_m=128,
            interpret=False,
        )
    win = jnp.full((B, R), -1, jnp.int32)
    with pytest.raises(NotImplementedError, match="single whole-M tile"):
        tiled.fused_chunk_windowed.__wrapped__(
            V, C, d2, win, 0, stopped, chunk=2, eps=1e-3, w=R,
            tile_m=128, interpret=False,
        )
    # single whole-M tile (revisits consecutive) and interpret mode
    # stay allowed
    tiled._require_interpret_for_multitile(False, 1)
    tiled._require_interpret_for_multitile(True, 4)


def test_chunked_vmem_model_counts_state_writeback():
    """Fix regression (pallas-vmem-model): the fused chunk kernels
    stream the full (state_rows, tile_m) Cholesky block back out every
    step; the model must count it."""
    from repro.kernels.dpp_greedy import tiling

    D, R, tm = 64, 128, 512
    per_step = tiling.tile_vmem_bytes(D, tm, R, windowed=False,
                                      chunked=False)
    chunked = tiling.tile_vmem_bytes(D, tm, R, windowed=False,
                                     chunked=True)
    Rp = tiling.round_up(R, tiling.SUBLANE)
    assert chunked - per_step == 4 * 2 * (Rp - tiling.SUBLANE) * tm
    # windowed already streamed the full state; chunked adds nothing
    assert tiling.tile_vmem_bytes(D, tm, R, windowed=True, chunked=True) \
        == tiling.tile_vmem_bytes(D, tm, R, windowed=True, chunked=False)


def test_stream_tile_fits_chunked_budget():
    """Fix regression: the streaming executor sizes its tile with the
    chunked model, so the tile it picks fits the budget under the
    fused chunk kernels' real working set."""
    from repro.kernels.dpp_greedy import ops, tiling

    D, M, R = 64, 1 << 20, 128
    tile, Mp = ops._stream_tile(D, M, R, False, None, None)
    assert tile > 0 and Mp % tile == 0
    assert tiling.tile_vmem_bytes(D, tile, R, windowed=False,
                                  chunked=True) \
        <= tiling.TilePolicy().vmem_budget_bytes
