"""Streaming slate emission — the differential harness.

Every backend's chunk-emitting executor is locked to the whole-slate
result and, through the shared ``greedy_oracle`` fixture, to the one
jnp rebuild oracle:

* ``greedy_map_chunks`` chunks concatenate index-for-index (d_hist to
  ~1 ulp) to ``greedy_map`` for every backend × window × chunk_size ×
  ragged-M × mask combination;
* a hypothesis property pins the stronger invariant: *any prefix* of
  chunks equals the whole-slate prefix (streaming can be cut off at any
  chunk boundary and what was already emitted is final);
* ``Reranker.stream`` equals ``Reranker.rerank`` through the serving
  layer (shortlist, global-id mapping, per-chunk d_hist), sharded
  included;
* the fused Pallas chunk executor makes exactly **one** pallas_call —
  one HBM C/d2 round-trip — per chunk, not one per step (checked
  structurally on the jaxpr), while the whole-slate tiled driver keeps
  its per-step launch inside the loop;
* ``GreedySpec``/``DPPRerankConfig`` validation: ``chunk_size`` on a
  backend that would silently ignore it fails at construction.

The CI tiled-matrix job re-runs this suite with extra tile widths via
``DPP_TILE_M`` (same contract as tests/test_kernel_tiled.py).
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import (
    assert_greedy_parity,
    make_greedy_inputs,
    serve_rerank,
    serve_rerank_stream,
)
from repro.core import (
    GreedySpec,
    GreedySpecError,
    greedy_chunk,
    greedy_init,
    greedy_map,
    greedy_map_chunks,
    greedy_step,
)
from repro.distributed.context import make_mesh_compat
from repro.serving.reranker import DPPRerankConfig

# the CI autotune lane sets DPP_TILE_M=auto — a policy mode, not a
# width, so only digit values contribute an explicit tile here
_ENV_TILE = (
    int(os.environ["DPP_TILE_M"])
    if os.environ.get("DPP_TILE_M", "").isdigit() else None
)

BACKENDS = ["jnp", "pallas_resident", "pallas_tiled", "sharded",
            "sharded_tiled"]


def _spec(backend, k, window, chunk=None, eps=1e-6):
    """GreedySpec for one differential backend.  ``pallas_resident``
    leaves tile_m to the policy (resident-size problems stream as one
    whole-M tile); ``pallas_tiled`` forces multi-tile sweeps."""
    tile = _ENV_TILE or 128
    if backend == "jnp":
        # the jnp spec cannot carry chunk_size (GreedySpec rejects it);
        # the streaming calls pass it explicitly
        return GreedySpec(k=k, window=window, backend="jnp", eps=eps)
    if backend == "pallas_resident":
        return GreedySpec(k=k, window=window, backend="pallas", eps=eps,
                          chunk_size=chunk)
    if backend == "pallas_tiled":
        return GreedySpec(k=k, window=window, backend="pallas", eps=eps,
                          tile_m=tile, chunk_size=chunk)
    mesh = make_mesh_compat((1,), ("data",))
    tm = tile if backend == "sharded_tiled" else None
    return GreedySpec(k=k, window=window, backend="sharded", mesh=mesh,
                      eps=eps, tile_m=tm, chunk_size=chunk)


def _collect(spec, V, mask, chunk):
    sels, dhs = [], []
    for res in greedy_map_chunks(spec, V=V, mask=mask, chunk_size=chunk):
        sels.append(np.asarray(res.indices))
        dhs.append(np.asarray(res.d_hist))
    return sels, dhs


# ---------------------------------------------------------------------------
# The core differential: chunks concatenate to the whole slate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("window", [None, 3, 1])
@pytest.mark.parametrize("chunk", [1, 4, 16])
def test_chunks_concatenate_to_whole(backend, window, chunk):
    """backend × window × chunk_size × ragged M × mask: the streamed
    chunks concatenate index-for-index (d_hist ~1 ulp) to greedy_map."""
    D, M, k = 16, 137, 10  # M ragged: every kernel/sharded path pads
    V = make_greedy_inputs(11 + (window or 0), None, D, M)
    rng = np.random.default_rng(5)
    mask = jnp.asarray(rng.uniform(size=M) > 0.3)
    whole = greedy_map(_spec(backend, k, window), V=V, mask=mask)
    sels, dhs = _collect(_spec(backend, k, window, chunk), V, mask, chunk)
    sizes = [s.shape[-1] for s in sels]
    assert sum(sizes) == k and max(sizes) <= chunk  # ragged tail covered
    np.testing.assert_array_equal(
        np.concatenate(sels), np.asarray(whole.indices)
    )
    np.testing.assert_allclose(
        np.concatenate(dhs), np.asarray(whole.d_hist), rtol=1e-6, atol=1e-7
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_streamed_slate_matches_oracle(greedy_oracle, backend):
    """The concatenated stream is pinned to the shared oracle itself,
    not merely to this backend's whole-slate path."""
    D, M, k, w, chunk = 16, 90, 8, 3, 3
    V = make_greedy_inputs(23, None, D, M)
    rng = np.random.default_rng(6)
    mask = jnp.asarray(rng.uniform(size=M) > 0.25)
    sels, dhs = _collect(_spec(backend, k, w, chunk), V, mask, chunk)
    assert_greedy_parity(
        greedy_oracle, np.concatenate(sels), np.concatenate(dhs),
        V, k, window=w, mask=mask,
    )


@pytest.mark.parametrize("backend", ["jnp", "pallas_tiled", "sharded"])
def test_eps_stop_latches_across_chunks(backend):
    """A rank-deficient kernel stops mid-stream: the stop must latch at
    the same step as the whole-slate path and every later chunk must
    hold -1 / 0."""
    D, M, k, chunk = 5, 160, 12, 4
    V = make_greedy_inputs(31, None, D, M)
    whole = greedy_map(_spec(backend, k, None, eps=1e-3), V=V)
    sels, dhs = _collect(
        _spec(backend, k, None, chunk, eps=1e-3), V, None, chunk
    )
    sel = np.concatenate(sels)
    np.testing.assert_array_equal(sel, np.asarray(whole.indices))
    assert (sel == -1).any(), "eps-stop never fired — the case is vacuous"
    np.testing.assert_allclose(
        np.concatenate(dhs), np.asarray(whole.d_hist), rtol=1e-6, atol=1e-7
    )


def test_greedy_step_and_mixed_chunks():
    """The raw init/step/chunk API: single steps interleaved with chunks
    resume exactly where the state left off."""
    D, M, k = 12, 100, 9
    V = make_greedy_inputs(41, None, D, M)
    spec = GreedySpec(k=k, window=4, backend="jnp", eps=1e-6)
    whole = greedy_map(spec, V=V)
    state = greedy_init(spec, V=V)
    out = []
    state, i0, d0 = greedy_step(spec, state, V=V)
    out.append([int(i0)])
    state, sel, _ = greedy_chunk(spec, state, V=V, chunk_size=5)
    out.append(np.asarray(sel))
    state, sel, _ = greedy_chunk(spec, state, V=V, chunk_size=3)
    out.append(np.asarray(sel))
    np.testing.assert_array_equal(
        np.concatenate(out), np.asarray(whole.indices)
    )


def test_batched_pallas_chunks():
    """The fused chunk kernels carry a user batch; per-user eps-stop
    latches independently."""
    B, D, M, k, chunk = 3, 10, 140, 8, 3
    V = make_greedy_inputs(47, B, D, M)
    mask = jnp.asarray(np.random.default_rng(8).uniform(size=(B, M)) > 0.3)
    spec = GreedySpec(k=k, window=3, backend="pallas", eps=1e-6,
                      tile_m=128, chunk_size=chunk)
    whole = greedy_map(
        GreedySpec(k=k, window=3, backend="pallas", eps=1e-6, tile_m=128),
        V=V, mask=mask,
    )
    chunks = list(greedy_map_chunks(spec, V=V, mask=mask))
    sel = np.concatenate([np.asarray(c.indices) for c in chunks], axis=1)
    assert sel.shape == (B, k)
    np.testing.assert_array_equal(sel, np.asarray(whole.indices))


# ---------------------------------------------------------------------------
# Hypothesis: any prefix of chunks equals the whole-slate prefix
# ---------------------------------------------------------------------------


def test_prefix_of_chunks_equals_whole_prefix_property():
    """Streaming can be cut at any chunk boundary: what was emitted is
    final — every prefix of the chunk sequence equals the whole-slate
    prefix of the same length (jnp backend; the other backends are
    pinned to jnp above)."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        M=st.integers(16, 120),
        D=st.integers(4, 24),
        k=st.integers(1, 12),
        window=st.one_of(st.none(), st.integers(1, 6)),
        chunk=st.integers(1, 8),
        masked=st.booleans(),
    )
    def check(seed, M, D, k, window, chunk, masked):
        k = min(k, D)  # full-rank regime (argmax above the noise floor)
        V = make_greedy_inputs(seed, None, D, M, alpha=None)
        rng = np.random.default_rng(seed)
        mask = jnp.asarray(rng.uniform(size=M) > 0.3) if masked else None
        spec = GreedySpec(k=k, window=window, backend="jnp", eps=1e-6)
        whole = np.asarray(greedy_map(spec, V=V, mask=mask).indices)
        sels, _ = _collect(spec, V, mask, chunk)
        done = 0
        for i, s in enumerate(sels):
            done += s.shape[-1]
            prefix = np.concatenate(sels[: i + 1])
            np.testing.assert_array_equal(prefix, whole[:done])
        assert done == k

    check()


# ---------------------------------------------------------------------------
# Serving layer: Reranker.stream == Reranker.rerank
# ---------------------------------------------------------------------------


def _serving_cfgs():
    mesh = make_mesh_compat((1,), ("data",))
    tile = _ENV_TILE or 128
    return {
        "jnp": {},
        "pallas": dict(use_kernel=True, tile_m=tile),
        "sharded": dict(mesh=mesh),
        "sharded_tiled": dict(mesh=mesh, tile_m=tile),
    }


@pytest.mark.parametrize("backend", ["jnp", "pallas", "sharded",
                                     "sharded_tiled"])
@pytest.mark.parametrize("window", [None, 4])
def test_rerank_stream_matches_rerank(backend, window):
    """Serving-level differential: global ids and per-chunk d_hist of
    the stream concatenate to the whole-slate rerank — shortlist,
    masking and the ragged final chunk (N % chunk != 0) included."""
    rng = np.random.default_rng(17)
    M, D, N, chunk = 300, 16, 10, 4
    scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    feats = feats / jnp.linalg.norm(feats, axis=1, keepdims=True)
    mask = jnp.asarray(rng.uniform(size=M) > 0.25)
    cfg = DPPRerankConfig(
        slate_size=N, shortlist=128, alpha=3.0, eps=1e-6, window=window,
        chunk_size=chunk, **_serving_cfgs()[backend],
    )
    ref, ref_dh = serve_rerank(scores, feats, cfg, mask=mask)
    chunks = list(serve_rerank_stream(scores, feats, cfg, mask=mask))
    assert [c[0].shape[0] for c in chunks] == [4, 4, 2]
    sel = np.concatenate([np.asarray(c[0]) for c in chunks])
    dh = np.concatenate([np.asarray(c[1]) for c in chunks])
    np.testing.assert_array_equal(sel, np.asarray(ref))
    np.testing.assert_allclose(dh, np.asarray(ref_dh), rtol=1e-6, atol=1e-7)
    # masked items can never be streamed out
    assert all(bool(mask[i]) for i in sel if i >= 0)


def test_rerank_stream_chunk_size_required_and_overridable():
    rng = np.random.default_rng(19)
    M, D = 64, 8
    scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    cfg = DPPRerankConfig(slate_size=6, shortlist=32)
    with pytest.raises(ValueError, match="chunk size"):
        next(serve_rerank_stream(scores, feats, cfg))
    ref, _ = serve_rerank(scores, feats, cfg)
    chunks = list(serve_rerank_stream(scores, feats, cfg, chunk_size=2))
    assert len(chunks) == 3
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(c[0]) for c in chunks]), np.asarray(ref)
    )


# ---------------------------------------------------------------------------
# The fused sweep: one pallas_call — one C/d2 HBM round-trip — per chunk
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 3])
def test_fused_chunk_is_one_pallas_call(window):
    """Advancing a chunk of c steps on the pallas backend is ONE fused
    pallas_call (one C/d2 round-trip through HBM), not c per-step
    launches — while the whole-slate tiled driver demonstrably keeps
    its launch inside the step loop."""
    from repro.kernels.dpp_greedy.tiled import pallas_call_structure

    D, M, k, chunk = 12, 256, 8, 4
    V = make_greedy_inputs(53, None, D, M)
    spec = GreedySpec(k=k, window=window, backend="pallas", eps=1e-6,
                      tile_m=128, chunk_size=chunk)
    state = greedy_init(spec, V=V)
    jaxpr = jax.make_jaxpr(
        lambda s, v: greedy_chunk(spec, s, V=v, chunk_size=chunk)
    )(state, V)
    counts = pallas_call_structure(jaxpr)
    assert counts == {"flat": 1, "looped": 0}, counts

    # contrast: the per-step whole-slate tiled driver launches per step
    # (explicit TilePolicy: the structural claim needs the tiled path
    # even when a DPP_TILE_M override — e.g. "auto", which resolves
    # these resident-size shapes to one flat launch — is in effect)
    from repro.kernels.dpp_greedy import TilePolicy, dpp_greedy

    jaxpr_whole = jax.make_jaxpr(
        lambda v: dpp_greedy(v, k, window=window,
                             tile_policy=TilePolicy(tile_m=128))
    )(V[None])
    whole_counts = pallas_call_structure(jaxpr_whole)
    assert whole_counts["looped"] >= 1, whole_counts


# ---------------------------------------------------------------------------
# Construction-time validation (satellite: mirror the tile_m rule)
# ---------------------------------------------------------------------------


def test_spec_rejects_chunk_size_on_backends_that_ignore_it():
    """chunk_size on the pure-jnp whole-slate path would be silently
    ignored — rejected when the spec is built, exactly as tile_m is."""
    with pytest.raises(GreedySpecError, match="chunk_size"):
        GreedySpec(k=8, backend="jnp", chunk_size=4)
    # auto without a mesh resolves to jnp — also rejected
    with pytest.raises(GreedySpecError, match="chunk_size"):
        GreedySpec(k=8, chunk_size=4)
    with pytest.raises(GreedySpecError, match="chunk_size"):
        GreedySpec(k=8, backend="pallas", chunk_size=0)
    with pytest.raises(GreedySpecError, match="chunk_size"):
        GreedySpec(k=8, backend="pallas", chunk_size=-2)
    # backends with a chunked execution path accept it
    GreedySpec(k=8, backend="pallas", chunk_size=4)
    GreedySpec(k=8, backend="sharded", chunk_size=4,
               mesh=make_mesh_compat((1,), ("data",)))
    # serving config mirrors the positivity check, and its greedy_spec()
    # never forwards chunk_size onto a jnp spec
    with pytest.raises(ValueError, match="chunk_size"):
        DPPRerankConfig(chunk_size=0)
    assert DPPRerankConfig(chunk_size=4).greedy_spec().chunk_size is None
    assert (
        DPPRerankConfig(chunk_size=4, use_kernel=True).greedy_spec()
        .chunk_size == 4
    )


def test_streaming_rejects_missing_or_bad_chunk():
    D, M = 8, 64
    V = make_greedy_inputs(59, None, D, M)
    spec = GreedySpec(k=4, backend="jnp")
    with pytest.raises(ValueError, match="chunk size"):
        next(greedy_map_chunks(spec, V=V))
    with pytest.raises(ValueError, match="chunk_size"):
        next(greedy_map_chunks(spec, V=V, chunk_size=0))
    with pytest.raises(ValueError, match="exactly one"):
        greedy_init(spec)
