"""GraphCast-family GNN: smoke tests + segment-sum message-passing oracle."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.data import batched_molecules, neighbor_sample, pad_subgraph, random_graph
from repro.models import gnn

TINY = gnn.GNNConfig(
    name="tiny-gnn", n_layers=2, d_hidden=16, d_feat=8, n_vars=3, d_edge=4,
    dtype=jnp.float32,
)


def test_forward_shapes_and_finite():
    g = random_graph(50, 200, TINY.d_feat, TINY.n_vars, seed=0)
    params = gnn.init_params(jax.random.PRNGKey(0), TINY)
    out = gnn.apply(params, jnp.asarray(g.node_feats), jnp.asarray(g.edges), TINY)
    assert out.shape == (50, TINY.n_vars)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_loss_and_grads():
    g = random_graph(30, 100, TINY.d_feat, TINY.n_vars, seed=1)
    params = gnn.init_params(jax.random.PRNGKey(0), TINY)
    batch = {
        "node_feats": jnp.asarray(g.node_feats),
        "edges": jnp.asarray(g.edges),
        "targets": jnp.asarray(g.targets),
    }
    loss, grads = jax.value_and_grad(lambda p: gnn.mse_loss(p, batch, TINY))(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(a, np.float32)).all() for a in jax.tree.leaves(grads))


def test_segment_sum_matches_dense_adjacency():
    """segment_sum message passing == dense adjacency matmul oracle."""
    rng = np.random.default_rng(2)
    N, E, D = 20, 60, 5
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    msgs = rng.normal(size=(E, D)).astype(np.float32)
    got = jax.ops.segment_sum(jnp.asarray(msgs), jnp.asarray(dst), num_segments=N)
    A = np.zeros((N, E), np.float32)
    A[dst, np.arange(E)] = 1.0
    np.testing.assert_allclose(np.asarray(got), A @ msgs, rtol=1e-5, atol=1e-5)


def test_edge_mask_excludes_padding():
    g = random_graph(25, 80, TINY.d_feat, TINY.n_vars, seed=3)
    params = gnn.init_params(jax.random.PRNGKey(0), TINY)
    feats, edges = jnp.asarray(g.node_feats), jnp.asarray(g.edges)
    out_ref = gnn.apply(params, feats, edges, TINY)
    # append garbage edges, masked off -> identical output
    bad = jnp.asarray([[0, 1], [3, 4], [7, 7]], jnp.int32)
    edges_pad = jnp.concatenate([edges, bad])
    mask = jnp.asarray([True] * 80 + [False] * 3)
    out_pad = gnn.apply(params, feats, edges_pad, TINY, edge_mask=mask)
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_ref), rtol=1e-4, atol=1e-5)


def test_neighbor_sampler_subgraph():
    g = random_graph(200, 1200, TINY.d_feat, TINY.n_vars, seed=4)
    rng = np.random.default_rng(0)
    seeds = rng.choice(200, size=16, replace=False)
    sub = neighbor_sample(g, seeds, fanouts=(5, 3), rng=rng)
    assert sub["node_feats"].shape[0] == sub["node_ids"].shape[0]
    # every edge endpoint is a valid local node id
    if sub["edges"].size:
        assert sub["edges"].max() < sub["node_ids"].shape[0]
    padded = pad_subgraph(sub, max_nodes=512, max_edges=2048)
    params = gnn.init_params(jax.random.PRNGKey(0), TINY)
    loss = gnn.mse_loss(
        params, {k: jnp.asarray(v) for k, v in padded.items()}, TINY
    )
    assert np.isfinite(float(loss))


def test_batched_molecules_disjoint():
    batch = batched_molecules(8, nodes_per=10, edges_per=20, d_feat=TINY.d_feat,
                              n_vars=TINY.n_vars, seed=5)
    params = gnn.init_params(jax.random.PRNGKey(0), TINY)
    out = gnn.apply(params, jnp.asarray(batch["node_feats"]),
                    jnp.asarray(batch["edges"]), TINY)
    assert out.shape == (80, TINY.n_vars)
    # graph 0's outputs must be independent of graph 7's features
    feats2 = batch["node_feats"].copy()
    feats2[70:] += 100.0
    out2 = gnn.apply(params, jnp.asarray(feats2), jnp.asarray(batch["edges"]), TINY)
    np.testing.assert_allclose(np.asarray(out[:10]), np.asarray(out2[:10]), rtol=1e-4, atol=1e-5)
