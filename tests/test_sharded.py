"""Sharded candidate-axis greedy MAP (core.sharded + serving.sharded_rerank).

Fast lane: GreedySpec construction-time validation, mask threading
through the serving layer, and the full sharded code path on a trivial
1-device mesh (the collectives run with axis size 1, so every branch is
exercised in-process).

Slow lane: multi-device correctness runs in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test
process keeps 1 device, per the dry-run isolation contract).  The
hypothesis property under test is the subsystem's core guarantee:
sharded greedy — exact and windowed, padded and masked — selects the
bit-identical slate and d_hist as the single-device low-rank path on
the gathered V.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import assert_greedy_parity, make_greedy_inputs, serve_rerank
from repro.core import (
    GreedySpec,
    GreedySpecError,
    dpp_greedy_lowrank,
    dpp_greedy_sharded,
    greedy_map,
    sharded_topk,
)
from repro.core.windowed import dpp_greedy_windowed_lowrank
from repro.distributed.context import make_mesh_compat
from repro.serving import DPPRerankConfig, Reranker, RerankRequest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _problem(seed, M=120, D=24):
    # the shared conftest builder (alpha=None = this suite's historical
    # gaussian / sqrt(D) conditioning)
    return make_greedy_inputs(seed, None, D, M, alpha=None)


# ---------------------------------------------------------------------------
# GreedySpec construction-time validation
# ---------------------------------------------------------------------------


def test_spec_validation_at_construction():
    """Bad configs fail with a named error when the spec is built, not
    deep inside a jitted trace."""
    with pytest.raises(GreedySpecError, match="k must be"):
        GreedySpec(k=0)
    with pytest.raises(GreedySpecError, match="k must be"):
        GreedySpec(k=-3)
    with pytest.raises(GreedySpecError, match="window must be"):
        GreedySpec(k=5, window=0)
    with pytest.raises(GreedySpecError, match="window must be"):
        GreedySpec(k=5, window=-1)
    with pytest.raises(GreedySpecError, match="unknown backend"):
        GreedySpec(k=5, backend="tpu")
    with pytest.raises(GreedySpecError, match="mesh"):
        GreedySpec(k=5, backend="sharded")
    with pytest.raises(GreedySpecError, match="mesh"):
        GreedySpec(k=5, backend="pallas", mesh=make_mesh_compat((1,), ("data",)))
    with pytest.raises(GreedySpecError, match="silently ignored"):
        GreedySpec(k=5, backend="jnp", mesh=make_mesh_compat((1,), ("data",)))
    # GreedySpecError is a ValueError: existing except-ValueError callers hold
    assert issubclass(GreedySpecError, ValueError)
    # valid specs still construct
    GreedySpec(k=5, window=5)
    GreedySpec(k=5, backend="sharded", mesh=make_mesh_compat((1,), ("data",)))


def test_rerank_config_validation():
    mesh = make_mesh_compat((1,), ("data",))
    with pytest.raises(ValueError, match="mutually exclusive"):
        DPPRerankConfig(use_kernel=True, mesh=mesh)
    spec = DPPRerankConfig(slate_size=4, mesh=mesh).greedy_spec()
    assert spec.backend == "sharded" and spec.mesh is mesh


def test_rerank_config_validates_at_construction():
    """Nonsensical slate/shortlist/window/eps fail when the config is
    built (mirroring GreedySpecError), not as shape/trace errors inside
    the jitted serve step."""
    with pytest.raises(ValueError, match="slate_size must be"):
        DPPRerankConfig(slate_size=0)
    with pytest.raises(ValueError, match="slate_size must be"):
        DPPRerankConfig(slate_size=-5)
    with pytest.raises(ValueError, match="shortlist must be"):
        DPPRerankConfig(shortlist=0)
    with pytest.raises(ValueError, match="shortlist must be"):
        DPPRerankConfig(shortlist=-1)
    with pytest.raises(ValueError, match="window must be"):
        DPPRerankConfig(window=0)
    with pytest.raises(ValueError, match="window must be"):
        DPPRerankConfig(window=-2)
    with pytest.raises(ValueError, match="eps must be"):
        DPPRerankConfig(eps=-1e-6)
    # boundary values that must still construct
    DPPRerankConfig(slate_size=1, shortlist=1, window=1, eps=0.0)


# ---------------------------------------------------------------------------
# Sharded greedy on a 1-device mesh (full code path, in-process)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1])
def test_sharded_matches_lowrank_one_device(seed):
    V = _problem(seed)
    ref = dpp_greedy_lowrank(V, 10, eps=1e-6)
    got = dpp_greedy_sharded(V, 10, mesh=make_mesh_compat((1,), ("data",)), eps=1e-6)
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    np.testing.assert_array_equal(np.asarray(ref.d_hist), np.asarray(got.d_hist))
    assert int(ref.n_selected) == int(got.n_selected)


@pytest.mark.parametrize("window", [None, 5])
def test_sharded_matches_shared_oracle(greedy_oracle, window):
    """The sharded backend against the one shared oracle fixture — the
    same ground truth the kernel and streaming suites assert against."""
    V = _problem(7)
    rng = np.random.default_rng(7)
    mask = jnp.asarray(rng.uniform(size=V.shape[1]) > 0.25)
    got = dpp_greedy_sharded(
        V, 10, mesh=make_mesh_compat((1,), ("data",)), window=window,
        eps=1e-6, mask=mask,
    )
    assert_greedy_parity(greedy_oracle, got.indices, got.d_hist, V, 10,
                         window=window, eps=1e-6, mask=mask)


def test_sharded_windowed_matches_one_device():
    V = _problem(3)
    mesh = make_mesh_compat((1,), ("data",))
    ref = dpp_greedy_windowed_lowrank(V, 24, window=5, eps=1e-6)
    got = dpp_greedy_sharded(V, 24, mesh=mesh, window=5, eps=1e-6)
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    np.testing.assert_array_equal(np.asarray(ref.d_hist), np.asarray(got.d_hist))


def test_sharded_mask_and_dispatch():
    """greedy_map routes backend='sharded' (and auto + mesh) correctly;
    masked candidates never selected."""
    V = _problem(4)
    M = V.shape[1]
    rng = np.random.default_rng(4)
    mask = jnp.asarray(rng.uniform(size=M) > 0.4)
    mesh = make_mesh_compat((1,), ("data",))
    ref = dpp_greedy_lowrank(V, 8, eps=1e-6, mask=mask)
    got = greedy_map(
        GreedySpec(k=8, backend="sharded", mesh=mesh, eps=1e-6), V=V, mask=mask
    )
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    auto = greedy_map(GreedySpec(k=8, mesh=mesh, eps=1e-6), V=V, mask=mask)
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(auto.indices))
    sel = np.asarray(got.indices)
    assert all(bool(mask[i]) for i in sel if i >= 0)


def test_sharded_rejects_dense_and_bad_rank():
    mesh = make_mesh_compat((1,), ("data",))
    spec = GreedySpec(k=4, backend="sharded", mesh=mesh)
    L = jnp.eye(8)
    with pytest.raises(ValueError, match="low-rank V"):
        greedy_map(spec, L=L)
    with pytest.raises(ValueError, match="ndim"):
        dpp_greedy_sharded(jnp.ones((2, 2, 4, 16)), 2, mesh=mesh)
    with pytest.raises(ValueError, match="mesh has no axis"):
        dpp_greedy_sharded(jnp.ones((4, 16)), 2, mesh=mesh, axis_name="model")


def test_sharded_topk_one_device():
    rng = np.random.default_rng(7)
    s = jnp.asarray(rng.uniform(size=97), jnp.float32)
    mesh = make_mesh_compat((1,), ("data",))
    v1, i1 = jax.lax.top_k(s, 13)
    v2, i2 = sharded_topk(s, 13, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_sharded_rerank_matches_dense_one_device():
    rng = np.random.default_rng(9)
    M, D = 300, 16
    scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    feats = feats / jnp.linalg.norm(feats, axis=1, keepdims=True)
    mesh = make_mesh_compat((1,), ("data",))
    for window in (None, 4):
        dense, _ = serve_rerank(
            scores, feats,
            DPPRerankConfig(slate_size=10, shortlist=128, alpha=3.0,
                            eps=1e-6, window=window),
        )
        sh, _ = serve_rerank(
            scores, feats,
            DPPRerankConfig(slate_size=10, shortlist=128, alpha=3.0,
                            eps=1e-6, window=window, mesh=mesh),
        )
        np.testing.assert_array_equal(np.asarray(dense), np.asarray(sh))


# ---------------------------------------------------------------------------
# Batched sharded greedy / rerank (users x candidates on one mesh)
# ---------------------------------------------------------------------------


def test_sharded_batched_matches_lowrank_batch_one_device():
    """V (B, D, M): the batched sharded loop (state (B, Mloc) per device,
    collectives batched over B) matches the vmap single-device path."""
    from repro.core import dpp_greedy_lowrank_batch

    rng = np.random.default_rng(21)
    B, D, M, k = 4, 12, 90, 8
    V = jnp.asarray(rng.normal(size=(B, D, M)), jnp.float32) / np.sqrt(D)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.3)
    mesh = make_mesh_compat((1,), ("data",))
    ref = dpp_greedy_lowrank_batch(V, k, 1e-6, mask)
    got = dpp_greedy_sharded(V, k, mesh=mesh, eps=1e-6, mask=mask)
    assert got.indices.shape == (B, k)
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    np.testing.assert_allclose(
        np.asarray(ref.d_hist), np.asarray(got.d_hist), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_array_equal(
        np.asarray(ref.n_selected), np.asarray(got.n_selected)
    )
    # dispatch no longer rejects batched V on the sharded backend
    via_map = greedy_map(
        GreedySpec(k=k, backend="sharded", mesh=mesh, eps=1e-6), V=V, mask=mask
    )
    np.testing.assert_array_equal(
        np.asarray(ref.indices), np.asarray(via_map.indices)
    )


def test_sharded_topk_batched_one_device():
    rng = np.random.default_rng(22)
    s = jnp.asarray(rng.uniform(size=(3, 97)), jnp.float32)
    mesh = make_mesh_compat((1,), ("data",))
    v1, i1 = jax.lax.top_k(s, 13)  # top_k batches over leading axes
    v2, i2 = sharded_topk(s, 13, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


@pytest.mark.parametrize("window", [None, 3])
@pytest.mark.parametrize("per_user_feats", [False, True])
def test_rerank_batch_sharded_matches_vmap_one_device(window, per_user_feats):
    """A batched request with cfg.mesh: identical slates, per user, to
    the vmap of the single-device dispatch — shared or per-user
    features, per-user masks, padded M (not divisible by the axis
    size)."""
    rng = np.random.default_rng(23)
    B, M, D = 4, 121, 8
    scores = jnp.asarray(rng.uniform(size=(B, M)), jnp.float32)
    shape = (B, M, D) if per_user_feats else (M, D)
    feats = rng.normal(size=shape).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=-1, keepdims=True)
    feats = jnp.asarray(feats)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.25)
    mesh = make_mesh_compat((1,), ("data",))
    kw = dict(slate_size=6, shortlist=64, alpha=3.0, eps=1e-6, window=window)
    ref, ref_dh = serve_rerank(scores, feats, DPPRerankConfig(**kw), mask=mask)
    got, got_dh = serve_rerank(
        scores, feats, DPPRerankConfig(mesh=mesh, **kw), mask=mask
    )
    assert got.shape == (B, 6)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    np.testing.assert_allclose(
        np.asarray(ref_dh), np.asarray(got_dh), rtol=1e-6, atol=1e-7
    )


def test_rerank_batch_sharded_eps_stop():
    """Rank-deficient per-user kernels eps-stop at the same step as the
    vmap single-device path (slots after the stop hold -1)."""
    rng = np.random.default_rng(24)
    B, M, D = 4, 80, 3
    scores = jnp.asarray(rng.uniform(size=(B, M)), jnp.float32)
    feats = rng.normal(size=(B, M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=-1, keepdims=True)
    feats = jnp.asarray(feats)
    mesh = make_mesh_compat((1,), ("data",))
    kw = dict(slate_size=10, shortlist=64, alpha=2.0, eps=1e-2)
    ref, _ = serve_rerank(scores, feats, DPPRerankConfig(**kw))
    got, _ = serve_rerank(scores, feats, DPPRerankConfig(mesh=mesh, **kw))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    assert (np.asarray(got) == -1).any()  # the stop actually fired


# ---------------------------------------------------------------------------
# Mask plumbing regressions (shared (M,) mask x batched V; poisoned
# scores on masked items)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas", "sharded"])
def test_shared_mask_batched_V_all_backends(backend):
    """A shared (M,) mask alongside a batched V (B, D, M) is broadcast to
    (B, M) in dispatch — regression for the pallas path leaving mb
    unbatched (mask.reshape(B, 1, M) blew up) and for the jnp/sharded
    batch paths vmapping a rank-1 mask."""
    rng = np.random.default_rng(31)
    B, D, M, k = 3, 10, 72, 6
    V = jnp.asarray(rng.normal(size=(B, D, M)), jnp.float32) / np.sqrt(D)
    mask = jnp.asarray(rng.uniform(size=M) > 0.4)  # shared across users
    kw = dict(k=k, eps=1e-6)
    if backend == "sharded":
        kw["mesh"] = make_mesh_compat((1,), ("data",))
    spec = GreedySpec(backend=backend, **kw)
    got = greedy_map(spec, V=V, mask=mask)
    ref = greedy_map(
        GreedySpec(k=k, backend="jnp", eps=1e-6),
        V=V,
        mask=jnp.broadcast_to(mask, (B, M)),
    )
    assert got.indices.shape == (B, k)
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    sel = np.asarray(got.indices)
    assert all(bool(mask[i]) for i in sel.ravel() if i >= 0)


@pytest.mark.parametrize("poison", [float("nan"), float("-inf")])
def test_sharded_rerank_masked_score_poison(poison):
    """A NaN/-inf score on a *masked* item must not leak into the kernel:
    V's masked columns are zeroed exactly as the single-device rerank
    zeroes masked shortlist relevances."""
    rng = np.random.default_rng(32)
    M, D = 150, 8
    scores = rng.uniform(size=M).astype(np.float32)
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    mask = np.ones(M, bool)
    mask[7] = False
    clean = jnp.asarray(scores)
    scores = scores.copy()
    scores[7] = poison
    mesh = make_mesh_compat((1,), ("data",))
    cfg = DPPRerankConfig(
        slate_size=8, shortlist=64, alpha=3.0, eps=1e-6, mesh=mesh
    )
    slate, dh = serve_rerank(jnp.asarray(scores), jnp.asarray(feats), cfg,
                             mask=jnp.asarray(mask))
    slate, dh = np.asarray(slate), np.asarray(dh)
    assert (slate >= 0).sum() == 8 and 7 not in slate.tolist()
    assert np.isfinite(dh).all()
    # the poisoned-but-masked score changes nothing vs a clean one
    ref, _ = serve_rerank(clean, jnp.asarray(feats), cfg,
                          mask=jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(ref), slate)


def test_sharded_rerank_rejects_rank_inconsistent_inputs():
    """Rank-inconsistent inputs must never reach the mesh: a request
    whose feats or mask carry a batch axis the scores lack fails at
    RerankRequest construction, and a batched request cannot stream."""
    rng = np.random.default_rng(34)
    M, D, B = 64, 6, 3
    scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    mesh = make_mesh_compat((1,), ("data",))
    cfg = DPPRerankConfig(slate_size=4, shortlist=32, mesh=mesh)
    with pytest.raises(ValueError, match="feats must be"):
        RerankRequest(scores=scores, feats=jnp.stack([feats] * B))
    with pytest.raises(ValueError, match="mask must be"):
        RerankRequest(scores=scores, feats=feats,
                      mask=jnp.ones((B, M), bool))
    with pytest.raises(ValueError, match="single request"):
        Reranker(cfg).stream(
            RerankRequest(scores=jnp.stack([scores] * B), feats=feats)
        )


def test_sharded_rerank_inf_relevance_outside_shortlist():
    """An unmasked item whose relevance overflows to inf (alpha < 1 with
    a very negative score) ranks outside the top-C shortlist — the
    single-device rerank never builds its V column, and the sharded path
    must likewise zero it rather than let the inf poison the matvec."""
    rng = np.random.default_rng(33)
    M, D = 200, 8
    scores = rng.uniform(size=M).astype(np.float32)
    scores[11] = -130.0  # 0.5 ** -130 overflows float32 -> inf relevance
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    mesh = make_mesh_compat((1,), ("data",))
    kw = dict(slate_size=8, shortlist=64, alpha=0.5, eps=1e-6)
    ref, _ = serve_rerank(jnp.asarray(scores), jnp.asarray(feats),
                          DPPRerankConfig(**kw))
    got, dh = serve_rerank(jnp.asarray(scores), jnp.asarray(feats),
                           DPPRerankConfig(mesh=mesh, **kw))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    assert np.isfinite(np.asarray(dh)).all()
    assert 11 not in np.asarray(got).tolist()


# ---------------------------------------------------------------------------
# Mask threading through the serving layer (satellite: serve can now
# exclude already-seen / filtered items)
# ---------------------------------------------------------------------------


def test_rerank_mask_excludes_banned_items():
    rng = np.random.default_rng(11)
    M, D = 200, 16
    scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    feats = feats / jnp.linalg.norm(feats, axis=1, keepdims=True)
    cfg = DPPRerankConfig(slate_size=10, shortlist=64, alpha=3.0, eps=1e-6)
    base, _ = serve_rerank(scores, feats, cfg)
    banned = np.asarray(base)[:5]
    mask = jnp.ones(M, bool).at[banned].set(False)
    slate, _ = serve_rerank(scores, feats, cfg, mask=mask)
    slate = np.asarray(slate)
    assert set(banned.tolist()).isdisjoint(set(slate.tolist()))
    assert (slate >= 0).sum() == 10  # the slate refills from unbanned items


def test_rerank_batch_mask():
    rng = np.random.default_rng(12)
    B, M, D = 3, 96, 8
    scores = jnp.asarray(rng.uniform(size=(B, M)), jnp.float32)
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.3)
    slates, _ = serve_rerank(
        scores, jnp.asarray(feats),
        DPPRerankConfig(slate_size=6, shortlist=48), mask=mask,
    )
    assert slates.shape == (B, 6)
    for b in range(B):
        for i in np.asarray(slates[b]):
            if i >= 0:
                assert bool(mask[b, i])


# ---------------------------------------------------------------------------
# Multi-device property test (subprocess, slow lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_matches_lowrank_multidevice_property():
    """Hypothesis: on an 8-host-device mesh, sharded greedy selects the
    identical slate as the single-device low-rank path (d_hist equal to
    ~1 ulp) — exact and windowed modes, M divisible by P or padded,
    masked or not."""
    pytest.importorskip("hypothesis")
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from hypothesis import given, settings, strategies as st
        from repro.core import dpp_greedy_sharded, dpp_greedy_lowrank
        from repro.core.windowed import dpp_greedy_windowed_lowrank
        from repro.distributed.context import make_mesh_compat
        assert jax.device_count() == 8
        mesh = make_mesh_compat((8,), ("data",))

        @settings(max_examples=20, deadline=None)
        @given(
            seed=st.integers(0, 2**31 - 1),
            M=st.integers(16, 200),
            D=st.integers(4, 32),
            k=st.integers(1, 12),
            window=st.one_of(st.none(), st.integers(1, 6)),
            masked=st.booleans(),
        )
        def check(seed, M, D, k, window, masked):
            # stay in the full-rank regime (k <= D): past the kernel's
            # numerical rank the marginal gains are f32 cancellation
            # noise and argmax order is not meaningful (the paper's
            # eq.-20 eps-stop exists to halt selection there)
            k = min(k, D)
            rng = np.random.default_rng(seed)
            V = jnp.asarray(rng.normal(size=(D, M)), jnp.float32) / np.sqrt(D)
            mask = jnp.asarray(rng.uniform(size=M) > 0.3) if masked else None
            if window is None or window >= k:
                ref = dpp_greedy_lowrank(V, k, eps=1e-6, mask=mask)
            else:
                ref = dpp_greedy_windowed_lowrank(
                    V, k, window=window, eps=1e-6, mask=mask)
            got = dpp_greedy_sharded(
                V, k, mesh=mesh, window=window, eps=1e-6, mask=mask)
            np.testing.assert_array_equal(
                np.asarray(ref.indices), np.asarray(got.indices))
            # XLA may compile the per-shard (D, M/P) reductions with a
            # different op order than the (D, M) single-device shapes, so
            # d_hist is identical only to ~1 ulp, not bitwise
            np.testing.assert_allclose(
                np.asarray(ref.d_hist), np.asarray(got.d_hist),
                rtol=1e-6, atol=1e-7)
            assert int(ref.n_selected) == int(got.n_selected)

        check()
        print("SHARDED-PROPERTY-OK")
    """)


@pytest.mark.slow
def test_sharded_rerank_multidevice_serving_parity():
    """8-device sharded rerank (sharded top-k shortlist + sharded greedy)
    returns the identical slate to the single-device serving path."""
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import sharded_topk
        from repro.distributed.context import make_mesh_compat
        from repro.serving import DPPRerankConfig, Reranker, RerankRequest
        def rr(s, f, cfg, mask=None):
            return Reranker(cfg).rerank(
                RerankRequest(scores=s, feats=f, mask=mask))
        assert jax.device_count() == 8
        mesh = make_mesh_compat((8,), ("data",))
        rng = np.random.default_rng(0)
        M, D = 3001, 16  # deliberately not divisible by 8 (padded shards)
        scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
        feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
        feats = feats / jnp.linalg.norm(feats, axis=1, keepdims=True)
        mask = jnp.asarray(rng.uniform(size=M) > 0.2)
        v1, i1 = jax.lax.top_k(scores, 500)
        v2, i2 = sharded_topk(scores, 500, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        # window=1 is the regression case for the PartitionId SPMD
        # lowering failure (axis_index must stay hoisted out of the loop)
        for window in (None, 1, 5):
            for m in (None, mask):
                dense, _ = rr(scores, feats, DPPRerankConfig(
                    slate_size=16, shortlist=500, alpha=3.0, eps=1e-6,
                    window=window), mask=m)
                sh, _ = rr(scores, feats, DPPRerankConfig(
                    slate_size=16, shortlist=500, alpha=3.0, eps=1e-6,
                    window=window, mesh=mesh), mask=m)
                np.testing.assert_array_equal(np.asarray(dense), np.asarray(sh))
        print("SHARDED-SERVING-OK")
    """)


@pytest.mark.slow
def test_rerank_batch_sharded_multidevice_parity():
    """Acceptance bar for the users x candidates composition: on an
    8-host-device mesh, a batched request with cfg.mesh returns slates
    identical index-for-index (d_hist to ~1 ulp) to vmap of the
    single-device dispatch for B >= 4 users with per-user masks, padded
    M (not divisible by P), and per-user eps-stop."""
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from repro.distributed.context import make_mesh_compat
        from repro.serving import DPPRerankConfig, Reranker, RerankRequest
        def rr(s, f, cfg, mask=None):
            return Reranker(cfg).rerank(
                RerankRequest(scores=s, feats=f, mask=mask))
        assert jax.device_count() == 8
        mesh = make_mesh_compat((8,), ("data",))
        rng = np.random.default_rng(1)
        B, M, D = 5, 1501, 12  # M not divisible by 8 (padded shards)
        scores = jnp.asarray(rng.uniform(size=(B, M)), jnp.float32)
        feats = rng.normal(size=(M, D)).astype(np.float32)
        feats /= np.linalg.norm(feats, axis=1, keepdims=True)
        feats = jnp.asarray(feats)
        mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.25)
        for window in (None, 1, 4):
            for m in (None, mask):
                kw = dict(slate_size=10, shortlist=400, alpha=3.0,
                          eps=1e-6, window=window)
                ref, ref_dh = rr(
                    scores, feats, DPPRerankConfig(**kw), mask=m)
                got, got_dh = rr(
                    scores, feats, DPPRerankConfig(mesh=mesh, **kw), mask=m)
                np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
                np.testing.assert_allclose(
                    np.asarray(ref_dh), np.asarray(got_dh),
                    rtol=1e-6, atol=1e-7)
        # per-user eps-stop: rank-deficient per-user kernels (D=3) halt
        # at different steps per user; batched sharded must agree
        Bs, Ms, Ds = 4, 400, 3
        s2 = jnp.asarray(rng.uniform(size=(Bs, Ms)), jnp.float32)
        f2 = rng.normal(size=(Bs, Ms, Ds)).astype(np.float32)
        f2 /= np.linalg.norm(f2, axis=-1, keepdims=True)
        f2 = jnp.asarray(f2)
        kw = dict(slate_size=8, shortlist=200, alpha=2.0, eps=1e-2)
        ref, _ = rr(s2, f2, DPPRerankConfig(**kw))
        got, _ = rr(s2, f2, DPPRerankConfig(mesh=mesh, **kw))
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
        assert (np.asarray(got) == -1).any()
        print("SHARDED-BATCH-OK")
    """)
