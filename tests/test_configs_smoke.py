"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED same-family config and runs one forward/train step on CPU,
asserting output shapes and no NaNs (full configs are exercised only via
the dry-run)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch, list_archs
from repro.data import batched_molecules, recsys_batches
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm

ALL_ARCHS = list_archs()


def test_registry_complete():
    assert len(ALL_ARCHS) == 10
    assert set(ALL_ARCHS) == {
        "arctic-480b", "olmoe-1b-7b", "phi3-mini-3.8b", "gemma3-27b",
        "qwen1.5-4b", "graphcast", "autoint", "xdeepfm", "wide-deep", "deepfm",
    }


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_reduced_smoke(arch_id):
    spec = get_arch(arch_id)
    cfg = spec.reduced()
    rng = jax.random.PRNGKey(0)
    if spec.family == "lm":
        params = tfm.init_params(rng, cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
        hidden, aux, _ = tfm.forward_hidden(params, toks, cfg)
        assert hidden.shape == (2, 24, cfg.d_model)
        assert np.isfinite(np.asarray(hidden, np.float32)).all()
        loss = tfm.train_loss(params, {"tokens": toks}, cfg)
        assert np.isfinite(float(loss))
    elif spec.family == "recsys":
        params = recsys_mod.init_params(rng, cfg)
        batch = next(recsys_batches(cfg.vocab_sizes, batch=32, seed=0))
        z = recsys_mod.forward_logits(params, jnp.asarray(batch["ids"]), cfg)
        assert z.shape == (32,)
        assert np.isfinite(np.asarray(z)).all()
        loss = recsys_mod.bce_loss(
            params, {k: jnp.asarray(v) for k, v in batch.items()}, cfg
        )
        assert np.isfinite(float(loss))
    else:
        params = gnn_mod.init_params(rng, cfg)
        batch = batched_molecules(4, 10, 20, cfg.d_feat, cfg.n_vars, seed=0)
        out = gnn_mod.apply(
            params, jnp.asarray(batch["node_feats"]), jnp.asarray(batch["edges"]), cfg
        )
        assert out.shape == (40, cfg.n_vars)
        assert np.isfinite(np.asarray(out, np.float32)).all()


@pytest.mark.parametrize("arch_id", ["arctic-480b", "gemma3-27b", "olmoe-1b-7b"])
def test_full_config_param_counts(arch_id):
    """Full configs match their advertised scale (structure only — the
    params are never materialized)."""
    spec = get_arch(arch_id)
    n = spec.config.param_count()
    expected = {"arctic-480b": 480e9, "gemma3-27b": 27e9, "olmoe-1b-7b": 7e9}[arch_id]
    assert 0.65 * expected < n < 1.45 * expected, (arch_id, n)


def test_full_lm_configs_head_divisibility():
    for arch_id in ALL_ARCHS:
        spec = get_arch(arch_id)
        if spec.family != "lm":
            continue
        cfg = spec.config
        assert cfg.n_heads % cfg.n_kv_heads == 0
        windows = cfg.layer_windows()
        assert len(windows) == cfg.n_layers


def test_shape_sets_assigned():
    for arch_id in ALL_ARCHS:
        spec = get_arch(arch_id)
        n = len(spec.shapes)
        assert n == 4, (arch_id, n)  # 10 archs x 4 shapes = 40 cells
