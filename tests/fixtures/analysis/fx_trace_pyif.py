"""Seeded violations: trace-pyif (Python control flow on tracers)."""
import jax


@jax.jit
def branch_on_tracer(x):
    if x > 0:  # LINE: trace-pyif if
        return x
    return -x


@jax.jit
def loop_on_tracer(x):
    y = x * 2.0
    while y < 10.0:  # LINE: trace-pyif while (taint flows via y)
        y = y + 1.0
    return y


@jax.jit
def host_branches_are_fine(x, mode=None):
    # `is None` and shape comparisons are host checks — no finding
    if mode is None:
        return x
    if x.shape[0] > 4:
        return x[:4]
    return x
