"""Seeded violations: session-geometry (mutable resume geometry)."""


class WobblySession:
    def __init__(self, spec):
        self.spec = spec
        self._state = None
        self._V = None

    def next_chunk(self, n):
        return greedy_chunk(  # noqa: F821
            self.spec, self._state, self._V, chunk_size=n
        )

    def extend(self, V_new):
        self.spec = rebuild_spec(self.spec)  # LINE: session-geometry write  # noqa: F821,E501
        self._state, self._V = greedy_state_extend(  # noqa: F821
            self.spec, self._state, self._V, 0, V_new
        )

    def extend_again(self, V_new):
        return greedy_state_extend(  # LINE: session-geometry 2nd launch
            self.spec, self._state, self._V, 0, V_new
        )  # noqa: F821


class SteadySession:
    """Write-once geometry, one launch site per family: proves clean."""

    def __init__(self, spec):
        self.spec = spec
        self._state = None
        self._V = None

    def next_chunk(self, n):
        return greedy_chunk(  # noqa: F821
            self.spec, self._state, self._V, chunk_size=n
        )

    def extend(self, V_new):
        self._state, self._V = greedy_state_extend(  # noqa: F821
            self.spec, self._state, self._V, 0, V_new
        )

    def rescore(self, start, V_blk):
        self._state, self._V = greedy_state_rescore(  # noqa: F821
            self.spec, self._state, self._V, start, V_blk
        )
