"""Seeded violations: host-sync-hot (syncs in pump() hot phases)."""
import numpy as np


class LeakyRouter:
    def pump(self):
        with obs.span("router.pump"):  # noqa: F821 (parsed, not run)
            flags = np.asarray(self.state.stopped)  # LINE: host-sync-hot
        done = self.handle.block_until_ready()  # LINE: host-sync-hot
        with obs.span("router.pump.sync"):  # noqa: F821
            ok = np.asarray(self.state.stopped)  # allowed: *.sync span
        with obs.span("router.pump.materialize"):  # noqa: F821
            out = np.asarray(self.slate)  # allowed: *.materialize span
        return flags, done, ok, out

    def not_pump(self):
        # syncs outside pump() are not this rule's business
        return np.asarray(self.slate)
