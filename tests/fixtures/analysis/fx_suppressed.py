"""Suppression semantics: valid suppression silences, typo is itself a
finding (bad-suppression) and silences nothing."""
import jax


@jax.jit
def tolerated(x):
    if x > 0:  # repro: ignore[trace-pyif]
        return x
    return -x


@jax.jit
def typo_does_not_silence(x):
    if x > 0:  # repro: ignore[trace-pyiff] LINE: bad-suppression
        return x
    return -x
