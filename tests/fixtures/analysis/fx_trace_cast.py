"""Seeded violations: trace-cast (concretizing casts in traced scopes)."""
import functools

import jax


@jax.jit
def cast_in_jit(x):
    return float(x) + 1.0  # LINE: trace-cast float


@functools.partial(jax.jit, static_argnames=("k",))
def item_in_jit(x, k):
    return x.sum().item() + k  # LINE: trace-cast item


def cast_in_kernel(x_ref, o_ref):
    o_ref[0] = int(x_ref[0])  # LINE: trace-cast kernel


@jax.jit
def static_shape_is_fine(x):
    # .shape / len() launder taint: no finding expected here
    return x.reshape(len(x.shape), -1)
