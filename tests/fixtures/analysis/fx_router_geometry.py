"""Seeded violations: router-geometry (mutable compiled geometry)."""


class WobblyRouter:
    def __init__(self, spec, chunk):
        self.spec = spec
        self.chunk = chunk
        self._state = None
        self._V = None
        self._D = None

    def admit(self, feats):
        self._D = feats.shape[0]  # LINE: router-geometry lazy unguarded
        if self._state is None:
            self._state = greedy_slots_init(  # noqa: F821
                self.spec, 4, self._D, 64
            )

    def pump(self):
        self.chunk = self.chunk + 1  # LINE: router-geometry write
        return greedy_chunk_slots(  # noqa: F821
            self.spec, self._state, self._V, self.chunk
        )

    def flush(self):
        return greedy_chunk_slots(  # LINE: router-geometry 2nd launch
            self.spec, self._state, self._V, self.chunk
        )  # noqa: F821


class SteadyRouter:
    """Write-once geometry, one launch site: proves clean."""

    def __init__(self, spec, chunk):
        self.spec = spec
        self.chunk = chunk
        self._state = None
        self._D = None

    def admit(self, feats):
        if self._D is None:
            self._D = feats.shape[0]
        if self._state is None:
            self._state = greedy_slots_init(  # noqa: F821
                self.spec, 4, self._D, 64
            )

    def pump(self, V):
        return greedy_chunk_slots(  # noqa: F821
            self.spec, self._state, V, self.chunk
        )
