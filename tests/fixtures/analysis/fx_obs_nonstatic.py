"""Seeded violations: obs-nonstatic (device work in obs hook args)."""
import jax.numpy as jnp


def emit(obs, x, n):
    with obs.span("serving.chunk", total=jnp.sum(x)):  # LINE: obs-nonstatic
        pass
    obs.span("serving.flush", last=x.item())  # LINE: obs-nonstatic
    with obs.span("serving.ok", count=n, width=int(n) * 2):
        pass  # host scalars are fine
