"""Seeded violations: jit-static-missing / jit-static-unhashable."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("chunk_sizes", "kk"))
def bad_statics(x, chunk_sizes: jnp.ndarray, k: int = 4):
    # "kk" -> jit-static-missing (typo of "k"); chunk_sizes annotated
    # as an array -> jit-static-unhashable
    return x * k


def caller(x):
    # unhashable literal into a static kw -> jit-static-unhashable
    return bad_statics(x, chunk_sizes=[1, 2, 3])


@functools.partial(jax.jit, static_argnames=("w",))
def good_statics(x, w: int):
    return x[:, :w]
