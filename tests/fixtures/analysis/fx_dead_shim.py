"""Seeded violations: dead-shim (removed PR-6 serving surface)."""
from repro.serving import rerank  # LINE: dead-shim import
from repro.serving.reranker import rerank_stream  # LINE: dead-shim import

import repro.serving as serving


def old_paths(scores, feats, cfg):
    a = rerank(scores, feats, cfg)
    b = rerank_stream(scores, feats, cfg)
    c = serving.sharded_rerank(scores, feats, cfg)  # LINE: dead-shim attr
    return a, b, c


def new_path_is_fine():
    from repro.serving.api import Reranker, RerankRequest  # noqa: F401
