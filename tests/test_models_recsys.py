"""RecSys models: smoke tests, EmbeddingBag vs dense one-hot oracle, FM
identity, and a small end-to-end learning check."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.data import recsys_batches
from repro.models import recsys
from repro.models.embedding import (
    EmbeddingSpec,
    embedding_bag,
    embedding_bag_ref,
    init_table,
)

VOCABS = (50, 30, 80, 20)


def tiny_cfg(interaction, **kw):
    defaults = dict(
        name=f"tiny-{interaction}", vocab_sizes=VOCABS, embed_dim=8,
        interaction=interaction, mlp_dims=(32, 16), dtype=jnp.float32,
    )
    defaults.update(kw)
    return recsys.RecsysConfig(**defaults)


CFGS = [
    tiny_cfg("fm"),
    tiny_cfg("cin", cin_layers=(12, 12)),
    tiny_cfg("concat"),
    tiny_cfg("self-attn", attn_layers=2, attn_heads=2, d_attn=4, mlp_dims=()),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_forward_and_loss(cfg):
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    batch = next(recsys_batches(cfg.vocab_sizes, batch=64, seed=0))
    z = recsys.forward_logits(params, jnp.asarray(batch["ids"]), cfg)
    assert z.shape == (64,)
    assert np.isfinite(np.asarray(z)).all()
    loss, grads = jax.value_and_grad(
        lambda p: recsys.bce_loss(p, {k: jnp.asarray(v) for k, v in batch.items()}, cfg)
    )(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(a, np.float32)).all() for a in jax.tree.leaves(grads))


def test_embedding_bag_matches_dense_onehot():
    spec = EmbeddingSpec(VOCABS, 8, pad_to_multiple=16)
    table = init_table(jax.random.PRNGKey(1), spec)
    rng = np.random.default_rng(0)
    ids = np.stack([rng.integers(0, v, size=(16, 3)) for v in VOCABS], axis=1)
    ids[:, :, 1:] = np.where(rng.uniform(size=ids[:, :, 1:].shape) < 0.5, -1, ids[:, :, 1:])
    ids = jnp.asarray(ids.astype(np.int32))
    got = embedding_bag(table, ids, spec)
    ref = embedding_bag_ref(table, ids, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-6)


def test_fm_identity():
    """0.5((Σv)² − Σv²) == Σ_{i<j} <v_i, v_j> (the FM identity)."""
    rng = np.random.default_rng(3)
    emb = rng.normal(size=(5, 6, 4)).astype(np.float32)
    fast = np.asarray(recsys.fm_second_order(jnp.asarray(emb)))
    slow = np.zeros(5, np.float32)
    for b in range(5):
        for i in range(6):
            for j in range(i + 1, 6):
                slow[b] += emb[b, i] @ emb[b, j]
    np.testing.assert_allclose(fast, slow, rtol=1e-4, atol=1e-4)


def test_deepfm_learns_planted_signal():
    """A few hundred SGD steps must beat chance AUC on the planted logit."""
    cfg = tiny_cfg("fm")
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    gen = recsys_batches(cfg.vocab_sizes, batch=256, seed=7)

    @jax.jit
    def step(p, ids, labels):
        loss, g = jax.value_and_grad(
            lambda q: recsys.bce_loss(q, {"ids": ids, "labels": labels}, cfg)
        )(p)
        p = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
        return p, loss

    first = last = None
    for i in range(150):
        b = next(gen)
        params, loss = step(params, jnp.asarray(b["ids"]), jnp.asarray(b["labels"]))
        if i == 0:
            first = float(loss)
        last = float(loss)
    assert last < first - 0.01, (first, last)


def test_item_embeddings_normalized():
    cfg = tiny_cfg("fm")
    params = recsys.init_params(jax.random.PRNGKey(0), cfg)
    f = recsys.item_embeddings(params, jnp.arange(10), cfg)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(f), axis=1), 1.0, rtol=1e-5)
