"""Windowed Div-DPP (beyond-paper long-slate variant)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    build_kernel_dense_raw,
    dpp_greedy_dense,
    normalize_columns,
    similarity_from_features,
    slate_diversity,
)
from repro.core.windowed import dpp_greedy_windowed


def problem(seed, M=120, D=48):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.uniform(0.2, 1.0, size=M), jnp.float32)
    F = normalize_columns(jnp.asarray(rng.normal(size=(D, M)), jnp.float32))
    S = similarity_from_features(F)
    return build_kernel_dense_raw(r, S), np.asarray(S)


@pytest.mark.parametrize("seed", [0, 1])
def test_full_window_matches_exact(seed):
    """window >= k degenerates to the exact Algorithm 1."""
    L, _ = problem(seed)
    k = 8
    exact = dpp_greedy_dense(L, k, eps=1e-5)
    windowed = dpp_greedy_windowed(L, k, window=k, eps=1e-5)
    np.testing.assert_array_equal(
        np.asarray(exact.indices), np.asarray(windowed.indices)
    )


def test_windowed_enables_long_slates():
    """Slate longer than rank(L) is impossible exactly (eps-stop) but the
    windowed variant keeps selecting with local diversity."""
    rng = np.random.default_rng(7)
    M, D = 100, 12  # rank 12 < slate 40
    F = normalize_columns(jnp.asarray(rng.normal(size=(D, M)), jnp.float32))
    L = build_kernel_dense_raw(jnp.ones(M), similarity_from_features(F))
    exact = dpp_greedy_dense(L, 40, eps=1e-3)
    assert int(exact.n_selected) <= D + 3  # exact greedy stops near rank
    win = dpp_greedy_windowed(L, 40, window=6, eps=1e-3)
    assert int(win.n_selected) == 40  # windowed keeps going
    sel = np.asarray(win.indices)
    assert len(set(sel.tolist())) == 40  # no repeats


def test_windowed_diversity_beats_relevance_order():
    L, S = problem(3)
    win = dpp_greedy_windowed(L, 20, window=5)
    sel = np.asarray(win.indices)
    top = np.argsort(-np.asarray(jnp.diagonal(L)))[:20]
    d_win = slate_diversity(sel, S)
    d_top = slate_diversity(top, S)
    assert d_win["avg"] >= d_top["avg"] - 0.05
