"""Windowed Div-DPP (beyond-paper long-slate variant).

The incremental implementation (O(w M)/step: Cholesky-ring append +
Givens downdate) is checked against the independently-derived
rebuild-every-step reference (O(w^2 M)/step), against the exact
Algorithm 1 when the window covers the slate, and through the unified
``greedy_map`` dispatcher and the serving reranker.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    GreedySpec,
    build_kernel_dense_raw,
    dpp_greedy_dense,
    greedy_map,
    normalize_columns,
    similarity_from_features,
    slate_diversity,
)
from repro.core.windowed import (
    dpp_greedy_windowed,
    dpp_greedy_windowed_batch,
    dpp_greedy_windowed_lowrank,
    dpp_greedy_windowed_rebuild,
)


def problem(seed, M=120, D=48):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.uniform(0.2, 1.0, size=M), jnp.float32)
    F = normalize_columns(jnp.asarray(rng.normal(size=(D, M)), jnp.float32))
    S = similarity_from_features(F)
    return build_kernel_dense_raw(r, S), np.asarray(S)


@pytest.mark.parametrize("seed", [0, 1])
def test_full_window_matches_exact(seed):
    """window >= k degenerates to the exact Algorithm 1."""
    L, _ = problem(seed)
    k = 8
    exact = dpp_greedy_dense(L, k, eps=1e-5)
    windowed = dpp_greedy_windowed(L, k, window=k, eps=1e-5)
    np.testing.assert_array_equal(
        np.asarray(exact.indices), np.asarray(windowed.indices)
    )


def test_windowed_enables_long_slates():
    """Slate longer than rank(L) is impossible exactly (eps-stop) but the
    windowed variant keeps selecting with local diversity."""
    rng = np.random.default_rng(7)
    M, D = 100, 12  # rank 12 < slate 40
    F = normalize_columns(jnp.asarray(rng.normal(size=(D, M)), jnp.float32))
    L = build_kernel_dense_raw(jnp.ones(M), similarity_from_features(F))
    exact = dpp_greedy_dense(L, 40, eps=1e-3)
    assert int(exact.n_selected) <= D + 3  # exact greedy stops near rank
    win = dpp_greedy_windowed(L, 40, window=6, eps=1e-3)
    assert int(win.n_selected) == 40  # windowed keeps going
    sel = np.asarray(win.indices)
    assert len(set(sel.tolist())) == 40  # no repeats


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("k,w", [(20, 5), (30, 3), (40, 1), (25, 7)])
def test_incremental_matches_rebuild(seed, k, w):
    """The O(w M)/step incremental update == the O(w^2 M)/step rebuild
    reference: same selections, same marginal gains."""
    L, _ = problem(seed)
    inc = dpp_greedy_windowed(L, k, window=w, eps=1e-5)
    reb = dpp_greedy_windowed_rebuild(L, k, window=w, eps=1e-5)
    np.testing.assert_array_equal(np.asarray(inc.indices), np.asarray(reb.indices))
    np.testing.assert_allclose(
        np.asarray(inc.d_hist), np.asarray(reb.d_hist), rtol=2e-3, atol=1e-5
    )


@pytest.mark.parametrize("seed", [0, 3])
def test_lowrank_matches_dense(seed):
    """Implicit-kernel windowed greedy (V with L = V^T V) == dense path."""
    M, D = 120, 48
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.uniform(0.2, 1.0, size=M), jnp.float32)
    F = normalize_columns(jnp.asarray(rng.normal(size=(D, M)), jnp.float32))
    L = build_kernel_dense_raw(r, similarity_from_features(F))
    V = F * r[None, :]
    dense = dpp_greedy_windowed(L, 25, window=6, eps=1e-5)
    lowrank = dpp_greedy_windowed_lowrank(V, 25, window=6, eps=1e-5)
    np.testing.assert_array_equal(
        np.asarray(dense.indices), np.asarray(lowrank.indices)
    )


def test_windowed_batch_matches_loop():
    Ls = jnp.stack([problem(s)[0] for s in range(3)])
    batch = dpp_greedy_windowed_batch(Ls, 15, window=4, eps=1e-5)
    for b in range(3):
        one = dpp_greedy_windowed(Ls[b], 15, window=4, eps=1e-5)
        np.testing.assert_array_equal(
            np.asarray(batch.indices[b]), np.asarray(one.indices)
        )


def test_greedy_map_dispatch():
    """The unified entry point routes exact/windowed x dense/low-rank."""
    L, _ = problem(5)
    exact = greedy_map(GreedySpec(k=10, eps=1e-5), L=L)
    np.testing.assert_array_equal(
        np.asarray(exact.indices),
        np.asarray(dpp_greedy_dense(L, 10, eps=1e-5).indices),
    )
    win = greedy_map(GreedySpec(k=20, window=5, eps=1e-5), L=L)
    np.testing.assert_array_equal(
        np.asarray(win.indices),
        np.asarray(dpp_greedy_windowed(L, 20, window=5, eps=1e-5).indices),
    )
    with pytest.raises(ValueError):
        greedy_map(GreedySpec(k=5), L=L, V=L)
    with pytest.raises(ValueError):
        greedy_map(GreedySpec(k=5, backend="pallas"), L=L)
    with pytest.raises(ValueError, match="window"):
        greedy_map(GreedySpec(k=5, window=0), L=L)


def test_reranker_windowed_long_feed():
    """Serving path: a window lets the slate run past the kernel rank."""
    from repro.serving.reranker import DPPRerankConfig
    from conftest import serve_rerank

    rng = np.random.default_rng(2)
    M, D = 200, 12  # rank 12 << slate 48
    scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    feats = feats / jnp.linalg.norm(feats, axis=1, keepdims=True)
    exact_cfg = DPPRerankConfig(slate_size=48, shortlist=M, eps=1e-3)
    win_cfg = DPPRerankConfig(slate_size=48, shortlist=M, eps=1e-3, window=6)
    sel_exact, _ = serve_rerank(scores, feats, exact_cfg)
    sel_win, _ = serve_rerank(scores, feats, win_cfg)
    n_exact = int((np.asarray(sel_exact) >= 0).sum())
    n_win = int((np.asarray(sel_win) >= 0).sum())
    assert n_exact < 48  # exact eps-stops well short of the feed length
    assert n_win == 48  # windowed fills the whole feed
    valid = np.asarray(sel_win)
    assert len(set(valid.tolist())) == 48  # no repeats


def test_windowed_diversity_beats_relevance_order():
    L, S = problem(3)
    win = dpp_greedy_windowed(L, 20, window=5)
    sel = np.asarray(win.indices)
    top = np.argsort(-np.asarray(jnp.diagonal(L)))[:20]
    d_win = slate_diversity(sel, S)
    d_top = slate_diversity(top, S)
    assert d_win["avg"] >= d_top["avg"] - 0.05
