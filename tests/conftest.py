"""Shared fixtures for the greedy MAP parity suites.

Every fast-greedy backend in the repo — the jnp incremental paths, the
resident and tiled Pallas kernels, the candidate-sharded SPMD loop, and
now the chunk-emitting streaming executors — is ultimately tested
against **one oracle**: the independently-derived jnp rebuild path
(``dpp_greedy_windowed_rebuild``: per step, rebuild the window's
Cholesky factor from the dense kernel and re-solve every candidate;
``window >= k`` degenerates to the exact Algorithm 1).  The
``greedy_oracle`` fixture hands that oracle to every suite; its second
parametrization cross-checks through the incremental jnp path, which is
itself pinned to the rebuild oracle in tests/test_windowed.py — so a
backend passing either parametrization is transitively locked to the
same ground truth.

``make_greedy_inputs`` is the one input builder (it replaces the three
copy-pasted per-suite helpers: ``make_inputs`` in
test_kernel_dpp_greedy.py / test_kernel_tiled.py and ``_problem`` in
test_sharded.py), and ``assert_greedy_parity`` the one parity assertion
(indices index-for-index, d_hist to the oracle's tolerance).

The rebuild oracle materializes the dense (M, M) kernel — fine at test
sizes; the huge-M acceptance tests keep the low-rank incremental
parametrization.
"""
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import map_relevance
from repro.core.greedy_chol import dpp_greedy_lowrank
from repro.core.windowed import (
    dpp_greedy_windowed_lowrank,
    dpp_greedy_windowed_rebuild,
)


@pytest.fixture(autouse=True)
def _obs_lane():
    """CI's obs lane (``REPRO_OBS=1``) keeps a live observability
    session installed across every test, so the whole differential
    suite doubles as proof that telemetry never changes results.
    Unset (the default), this is a no-op and obs stays off."""
    if not os.environ.get("REPRO_OBS"):
        yield
        return
    from repro import obs

    fresh = not obs.enabled()
    if fresh:
        obs.enable(obs.ObsConfig(enabled=True))
    yield
    if fresh:
        obs.disable()


def serve_rerank(scores, feats, cfg, mask=None):
    """One-shot serving call through the session API — the test-suite
    spelling of what the removed PR-6 ``rerank``/``rerank_batch`` shims
    used to do (``Reranker`` dispatches on the request shape, so one
    helper covers single requests, user batches, and ``cfg.mesh``)."""
    from repro.serving.api import Reranker, RerankRequest

    return Reranker(cfg).rerank(
        RerankRequest(scores=scores, feats=feats, mask=mask)
    )


def serve_rerank_stream(scores, feats, cfg, mask=None, chunk_size=None):
    """Chunked serving call through the session API (the removed
    ``rerank_stream``/``sharded_rerank_stream`` shims' contract)."""
    from repro.serving.api import Reranker, RerankRequest

    return Reranker(cfg).stream(
        RerankRequest(scores=scores, feats=feats, mask=mask),
        chunk_size=chunk_size,
    )


def make_greedy_inputs(seed, B, D, M, alpha=2.0, dtype=jnp.float32):
    """Low-rank greedy inputs ``V`` with ``L = V^T V``.

    ``alpha`` set: column-normalized features scaled by the paper's
    relevance map (the serving-shaped distribution the kernel suites
    use).  ``alpha=None``: plain gaussian / sqrt(D) columns (the
    conditioning the sharded suite uses).  ``B=None`` returns a single
    ``(D, M)`` problem, otherwise ``(B, D, M)``.
    """
    rng = np.random.default_rng(seed)
    Bx = 1 if B is None else B
    if alpha is None:
        V = jnp.asarray(rng.normal(size=(Bx, D, M)), dtype) / np.sqrt(D)
    else:
        F = jnp.asarray(rng.normal(size=(Bx, D, M)), dtype)
        F = F / jnp.maximum(jnp.linalg.norm(F, axis=1, keepdims=True), 1e-12)
        r = jnp.asarray(rng.uniform(size=(Bx, M)), dtype)
        V = F * map_relevance(r, alpha)[:, None, :]
    return V[0] if B is None else V


class GreedyOracle:
    """Callable ground truth: ``oracle(V, k, window=, eps=, mask=)``
    -> ``(sel (k,) int32, d_hist (k,))`` numpy arrays for a single
    low-rank problem ``V (D, M)`` (batched ``V (B, D, M)`` is mapped
    per problem).  ``dh_rtol``/``dh_atol`` are the d_hist tolerance a
    fast path is held to against this derivation."""

    def __init__(self, name, fn, dh_rtol, dh_atol):
        self.name = name
        self._fn = fn
        self.dh_rtol = dh_rtol
        self.dh_atol = dh_atol

    def __call__(self, V, k, window=None, eps=1e-6, mask=None):
        V = jnp.asarray(V)
        if V.ndim == 3:
            ms = [None] * V.shape[0] if mask is None else list(mask)
            outs = [self._fn(V[b], k, window, eps, ms[b])
                    for b in range(V.shape[0])]
            return (np.stack([s for s, _ in outs]),
                    np.stack([d for _, d in outs]))
        return self._fn(V, k, window, eps, mask)


def _rebuild_oracle(V, k, window, eps, mask):
    L = V.T.astype(jnp.float32) @ V.astype(jnp.float32)
    w = window if (window is not None and window < k) else k
    res = dpp_greedy_windowed_rebuild(L, k, window=w, eps=eps, mask=mask)
    return np.asarray(res.indices), np.asarray(res.d_hist)


def _incremental_oracle(V, k, window, eps, mask):
    V = V.astype(jnp.float32)
    if window is not None and window < k:
        res = dpp_greedy_windowed_lowrank(V, k, window=window, eps=eps,
                                          mask=mask)
    else:
        res = dpp_greedy_lowrank(V, k, eps=eps, mask=mask)
    return np.asarray(res.indices), np.asarray(res.d_hist)


# the rebuild derivation regularizes with a 1e-6 jitter, so its d_hist
# carries more noise than the incremental path's exact recurrence
_ORACLES = {
    "rebuild": lambda: GreedyOracle("rebuild", _rebuild_oracle, 2e-3, 1e-4),
    "incremental": lambda: GreedyOracle(
        "incremental", _incremental_oracle, 3e-4, 1e-5
    ),
}


@pytest.fixture(params=["rebuild", "incremental"])
def greedy_oracle(request):
    """The single greedy MAP oracle every backend suite asserts against
    (parametrized over the two independent jnp derivations)."""
    return _ORACLES[request.param]()


def assert_greedy_parity(oracle, sel, dh, V, k, window=None, eps=1e-6,
                         mask=None):
    """Indices must match the oracle index for index; d_hist within the
    oracle derivation's tolerance."""
    ref_sel, ref_dh = oracle(V, k, window=window, eps=eps, mask=mask)
    np.testing.assert_array_equal(np.asarray(sel), ref_sel)
    np.testing.assert_allclose(
        np.asarray(dh), ref_dh, rtol=oracle.dh_rtol, atol=oracle.dh_atol
    )
