"""Observability (repro.obs) suite.

Three contracts:

* **Off means off** — with no session installed every hook is a single
  global read: ``obs.span`` hands back one shared no-op singleton (no
  allocation), the metric hooks return immediately, and the per-call
  cost stays in the nanosecond range.
* **The instruments are correct** — counters/gauges/histograms
  aggregate by sorted label set, the registry refuses kind conflicts,
  the span ring drops oldest-first under pressure, and the Chrome
  ``trace_event`` export round-trips through JSON schema-valid.
* **Telemetry never changes results** — the router produces
  index-for-index identical slates with a session installed, a raising
  ``metrics_hook`` is logged and counted but never kills the pump, and
  the recompile ledger observes what the serving layer claims: zero jit
  cache misses through the warmed router vs at least one per distinct k
  down the per-k serial path.

The CI obs lane re-runs the streaming/router suites with ``REPRO_OBS=1``
(a conftest autouse fixture keeps a session installed throughout) so
every existing differential test doubles as an enabled-path parity test.
"""
import json
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro import obs
from repro.obs import (
    MetricsRegistry,
    NULL_SPAN,
    ObsConfig,
    SpanTracer,
    validate_chrome_trace,
)
from repro.obs.dispatch import record_chunk, record_kernel_dispatch

from tests.test_router import make_request, session


@pytest.fixture
def fresh_obs():
    """A session this test owns outright (torn down after), replacing
    whatever the environment (REPRO_OBS lane) installed."""
    obs.disable()
    s = obs.enable(ObsConfig(enabled=True))
    yield s
    obs.disable()


@pytest.fixture
def no_obs():
    """Guaranteed-disabled hooks for the cheap-when-off tests."""
    obs.disable()
    yield
    obs.disable()


# ---------------------------------------------------------------------------
# Off by default, near-zero when off
# ---------------------------------------------------------------------------


def test_disabled_span_is_one_shared_singleton(no_obs):
    assert not obs.enabled()
    s = obs.span("anything", M=128, k=8)
    assert s is obs.span("something else") is NULL_SPAN
    with s as inner:  # usable as a context manager, records nothing
        assert inner.set(extra=1) is s
    assert obs.tracer() is None and obs.registry() is None
    # metric hooks are plain returns
    obs.inc("c", 2, backend="jnp")
    obs.gauge_set("g", 1.0)
    obs.observe("h", 0.5)


def test_disabled_hooks_are_nanosecond_cheap(no_obs):
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with obs.span("hot"):
            pass
        obs.inc("c")
    per_call = (time.perf_counter() - t0) / (2 * n)
    # a generous ceiling (CI boxes jitter); the real disabled cost is a
    # global read + singleton return, ~100ns
    assert per_call < 20e-6, f"disabled hook cost {per_call * 1e6:.2f}us"


def test_disabled_config_is_a_noop_and_session_scopes(no_obs):
    assert obs.enable(ObsConfig(enabled=False)) is None
    assert not obs.enabled()
    with obs.session(ObsConfig(enabled=True)) as s:
        assert obs.enabled() and s is obs.active()
        s2 = obs.enable(ObsConfig(enabled=True))  # kept, not replaced
        assert s2 is s
    assert not obs.enabled()


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_units():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc(backend="jnp")
    c.inc(2, backend="pallas")
    c.inc(backend="jnp")
    assert c.value(backend="jnp") == 2
    assert c.value(backend="pallas") == 2
    assert c.total() == 4
    assert reg.counter("req_total") is c  # get-or-create

    g = reg.gauge("depth")
    g.set(3)
    g.inc(2)
    assert g.value() == 5

    h = reg.histogram("lat_s")
    for v in (0.001, 0.01, 0.1):
        h.observe(v)
    assert h.count() == 3
    assert h.sum() == pytest.approx(0.111)
    assert h.mean() == pytest.approx(0.037)


def test_registry_rejects_kind_conflicts():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(ValueError, match="x"):
        reg.gauge("x")


def test_snapshot_and_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(3, backend="jnp", chunked="1")
    reg.gauge("depth", "queue depth").set(2.0)
    reg.histogram("lat_s", "latency").observe(0.02)
    snap = reg.snapshot()
    assert snap["counters"]["req_total"] == {"backend=jnp,chunked=1": 3}
    assert snap["gauges"]["depth"] == {"": 2.0}
    cell = snap["histograms"]["lat_s"][""]
    assert cell["count"] == 1 and cell["sum"] == pytest.approx(0.02)
    # snapshot is JSON-serializable as-is (BENCH_<fig>.json embeds it)
    json.loads(json.dumps(snap))

    text = reg.expose()
    assert "# HELP req_total requests" in text
    assert "# TYPE req_total counter" in text
    assert 'req_total{backend="jnp",chunked="1"} 3' in text
    assert "# TYPE lat_s histogram" in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text


# ---------------------------------------------------------------------------
# Span tracer + Chrome export
# ---------------------------------------------------------------------------


def test_span_ring_drops_oldest_and_counts():
    tr = SpanTracer(ring_size=2)
    for i in range(5):
        with tr.span(f"s{i}", i=i):
            pass
    assert tr.total == 5 and tr.dropped == 3 and len(tr) == 2
    names = [s["name"] for s in tr.finished()]
    assert names == ["s3", "s4"]


def test_chrome_export_round_trips_schema_valid(fresh_obs):
    with obs.span("outer", M=64):
        with obs.span("inner"):
            pass
    doc = json.loads(json.dumps(fresh_obs.tracer.export_chrome()))
    assert validate_chrome_trace(doc) is None
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} >= {"outer", "inner"}
    outer = next(e for e in xs if e["name"] == "outer")
    inner = next(e for e in xs if e["name"] == "inner")
    assert outer["args"]["M"] == 64
    # containment: inner nests inside outer on the same track
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1


def test_validate_chrome_trace_flags_violations():
    assert validate_chrome_trace({"not": "a trace"}) is not None
    bad = {"traceEvents": [{"ph": "X", "name": "a"}]}  # no ts/dur/pid/tid
    assert validate_chrome_trace(bad) is not None


# ---------------------------------------------------------------------------
# Dispatch telemetry
# ---------------------------------------------------------------------------


def test_record_hooks_are_noops_without_a_session(no_obs):
    record_kernel_dispatch("tiled", D=8, M=256, state_rows=8,
                           windowed=False, tile_m=128, vmem_bytes=1 << 20)
    record_chunk("jnp", B=2, chunk=4, M=64)  # must not raise


def test_record_kernel_dispatch_counts_modes(fresh_obs):
    reg = fresh_obs.registry
    record_kernel_dispatch("resident", D=8, M=128, state_rows=8,
                           windowed=False, tile_m=128, vmem_bytes=4096)
    record_kernel_dispatch("tiled", D=8, M=4096, state_rows=8,
                           windowed=True, tile_m=512, vmem_bytes=8192)
    c = reg.get("dpp_kernel_dispatch_total")
    assert c.value(mode="resident", windowed="False") == 1
    assert c.value(mode="tiled", windowed="True") == 1
    assert reg.get("dpp_tile_m").value() == 512  # the last dispatch
    assert reg.get("dpp_vmem_bytes_est").value() == 8192


def test_rerank_emits_dispatch_and_eval_counters(fresh_obs):
    rr = session(slots=2, chunk=3, bucket=32, k=6)
    req = make_request(11, 40, k=6)
    rr.rerank(req)  # whole-slate path: dispatch + unchunked step counts
    for c, _ in rr.stream(req):  # chunked path: per-chunk launches
        c.block_until_ready()
    snap = fresh_obs.registry.snapshot()
    assert sum(snap["counters"]["greedy_dispatch_total"].values()) >= 1
    assert sum(snap["counters"]["greedy_chunks_total"].values()) >= 2
    steps = sum(snap["counters"]["greedy_steps_total"].values())
    evals = sum(snap["counters"]["marginal_evals_total"].values())
    assert steps >= 12  # 6 whole-slate + 6 streamed
    assert evals >= steps  # every launched step scores >= 1 candidate


# ---------------------------------------------------------------------------
# Router integration: spans, stats view, hook guard, recompile ledger
# ---------------------------------------------------------------------------


def test_router_parity_and_pump_spans_with_obs_enabled(fresh_obs):
    rr = session(slots=2, chunk=3, bucket=32, k=8)
    reqs = [make_request(1, 40, k=8), make_request(2, 24, k=5),
            make_request(3, 48, k=7, masked=True)]
    expect = [tuple(np.asarray(x) for x in rr.rerank(r)) for r in reqs]
    handles = [rr.submit(r) for r in reqs]
    rr.router.drain()
    for h, (ei, ed) in zip(handles, expect):
        gi, gd = h.result()
        np.testing.assert_array_equal(gi, ei)
        np.testing.assert_allclose(gd, ed, rtol=1e-4, atol=1e-6)

    spans = fresh_obs.tracer.finished()
    counts = {}
    for s in spans:
        counts[s["name"]] = counts.get(s["name"], 0) + 1
    pumps = counts.get("router.pump", 0)
    assert pumps > 0
    for phase in ("evict", "admit", "launch", "materialize"):
        assert counts.get(f"router.pump.{phase}", 0) == pumps
    assert counts.get("router.pump.sync", 0) >= pumps - 1

    st = rr.router.stats  # the registry-backed view keeps its surface
    assert st.completed == 3 and st.slot_occupancy == 0
    assert st.ttfc_count == 3 and st.mean_ttfc > 0


def test_raising_metrics_hook_never_kills_the_pump(fresh_obs, caplog):
    calls = []

    def bad_hook(snap):
        calls.append(snap.completed)
        raise RuntimeError("operator bug")

    from repro.serving import DPPRerankConfig, Reranker, RouterConfig

    cfg = DPPRerankConfig(slate_size=6, shortlist=32, alpha=3.0,
                          chunk_size=3)
    rr = Reranker(cfg, router_config=RouterConfig(
        slots=2, chunk_size=3, max_candidates=32, metrics_hook=bad_hook,
    ))
    reqs = [make_request(7, 32, k=6), make_request(8, 24, k=4)]
    handles = [rr.submit(r) for r in reqs]
    with caplog.at_level("ERROR", logger="repro.serving.router"):
        rr.router.drain()
    assert all(h.done and not h.timed_out for h in handles)
    assert len(calls) > 0  # the hook kept being offered every pump
    assert any("metrics_hook" in r.message for r in caplog.records)
    errs = fresh_obs.registry.get("router_hook_errors_total")
    assert errs.total() == len(calls)


def test_router_zero_misses_vs_serial_per_k_recompiles(fresh_obs):
    """The fig8 gate at test size: the warmed router's measured drive
    shows zero jit cache misses, while per-k serial streaming (k folded
    into the compiled C (M, k) geometry) must miss per distinct k."""
    cm = fresh_obs.compile_monitor
    rr = session(slots=2, chunk=3, bucket=32, k=8, max_queue=16)
    reqs = [make_request(s, 36, k=kk, masked=s % 2 == 0)
            for s, kk in [(21, 8), (22, 5), (23, 7), (24, 4), (25, 6)]]
    warm = [rr.submit(r) for r in reqs[:2]]
    rr.router.drain()
    assert all(h.done for h in warm)
    cm.mark()
    handles = [rr.submit(r) for r in reqs[2:]]
    rr.router.drain()
    assert all(h.done for h in handles)
    assert cm.since_mark() == 0, (
        "router re-jitted after warmup — per-request k/mask leaked into "
        "compiled shapes"
    )

    cm.mark()
    distinct_k = sorted({r.slate_size for r in reqs})
    for k in distinct_k:
        r = reqs[[q.slate_size for q in reqs].index(k)]
        for c, _ in rr.stream(r):
            c.block_until_ready()
    assert cm.since_mark() >= len(distinct_k)
