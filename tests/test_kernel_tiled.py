"""Tiled-vs-resident dpp_greedy kernel parity + TilePolicy dispatch.

The tiled streaming kernels must select the identical slate (d_hist to
~1 ulp) as the resident whole-in-VMEM kernels and the jnp oracle across
tile sizes {M (single tile), M/2, 128}, ragged tails, masks, eps-stop
and windowed eviction — and a config past the old VMEM gate must run
the Pallas path (interpret mode here) instead of falling back to jnp.

The CI tiled-matrix job sweeps extra tile widths through the
``DPP_TILE_M`` env var (appended to the parametrized grid).

The 8-device sharded tiled-local-update parity runs in a subprocess in
the slow lane (same isolation contract as tests/test_distributed.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from conftest import assert_greedy_parity, make_greedy_inputs as make_inputs
from repro.core import GreedySpec, GreedySpecError, greedy_map
from repro.kernels.dpp_greedy import (
    TilePolicy,
    VMEM_BUDGET_BYTES,
    dpp_greedy,
    dpp_greedy_ref,
    tile_vmem_bytes,
    untiled_vmem_bytes,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# extra tile width injected by the CI tiled-matrix job; non-numeric
# values ("auto" in the autotune lane) name a policy mode, not a width
_ENV_TILES = (
    [int(os.environ["DPP_TILE_M"])]
    if os.environ.get("DPP_TILE_M", "").isdigit() else []
)


def _tiles(M):
    """{M (single tile), M/2, 128} + the CI matrix tile, deduplicated."""
    ts = {M, M // 2, 128, *_ENV_TILES}
    return sorted(t for t in ts if t >= 128 and t % 128 == 0)


# ---------------------------------------------------------------------------
# Tiled-vs-resident-vs-oracle parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 6])
@pytest.mark.parametrize("tile", _tiles(512))
def test_tiled_matches_resident_and_ref(window, tile):
    B, D, M, k = 2, 32, 512, 16
    V = make_inputs(B + D + M + k + (window or 0), B, D, M)
    mask = jnp.ones((B, M), bool)
    sel_t, dh_t = dpp_greedy(V, k, window=window, tile_m=tile)
    sel_res, dh_res = dpp_greedy(V, k, window=window)  # resident kernel
    sel_r, dh_r = dpp_greedy_ref(V, mask, k, window=window)
    np.testing.assert_array_equal(np.asarray(sel_t), np.asarray(sel_r))
    np.testing.assert_array_equal(np.asarray(sel_t), np.asarray(sel_res))
    np.testing.assert_allclose(
        np.asarray(dh_t), np.asarray(dh_r), rtol=3e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(dh_t), np.asarray(dh_res), rtol=1e-6, atol=1e-7
    )


@pytest.mark.parametrize("window", [None, 5])
def test_tiled_ragged_tail_and_mask(window):
    """M not a multiple of the tile (padded, masked tail) + a user mask:
    padding can never be selected and the slate matches the oracle."""
    B, D, M, k = 2, 19, 413, 12
    V = make_inputs(17 + (window or 0), B, D, M)
    rng = np.random.default_rng(3)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.3)
    sel_t, dh_t = dpp_greedy(V, k, mask=mask, window=window, tile_m=128)
    sel_r, dh_r = dpp_greedy_ref(V, mask, k, window=window)
    np.testing.assert_array_equal(np.asarray(sel_t), np.asarray(sel_r))
    np.testing.assert_allclose(
        np.asarray(dh_t), np.asarray(dh_r), rtol=3e-4, atol=1e-5
    )
    for b in range(B):
        valid = np.asarray(sel_t[b])
        valid = valid[valid >= 0]
        assert (valid < M).all()
        assert np.asarray(mask[b])[valid].all()


def test_tiled_eps_stop():
    """Rank-deficient kernel: the tiled path stops exactly where the
    oracle stops, and stays stopped across the remaining sweeps."""
    B, D, M, k = 1, 6, 384, 16
    V = make_inputs(13, B, D, M)
    sel_t, dh_t = dpp_greedy(V, k, eps=1e-3, tile_m=128)
    sel_r, dh_r = dpp_greedy_ref(V, jnp.ones((B, M), bool), k, eps=1e-3)
    np.testing.assert_array_equal(np.asarray(sel_t), np.asarray(sel_r))
    assert int((np.asarray(sel_t) >= 0).sum()) <= D + 2


@pytest.mark.parametrize("w", [1, 3])
def test_tiled_windowed_eviction_parity(w):
    """Slates long enough that eviction moves the marginals: the tiled
    windowed kernel pins the same d_hist convention (pre-eviction
    selection marginal) as the jnp windowed path."""
    from repro.core.windowed import dpp_greedy_windowed_lowrank

    B, D, M, k = 1, 8, 256, 16
    V = make_inputs(37, B, D, M, alpha=1.0)
    _, dh_exact = dpp_greedy(V, k, tile_m=128)
    sel_t, dh_t = dpp_greedy(V, k, window=w, tile_m=128)
    assert not np.allclose(
        np.asarray(dh_exact)[0, w:], np.asarray(dh_t)[0, w:], rtol=1e-4
    ), "eviction never changed a marginal — the case is vacuous"
    ref = dpp_greedy_windowed_lowrank(V[0], k, window=w, eps=1e-3)
    np.testing.assert_array_equal(np.asarray(sel_t[0]), np.asarray(ref.indices))
    np.testing.assert_allclose(
        np.asarray(dh_t[0]), np.asarray(ref.d_hist), rtol=3e-4, atol=1e-6
    )
    s = np.asarray(sel_t)[0]
    assert (s >= 0).all() and len(set(s.tolist())) == k  # no eps-stop here


def test_tiled_unbounded_slate():
    """Windowed + tiled: slate length beyond the kernel rank keeps
    selecting with O(w * tile_m) VMEM per grid step."""
    B, D, M, k, w = 1, 12, 256, 40, 6
    V = make_inputs(29, B, D, M, alpha=1.0)
    sel_e, _ = dpp_greedy(V, k, eps=1e-3, tile_m=128)
    sel_w, _ = dpp_greedy(V, k, eps=1e-3, window=w, tile_m=128)
    assert int((np.asarray(sel_e) >= 0).sum()) <= D + 3
    s = np.asarray(sel_w)[0]
    assert (s >= 0).all() and len(set(s.tolist())) == k


@pytest.mark.parametrize("window", [None, 4])
def test_tiled_matches_shared_oracle(greedy_oracle, window):
    """The tiled streaming kernels against the one shared oracle fixture
    (the same ground truth the resident/sharded/streaming suites use)."""
    B, D, M, k = 2, 16, 96, 8
    V = make_inputs(67, B, D, M)
    rng = np.random.default_rng(4)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.25)
    sel, dh = dpp_greedy(V, k, mask=mask, window=window, tile_m=128)
    assert_greedy_parity(greedy_oracle, sel, dh, V, k, window=window,
                         eps=1e-3, mask=mask)


# ---------------------------------------------------------------------------
# Interpret-mode gaps (ROADMAP): the revisited-output running argmax
# under adversarial ties, and the vmap-of-pallas_call batching the
# sharded tiled local update leans on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 3])
@pytest.mark.parametrize("chunked", [False, True])
def test_tiled_running_argmax_adversarial_ties(window, chunked):
    """Every candidate's marginal is *exactly* float-equal to a twin in
    the other tile (the second tile duplicates the first), so every
    step's running argmax across the revisited (1, 1) cells is decided
    purely by tie-breaking — it must keep the earlier (lower-index)
    candidate, matching jnp.argmax over the concatenated axis, on both
    the per-step sweeps and the fused chunk kernels."""
    B, D, M, k = 1, 12, 256, 6  # two 128-tiles; tile 2 = copy of tile 1
    half = make_inputs(71, B, D, M // 2)
    V = jnp.concatenate([half, half], axis=2)
    sel_r, dh_r = dpp_greedy_ref(V, jnp.ones((B, M), bool), k,
                                 window=window)
    if chunked:
        from repro.kernels.dpp_greedy import (
            dpp_greedy_stream_chunk,
            dpp_greedy_stream_init,
        )

        state = dpp_greedy_stream_init(V, k, window=window, tile_m=128)
        sels = []
        for c in (2, 2, 2):
            state, sel, _ = dpp_greedy_stream_chunk(V, state, c, tile_m=128)
            sels.append(np.asarray(sel))
        sel_t = np.concatenate(sels, axis=1)
    else:
        sel_t, _ = dpp_greedy(V, k, window=window, tile_m=128)
    np.testing.assert_array_equal(np.asarray(sel_t), np.asarray(sel_r))
    # the ties were real and broke low: a twin pair stays exactly tied
    # until one member is selected, so a pick from the higher tile is
    # only legitimate when it is the twin of an earlier pick whose
    # eviction repaired its d2 (windowed only) — any other high-tile
    # pick means the running argmax broke a live tie the wrong way
    s = np.asarray(sel_t)[0]
    assert (s >= 0).all()
    prev = set()
    for x in s.tolist():
        if x >= M // 2:
            assert window is not None and (x - M // 2) in prev, (
                f"tie broke toward the higher tile at {x}"
            )
        prev.add(x)
    assert (s[: min(len(s), 2)] < M // 2).all()  # fresh ties broke low


@pytest.mark.parametrize("window", [None, 3])
def test_vmap_of_tiled_update_matches_per_problem(window):
    """The batched sharded path vmaps the per-device SPMD body, so the
    per-step tile kernels run under vmap-of-pallas_call.  Pin that
    batching rule directly: vmapping the shard-local update equals
    running it per problem."""
    from repro.kernels.dpp_greedy.tiled import (
        eviction_coeffs,
        tiled_update_exact,
        tiled_update_windowed,
    )

    B, D, M, k = 3, 8, 256, 5
    rng = np.random.default_rng(73)
    V = make_inputs(73, B, D, M)
    d2 = jnp.sum(V * V, axis=1)
    j = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    dj = jnp.sqrt(jnp.take_along_axis(d2, j[:, None], 1))[:, 0]
    vj = jnp.take_along_axis(V, j[:, None, None], axis=2)[:, :, 0]
    stopped = jnp.zeros((B,), bool)
    base = jnp.zeros((B,), jnp.int32)
    if window is None:
        C = jnp.asarray(rng.normal(size=(B, k, M)), jnp.float32) * 0.1
        cj = jnp.take_along_axis(C, j[:, None, None], axis=2)[:, :, 0]
        fn = lambda Vb, Cb, d2b, vjb, cjb, djb, st, jb, bb: (
            tiled_update_exact(Vb, Cb, d2b, vjb, cjb, djb, st, jb, bb,
                               tile_m=128)
        )
        batched = jax.vmap(fn)(V, C, d2, vj, cj, dj, stopped, j, base)
        single = [fn(V[b], C[b], d2[b], vj[b], cj[b], dj[b], stopped[b],
                     j[b], base[b]) for b in range(B)]
    else:
        w = window
        C = jnp.asarray(rng.normal(size=(B, w, M)), jnp.float32) * 0.1
        win = jnp.asarray(rng.integers(0, M, size=(B, w)), jnp.int32)
        cj = jnp.take_along_axis(C, j[:, None, None], axis=2)[:, :, 0]
        Cw = jnp.take_along_axis(C, jnp.clip(win, 0)[:, None, :], axis=2)
        full = jnp.ones((B,), bool)
        cos, sin, cj_post, d2j = eviction_coeffs(Cw, cj, dj * dj, full, w)
        djp = jnp.sqrt(jnp.maximum(d2j, 1e-12))
        pos = jnp.full((B,), w - 1, jnp.int32)
        fn = lambda Vb, Cb, d2b, vjb, cjb, djb, st, fl, co, si, jb, bb, po: (
            tiled_update_windowed(Vb, Cb, d2b, vjb, cjb, djb, st, fl, co,
                                  si, jb, bb, po, w=w, tile_m=128)
        )
        batched = jax.vmap(fn)(V, C, d2, vj, cj_post, djp, stopped, full,
                               cos, sin, j, base, pos)
        single = [fn(V[b], C[b], d2[b], vj[b], cj_post[b], djp[b],
                     stopped[b], full[b], cos[b], sin[b], j[b], base[b],
                     pos[b]) for b in range(B)]
    for out_b, outs in zip(batched, zip(*single)):
        np.testing.assert_allclose(
            np.asarray(out_b), np.stack([np.asarray(o) for o in outs]),
            rtol=1e-6, atol=1e-7,
        )


# ---------------------------------------------------------------------------
# The acceptance bar: past the old VMEM gate, the kernel path runs
# ---------------------------------------------------------------------------


def test_past_gate_runs_kernel_and_matches_oracle():
    """D=64, M=131072, w=8 exceeds the whole-array VMEM budget — the old
    gate silently fell back to jnp here.  TilePolicy must now dispatch
    the tiled Pallas kernels (interpret mode on CPU) and the slate must
    be identical to the jnp oracle.  k > w so the *windowed* tiled
    kernel (eviction included) is the one exercised past the gate."""
    B, D, M, k, w = 1, 64, 131072, 16, 8
    assert untiled_vmem_bytes(D, M, w) > VMEM_BUDGET_BYTES
    mode, tm = TilePolicy().decide(D, M, w, windowed=True)
    assert mode == "tiled" and tm is not None
    assert tile_vmem_bytes(D, tm, w, windowed=True) <= VMEM_BUDGET_BYTES
    V = make_inputs(19, B, D, M)
    sel_t, dh_t = dpp_greedy(V, k, window=w, eps=1e-6, interpret=True)
    sel_r, dh_r = dpp_greedy_ref(V, jnp.ones((B, M), bool), k, window=w,
                                 eps=1e-6)
    np.testing.assert_array_equal(np.asarray(sel_t), np.asarray(sel_r))
    np.testing.assert_allclose(
        np.asarray(dh_t), np.asarray(dh_r), rtol=3e-4, atol=1e-5
    )


# ---------------------------------------------------------------------------
# TilePolicy / dispatch plumbing
# ---------------------------------------------------------------------------


def test_tile_policy_decides():
    # comfortably in budget -> resident
    assert TilePolicy().decide(64, 4096, 16, False) == ("resident", None)
    # past budget -> tiled with a fitting, lane-aligned tile
    mode, tm = TilePolicy().decide(64, 1 << 20, 16, False)
    assert mode == "tiled" and tm % 128 == 0
    assert tile_vmem_bytes(64, tm, 16, False) <= VMEM_BUDGET_BYTES
    # explicit tile_m forces tiling even when resident would fit
    assert TilePolicy(tile_m=128).decide(16, 256, 4, False) == ("tiled", 128)
    # pathological row count: even one lane tile exceeds the budget
    assert TilePolicy().decide(200_000, 1 << 20, 16, False) == ("jnp", None)


def test_tile_policy_validation():
    with pytest.raises(ValueError, match="tile_m"):
        TilePolicy(tile_m=100)
    with pytest.raises(ValueError, match="tile_m"):
        TilePolicy(tile_m=-128)
    with pytest.raises(ValueError, match="vmem_budget_bytes"):
        TilePolicy(vmem_budget_bytes=0)
    with pytest.raises(ValueError, match="at most one"):
        dpp_greedy(
            jnp.ones((1, 4, 128)), 2, tile_m=128, tile_policy=TilePolicy()
        )


def test_vmem_bytes_shim_removed():
    # The pre-tiling ``vmem_bytes`` name shipped as a DeprecationWarning
    # shim for one release after PR 4; it is gone now everywhere it was
    # re-exported.  ``untiled_vmem_bytes`` is the resident-mode model.
    import importlib

    # (``import ... as pkg`` would grab the ``dpp_greedy`` *function*
    # re-exported by repro.kernels — go through importlib instead)
    pkg = importlib.import_module("repro.kernels.dpp_greedy")
    from repro.kernels.dpp_greedy import ops, tiling

    for mod in (pkg, ops, tiling):
        assert not hasattr(mod, "vmem_bytes")
    assert "vmem_bytes" not in pkg.__all__


def test_greedy_spec_tile_m_validation_and_threading():
    with pytest.raises(GreedySpecError, match="tile_m"):
        GreedySpec(k=4, tile_m=100)
    with pytest.raises(GreedySpecError, match="tile_m"):
        GreedySpec(k=4, backend="jnp", tile_m=128)
    # backend='auto' without a mesh resolves to jnp, which would also
    # silently ignore the tile — rejected at construction
    with pytest.raises(GreedySpecError, match="tile_m"):
        GreedySpec(k=4, tile_m=128)
    V = make_inputs(41, 1, 16, 384)[0]
    ref = greedy_map(GreedySpec(k=8, backend="jnp", eps=1e-6), V=V)
    got = greedy_map(
        GreedySpec(k=8, backend="pallas", eps=1e-6, tile_m=128), V=V
    )
    np.testing.assert_array_equal(np.asarray(ref.indices),
                                  np.asarray(got.indices))


def test_rerank_config_tile_m():
    from repro.serving.reranker import DPPRerankConfig
    from conftest import serve_rerank

    with pytest.raises(ValueError, match="tile_m"):
        DPPRerankConfig(tile_m=100, use_kernel=True)
    with pytest.raises(ValueError, match="tile_m"):
        DPPRerankConfig(tile_m=128)  # jnp backend would ignore it
    rng = np.random.default_rng(43)
    M, D = 400, 16
    scores = jnp.asarray(rng.uniform(size=M), jnp.float32)
    feats = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    feats = feats / jnp.linalg.norm(feats, axis=1, keepdims=True)
    kw = dict(slate_size=10, shortlist=256, eps=1e-6)
    base, _ = serve_rerank(scores, feats, DPPRerankConfig(**kw))
    tiled, _ = serve_rerank(
        scores, feats, DPPRerankConfig(use_kernel=True, tile_m=128, **kw)
    )
    np.testing.assert_array_equal(np.asarray(base), np.asarray(tiled))


# ---------------------------------------------------------------------------
# Sharded local update through the tiled kernel (fast: 1-device mesh)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 4])
def test_sharded_tiled_local_update_one_device(window):
    from repro.core import dpp_greedy_lowrank, dpp_greedy_sharded
    from repro.core.windowed import dpp_greedy_windowed_lowrank
    from repro.distributed.context import make_mesh_compat

    rng = np.random.default_rng(45)
    M, D, k = 300, 24, 12
    V = jnp.asarray(rng.normal(size=(D, M)), jnp.float32) / np.sqrt(D)
    mask = jnp.asarray(rng.uniform(size=M) > 0.3)
    mesh = make_mesh_compat((1,), ("data",))
    if window is None:
        ref = dpp_greedy_lowrank(V, k, eps=1e-6, mask=mask)
    else:
        ref = dpp_greedy_windowed_lowrank(V, k, window=window, eps=1e-6,
                                          mask=mask)
    got = dpp_greedy_sharded(
        V, k, mesh=mesh, window=window, eps=1e-6, mask=mask, tile_m=128
    )
    np.testing.assert_array_equal(np.asarray(ref.indices),
                                  np.asarray(got.indices))
    np.testing.assert_allclose(np.asarray(ref.d_hist), np.asarray(got.d_hist),
                               rtol=1e-6, atol=1e-7)


def test_sharded_tiled_batched_one_device():
    """The batched sharded path vmaps the SPMD body — the tiled Pallas
    pass inside must batch correctly (vmap-of-pallas_call)."""
    from repro.core import dpp_greedy_lowrank_batch, dpp_greedy_sharded
    from repro.distributed.context import make_mesh_compat

    rng = np.random.default_rng(46)
    B, D, M, k = 3, 12, 200, 8
    V = jnp.asarray(rng.normal(size=(B, D, M)), jnp.float32) / np.sqrt(D)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.3)
    mesh = make_mesh_compat((1,), ("data",))
    ref = dpp_greedy_lowrank_batch(V, k, 1e-6, mask)
    got = dpp_greedy_sharded(V, k, mesh=mesh, eps=1e-6, mask=mask, tile_m=128)
    np.testing.assert_array_equal(np.asarray(ref.indices),
                                  np.asarray(got.indices))


# ---------------------------------------------------------------------------
# Sharded tiled local update, 8 devices (subprocess, slow lane)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sharded_tiled_multidevice_parity():
    """On an 8-host-device mesh, the sharded path with tile_m set (every
    device's local update streamed through the tiled Pallas pass) selects
    the identical slate as the single-device low-rank paths — exact and
    windowed, ragged M (padded to P * tile_m), masked, batched."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import numpy as np, jax, jax.numpy as jnp
            from repro.core import (dpp_greedy_sharded, dpp_greedy_lowrank,
                                    dpp_greedy_lowrank_batch)
            from repro.core.windowed import dpp_greedy_windowed_lowrank
            from repro.distributed.context import make_mesh_compat
            assert jax.device_count() == 8
            mesh = make_mesh_compat((8,), ("data",))
            rng = np.random.default_rng(0)
            M, D, k = 3001, 16, 12  # not divisible by 8*128 (padded shards)
            V = jnp.asarray(rng.normal(size=(D, M)), jnp.float32) / np.sqrt(D)
            mask = jnp.asarray(rng.uniform(size=M) > 0.2)
            for window in (None, 1, 5):
                for m in (None, mask):
                    if window is None:
                        ref = dpp_greedy_lowrank(V, k, eps=1e-6, mask=m)
                    else:
                        ref = dpp_greedy_windowed_lowrank(
                            V, k, window=window, eps=1e-6, mask=m)
                    got = dpp_greedy_sharded(
                        V, k, mesh=mesh, window=window, eps=1e-6, mask=m,
                        tile_m=128)
                    np.testing.assert_array_equal(
                        np.asarray(ref.indices), np.asarray(got.indices))
                    np.testing.assert_allclose(
                        np.asarray(ref.d_hist), np.asarray(got.d_hist),
                        rtol=1e-6, atol=1e-7)
            B = 3
            Vb = jnp.asarray(rng.normal(size=(B, D, M)), jnp.float32)
            Vb = Vb / np.sqrt(D)
            mb = jnp.asarray(rng.uniform(size=(B, M)) > 0.3)
            ref = dpp_greedy_lowrank_batch(Vb, 8, 1e-6, mb)
            got = dpp_greedy_sharded(Vb, 8, mesh=mesh, eps=1e-6, mask=mb,
                                     tile_m=128)
            np.testing.assert_array_equal(
                np.asarray(ref.indices), np.asarray(got.indices))
            print("SHARDED-TILED-OK")
        """)],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDED-TILED-OK" in out.stdout
