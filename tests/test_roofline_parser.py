"""Unit tests for the trip-weighted HLO accounting (the roofline's data
source): synthetic HLO snippets with known answers."""
import numpy as np

from repro.roofline.hlo_collectives import (
    analyze_hlo,
    collective_op_counts,
    _shape_bytes,
    _transfer_bytes,
)

HLO = """\
HloModule test, is_scheduled=true

%cond (p: (s32[], f32[8,128])) -> pred[] {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %c = s32[] constant(10)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]{1,0}) parameter(0)
  %x = f32[8,128]{1,0} get-tuple-element(%p), index=1
  %ar = f32[8,128]{1,0} all-reduce(%x), replica_groups=[4,2]<=[8], to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]{1,0}) tuple(%i2, %ar)
}

ENTRY %main (a: f32[8,128], b: f32[128,64]) -> f32[8,64] {
  %a = f32[8,128]{1,0} parameter(0)
  %b = f32[128,64]{1,0} parameter(1)
  %w = (s32[], f32[8,128]{1,0}) while(%init), condition=%cond, body=%body
  %x = f32[8,128]{1,0} get-tuple-element(%w), index=1
  %ag = f32[8,256]{1,0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={1}
  ROOT %dot = f32[8,64]{1,0} dot(%x, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert _shape_bytes("(f32[2,3]{1,0}, bf16[4]{0})") == 24 + 8
    assert _shape_bytes("s32[]") == 4


def test_transfer_model():
    # all-reduce ring: 2 * size * (g-1)/g
    assert _transfer_bytes("all-reduce", 1000, 2) == 1000.0
    assert _transfer_bytes("all-gather", 800, 4) == 600.0
    assert _transfer_bytes("collective-permute", 5, 8) == 5.0
    assert _transfer_bytes("all-reduce", 1000, 1) == 0.0


def test_trip_weighted_walk():
    w = analyze_hlo(HLO)
    # dot flops: 2 * (8*64) * 128, executed once
    assert w["_flops"] == 2 * 8 * 64 * 128
    # all-reduce inside the while body runs 10x (cond constant):
    ar_bytes = 8 * 128 * 4
    expected_ar = 10 * 2 * ar_bytes * (2 - 1) / 2
    np.testing.assert_allclose(w["all-reduce"], expected_ar)
    # all-gather once: out 8*256*4, g=2
    np.testing.assert_allclose(w["all-gather"], 8 * 256 * 4 * 0.5)
    counts = collective_op_counts(HLO)
    assert counts["all-reduce"] == 1 and counts["all-gather"] == 1
