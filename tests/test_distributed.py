"""Distributed-runtime tests.

Multi-device correctness runs in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test process
keeps 1 device, per the dry-run isolation contract).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.elastic import choose_mesh_shape
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    RestartBudget,
    StragglerPolicy,
)

pytestmark = pytest.mark.slow  # subprocess multi-device suites dominate runtime

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_sharded_matches_local():
    """shard_map expert-parallel MoE == single-device MoE bit-for-math."""
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.context import axis_rules, make_mesh_compat, single_pod_rules
        from repro.models.moe import MoEConfig, moe_init, moe_apply
        # capacity_factor high enough that no token drops in either the
        # local (global-capacity) or sharded (per-source-capacity) path —
        # dropping policies legitimately differ at tight capacity.
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff=32, capacity_factor=8.0)
        rng = jax.random.PRNGKey(0)
        p = moe_init(rng, 16, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
        out_local, aux_local = moe_apply(p, x, cfg)  # no mesh
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        with axis_rules(single_pod_rules(), mesh):
            out_sh, aux_sh = jax.jit(lambda p, x: moe_apply(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_sh),
                                   rtol=2e-4, atol=2e-5)
        # aux loss is per-shard averaged in the sharded path (standard
        # micro-batch-level load-balance loss) — same scale, not identical
        assert np.isfinite(float(aux_sh)) and 0.2 < float(aux_sh)/float(aux_local) < 5.0
        print("MOE-OK")
    """)


def test_embedding_bag_sharded_matches_local():
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.context import axis_rules, make_mesh_compat, single_pod_rules
        from repro.models.embedding import EmbeddingSpec, embedding_bag, init_table
        spec = EmbeddingSpec((100, 60, 200), 8, pad_to_multiple=8)
        table = init_table(jax.random.PRNGKey(0), spec)
        rng = np.random.default_rng(0)
        ids = np.stack([rng.integers(0, v, size=(16, 2)) for v in spec.vocab_sizes], 1)
        ids[:, :, 1] = np.where(rng.uniform(size=(16, 3)) < 0.5, -1, ids[:, :, 1])
        ids = jnp.asarray(ids.astype(np.int32))
        ref = embedding_bag(table, ids, spec)  # no mesh -> local
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        with axis_rules(single_pod_rules(), mesh):
            got = jax.jit(lambda t, i: embedding_bag(t, i, spec))(table, ids)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(got), rtol=1e-5, atol=1e-6)
        print("EMB-OK")
    """)


def test_lm_train_step_sharded_matches_single():
    """One SGD-free loss eval: sharded vs single-device (tiny MoE LM)."""
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.context import axis_rules, make_mesh_compat, single_pod_rules
        from repro.models.transformer import TransformerConfig, init_params, train_loss
        from repro.models.moe import MoEConfig
        # aux_loss_coef=0: the aux term is per-shard averaged when sharded
        # (tested separately); here we check the CE path is identical.
        cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                                n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                                chunk_q=16, aux_loss_coef=0.0,
                                moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                              capacity_factor=8.0))
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 64)
        l0 = float(train_loss(params, {"tokens": toks}, cfg))
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        with axis_rules(single_pod_rules(), mesh):
            l1 = float(jax.jit(lambda p, b: train_loss(p, b, cfg))(params, {"tokens": toks}))
        assert abs(l0 - l1) < 5e-3, (l0, l1)
        print("LM-OK")
    """)


def test_train_restart_after_injected_failure(tmp_path):
    """Failure injection + auto-resume: the restart continues training."""
    ckpt = str(tmp_path / "ck")
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "deepfm",
            "--reduced", "--steps", "40", "--batch", "64", "--ckpt-dir", ckpt,
            "--ckpt-every", "10", "--log-every", "100"]
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r1 = subprocess.run(base + ["--fail-at-step", "25"], capture_output=True,
                        text=True, env=env, cwd=REPO, timeout=600)
    assert r1.returncode != 0 and "injected failure" in r1.stderr
    steps = sorted(d for d in os.listdir(ckpt) if d.startswith("step_"))
    assert steps, "no checkpoint committed before failure"
    r2 = subprocess.run(base + ["--resume", "auto"], capture_output=True,
                        text=True, env=env, cwd=REPO, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 20" in r2.stdout
    summary = json.loads(r2.stdout.strip().splitlines()[-1])
    assert summary["steps_run"] == 20  # 40 total - 20 resumed


def test_elastic_mesh_choice():
    assert choose_mesh_shape(512, 16) == (32, 16)
    assert choose_mesh_shape(496, 16) == (31, 16)  # lost a host: DP shrinks
    assert choose_mesh_shape(504, 16) == (31, 16)
    # policy prefers preserving the TP axis over using every survivor
    assert choose_mesh_shape(7, 16) == (1, 4)
    assert choose_mesh_shape(24, 8) == (3, 8)


def test_elastic_reshard_subprocess():
    """Lose 4 of 8 devices -> rebuild mesh -> state is intact."""
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed.elastic import make_elastic_mesh, reshard
        devs = jax.devices()
        mesh1 = make_elastic_mesh(devs, model_pref=4)      # (2, 4)
        x = jnp.arange(64.0).reshape(8, 8)
        x1 = jax.device_put(x, NamedSharding(mesh1, P("data", "model")))
        survivors = devs[:4]                               # pod loses 4 chips
        mesh2 = make_elastic_mesh(survivors, model_pref=4) # (1, 4)
        x2 = reshard(x1, NamedSharding(mesh2, P("data", "model")))
        np.testing.assert_array_equal(np.asarray(x2), np.asarray(x))
        assert mesh2.devices.shape == (1, 4)
        print("ELASTIC-OK")
    """)


def test_heartbeat_monitor():
    t = [0.0]
    hb = HeartbeatMonitor(n_hosts=3, timeout=10.0, clock=lambda: t[0])
    assert hb.dead_hosts() == []
    t[0] = 5.0
    hb.beat(0)
    hb.beat(1)
    t[0] = 12.0  # host 2 last beat at 0 -> dead
    assert hb.dead_hosts() == [2]
    assert hb.alive_hosts() == [0, 1]


def test_straggler_policy():
    sp = StragglerPolicy(factor=2.0, window=8, min_samples=3)
    for step in range(6):
        for h in range(4):
            sp.report(h, 1.0 if h != 3 else 3.5)  # host 3 is 3.5x median
    assert sp.stragglers() == [3]


def test_restart_budget():
    rb = RestartBudget(max_restarts=2, horizon_s=100.0)
    assert rb.record(now=0.0)
    assert rb.record(now=10.0)
    assert not rb.record(now=20.0)  # 3rd within horizon -> crash-loop
    assert rb.record(now=200.0)  # old events expired
