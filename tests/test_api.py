"""The unified serving API (repro.serving.api): Reranker / RerankRequest
dispatch, construction-time request validation, the legacy-shim
deprecation contract, and the streaming prep hoist.

The legacy functions (rerank / rerank_batch / rerank_stream /
sharded_rerank / sharded_rerank_stream) survive one release as
DeprecationWarning shims; every shim is asserted to (a) warn and
(b) return bitwise the session API's result.  The older suites keep
calling the shims directly — their continued passing is the shims'
behavioural coverage.
"""
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.distributed.context import make_mesh_compat
from repro.serving import (
    DPPRerankConfig,
    Reranker,
    RerankRequest,
    rerank,
    rerank_batch,
    rerank_stream,
    sharded_rerank,
    sharded_rerank_stream,
)


def _problem(M, D=8, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    shape = (M, D) if batch is None else (batch, M, D)
    f = rng.normal(size=shape).astype(np.float32)
    f /= np.maximum(np.linalg.norm(f, axis=-1, keepdims=True), 1e-12)
    s = rng.uniform(0.1, 1.0, size=shape[:-1]).astype(np.float32)
    return jnp.asarray(s), jnp.asarray(f)


CFG = DPPRerankConfig(slate_size=8, shortlist=32, alpha=3.0, chunk_size=3)


# ---------------------------------------------------------------------------
# RerankRequest: construction-time validation
# ---------------------------------------------------------------------------


def test_request_validates_at_construction():
    s, f = _problem(40)
    for bad in (
        dict(slate_size=0), dict(slate_size=-2), dict(shortlist=0),
        dict(deadline=0.0), dict(deadline=-1.0),
    ):
        with pytest.raises(ValueError):
            RerankRequest(scores=s, feats=f, **bad)
    with pytest.raises(ValueError, match="scores"):
        RerankRequest(scores=s[None, None], feats=f)
    with pytest.raises(ValueError, match="feats"):
        RerankRequest(scores=s, feats=f[None])  # (1, M, D) needs (B, M)
    with pytest.raises(ValueError, match="mask"):
        RerankRequest(scores=s, feats=f, mask=jnp.ones((2, 40), bool))
    req = RerankRequest(scores=s, feats=f, slate_size=5, rid="x")
    assert not req.batched and req.num_candidates == 40


def test_request_batched_shapes():
    s, f = _problem(30, batch=3)
    assert RerankRequest(scores=s, feats=f).batched
    # shared feats with a batch is fine
    RerankRequest(scores=s, feats=f[0])
    RerankRequest(scores=s, feats=f, mask=jnp.ones((3, 30), bool))
    RerankRequest(scores=s, feats=f[0], mask=jnp.ones((30,), bool))


def test_reranker_rejects_non_config():
    with pytest.raises(TypeError, match="DPPRerankConfig"):
        Reranker({"slate_size": 4})
    with pytest.raises(TypeError, match="RerankRequest"):
        Reranker(CFG).rerank(np.zeros(4))


# ---------------------------------------------------------------------------
# Dispatch parity: the session API serves what the old functions served
# ---------------------------------------------------------------------------


def test_rerank_single_matches_legacy():
    s, f = _problem(60, seed=1)
    m = jnp.asarray(np.arange(60) % 4 != 0)
    rr = Reranker(CFG)
    for mask in (None, m):
        new = rr.rerank(RerankRequest(scores=s, feats=f, mask=mask))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            old = rerank(s, f, CFG, mask=mask)
        np.testing.assert_array_equal(np.asarray(new[0]), np.asarray(old[0]))
        np.testing.assert_array_equal(np.asarray(new[1]), np.asarray(old[1]))


def test_rerank_batched_dispatch_matches_legacy():
    s, f = _problem(50, seed=2, batch=3)
    rr = Reranker(CFG)
    new = rr.rerank(RerankRequest(scores=s, feats=f))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = rerank_batch(s, f, CFG)
    np.testing.assert_array_equal(np.asarray(new[0]), np.asarray(old[0]))
    assert np.asarray(new[0]).shape == (3, CFG.slate_size)


def test_request_side_overrides():
    """Per-request k / shortlist fold into the session config without
    touching the session's own defaults."""
    s, f = _problem(60, seed=3)
    rr = Reranker(CFG)
    out, _ = rr.rerank(RerankRequest(scores=s, feats=f, slate_size=4))
    assert np.asarray(out).shape == (4,)
    exp, _ = rr.rerank(
        RerankRequest(scores=s, feats=f, slate_size=4, shortlist=16)
    )
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        import dataclasses

        old, _ = rerank(
            s, f, dataclasses.replace(CFG, slate_size=4, shortlist=16)
        )
    np.testing.assert_array_equal(np.asarray(exp), np.asarray(old))
    assert rr.cfg.slate_size == 8 and rr.cfg.shortlist == 32


def test_stream_concatenates_to_rerank():
    s, f = _problem(60, seed=4)
    rr = Reranker(CFG)
    req = RerankRequest(scores=s, feats=f)
    whole = np.asarray(rr.rerank(req)[0])
    chunks = [np.asarray(i) for i, _ in rr.stream(req)]
    assert all(len(c) <= CFG.chunk_size for c in chunks)
    np.testing.assert_array_equal(np.concatenate(chunks), whole)


def test_stream_rejects_batched_eagerly():
    s, f = _problem(30, seed=5, batch=2)
    # a plain generator would only raise at the first next(); the session
    # API raises at the call
    with pytest.raises(ValueError, match="single request"):
        Reranker(CFG).stream(RerankRequest(scores=s, feats=f))


def test_stream_prep_is_hoisted(monkeypatch):
    """The O(M) prep — validation, shortlist, state build — runs once at
    the stream() call; generator resumes never re-shortlist."""
    import repro.serving.api as api

    calls = {"n": 0}
    real = api._shortlist_kernel

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(api, "_shortlist_kernel", counting)
    s, f = _problem(60, seed=6)
    gen = Reranker(CFG).stream(RerankRequest(scores=s, feats=f))
    assert calls["n"] == 1  # prep happened at the call, before any next()
    n_chunks = sum(1 for _ in gen)
    assert n_chunks == -(-CFG.slate_size // CFG.chunk_size)
    assert calls["n"] == 1  # and never again on resume


def test_sharded_dispatch_one_device():
    mesh = make_mesh_compat((1,), ("data",))
    cfg = DPPRerankConfig(slate_size=6, shortlist=24, alpha=3.0, mesh=mesh,
                          chunk_size=3)
    s, f = _problem(48, seed=7)
    rr = Reranker(cfg)
    new = rr.rerank(RerankRequest(scores=s, feats=f))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = sharded_rerank(s, f, cfg)
    np.testing.assert_array_equal(np.asarray(new[0]), np.asarray(old[0]))
    streamed = np.concatenate(
        [np.asarray(i) for i, _ in rr.stream(RerankRequest(scores=s, feats=f))]
    )
    np.testing.assert_array_equal(streamed, np.asarray(new[0]))


# ---------------------------------------------------------------------------
# The deprecation contract (ISSUE: shims covered by filterwarnings test)
# ---------------------------------------------------------------------------


@pytest.mark.filterwarnings("error::DeprecationWarning")
def test_every_legacy_entry_point_warns():
    s, f = _problem(40, seed=8)
    sb, fb = _problem(40, seed=8, batch=2)
    mesh = make_mesh_compat((1,), ("data",))
    mcfg = DPPRerankConfig(slate_size=4, shortlist=16, mesh=mesh,
                           chunk_size=2)
    with pytest.raises(DeprecationWarning):
        rerank(s, f, CFG)
    with pytest.raises(DeprecationWarning):
        rerank_batch(sb, fb, CFG)
    with pytest.raises(DeprecationWarning):
        rerank_stream(s, f, CFG)
    with pytest.raises(DeprecationWarning):
        sharded_rerank(s, f, mcfg)
    with pytest.raises(DeprecationWarning):
        sharded_rerank_stream(s, f, mcfg)


def test_legacy_shims_still_serve():
    """The shims delegate, not just warn: results match the session API
    and the stream shim still yields chunks."""
    s, f = _problem(40, seed=9)
    rr = Reranker(CFG)
    exp = np.asarray(rr.rerank(RerankRequest(scores=s, feats=f))[0])
    with pytest.warns(DeprecationWarning):
        got = np.asarray(rerank(s, f, CFG)[0])
    np.testing.assert_array_equal(got, exp)
    with pytest.warns(DeprecationWarning):
        chunks = [np.asarray(i) for i, _ in rerank_stream(s, f, CFG)]
    np.testing.assert_array_equal(np.concatenate(chunks), exp)
