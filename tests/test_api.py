"""The unified serving API (repro.serving.api): Reranker / RerankRequest
dispatch, construction-time request validation, the streaming prep
hoist, and the legacy-shim *removal* pin.

The PR-6 function-per-shape shims (rerank / rerank_batch /
rerank_stream / sharded_rerank / sharded_rerank_stream) served their
one-release DeprecationWarning grace period and are gone;
``test_legacy_shims_are_removed`` pins that they never come back.
Dispatch correctness is asserted against the module-level
implementation bodies (``_rerank_impl`` & co.) and against per-request
self-consistency — the same ground the shim-comparison tests used to
stand on, minus the shims.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

from repro.distributed.context import make_mesh_compat
from repro.serving import DPPRerankConfig, Reranker, RerankRequest
from repro.serving.api import _rerank_impl


def _problem(M, D=8, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    shape = (M, D) if batch is None else (batch, M, D)
    f = rng.normal(size=shape).astype(np.float32)
    f /= np.maximum(np.linalg.norm(f, axis=-1, keepdims=True), 1e-12)
    s = rng.uniform(0.1, 1.0, size=shape[:-1]).astype(np.float32)
    return jnp.asarray(s), jnp.asarray(f)


CFG = DPPRerankConfig(slate_size=8, shortlist=32, alpha=3.0, chunk_size=3)


# ---------------------------------------------------------------------------
# RerankRequest: construction-time validation
# ---------------------------------------------------------------------------


def test_request_validates_at_construction():
    s, f = _problem(40)
    for bad in (
        dict(slate_size=0), dict(slate_size=-2), dict(shortlist=0),
        dict(deadline=0.0), dict(deadline=-1.0),
    ):
        with pytest.raises(ValueError):
            RerankRequest(scores=s, feats=f, **bad)
    with pytest.raises(ValueError, match="scores"):
        RerankRequest(scores=s[None, None], feats=f)
    with pytest.raises(ValueError, match="feats"):
        RerankRequest(scores=s, feats=f[None])  # (1, M, D) needs (B, M)
    with pytest.raises(ValueError, match="mask"):
        RerankRequest(scores=s, feats=f, mask=jnp.ones((2, 40), bool))
    req = RerankRequest(scores=s, feats=f, slate_size=5, rid="x")
    assert not req.batched and req.num_candidates == 40


def test_request_batched_shapes():
    s, f = _problem(30, batch=3)
    assert RerankRequest(scores=s, feats=f).batched
    # shared feats with a batch is fine
    RerankRequest(scores=s, feats=f[0])
    RerankRequest(scores=s, feats=f, mask=jnp.ones((3, 30), bool))
    RerankRequest(scores=s, feats=f[0], mask=jnp.ones((30,), bool))


def test_reranker_rejects_non_config():
    with pytest.raises(TypeError, match="DPPRerankConfig"):
        Reranker({"slate_size": 4})
    with pytest.raises(TypeError, match="RerankRequest"):
        Reranker(CFG).rerank(np.zeros(4))


# ---------------------------------------------------------------------------
# Dispatch parity: the session verbs agree with the implementation
# bodies and with each other
# ---------------------------------------------------------------------------


def test_rerank_single_matches_impl():
    s, f = _problem(60, seed=1)
    m = jnp.asarray(np.arange(60) % 4 != 0)
    rr = Reranker(CFG)
    for mask in (None, m):
        new = rr.rerank(RerankRequest(scores=s, feats=f, mask=mask))
        ref = _rerank_impl(s, f, CFG, mask)
        np.testing.assert_array_equal(np.asarray(new[0]), np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(new[1]), np.asarray(ref[1]))


def test_rerank_batched_dispatch_matches_per_user():
    s, f = _problem(50, seed=2, batch=3)
    rr = Reranker(CFG)
    new = rr.rerank(RerankRequest(scores=s, feats=f))
    assert np.asarray(new[0]).shape == (3, CFG.slate_size)
    for b in range(3):
        one = rr.rerank(RerankRequest(scores=s[b], feats=f[b]))
        np.testing.assert_array_equal(
            np.asarray(new[0][b]), np.asarray(one[0])
        )


def test_request_side_overrides():
    """Per-request k / shortlist fold into the session config without
    touching the session's own defaults."""
    s, f = _problem(60, seed=3)
    rr = Reranker(CFG)
    out, _ = rr.rerank(RerankRequest(scores=s, feats=f, slate_size=4))
    assert np.asarray(out).shape == (4,)
    exp, _ = rr.rerank(
        RerankRequest(scores=s, feats=f, slate_size=4, shortlist=16)
    )
    old, _ = Reranker(
        dataclasses.replace(CFG, slate_size=4, shortlist=16)
    ).rerank(RerankRequest(scores=s, feats=f))
    np.testing.assert_array_equal(np.asarray(exp), np.asarray(old))
    assert rr.cfg.slate_size == 8 and rr.cfg.shortlist == 32


def test_stream_concatenates_to_rerank():
    s, f = _problem(60, seed=4)
    rr = Reranker(CFG)
    req = RerankRequest(scores=s, feats=f)
    whole = np.asarray(rr.rerank(req)[0])
    chunks = [np.asarray(i) for i, _ in rr.stream(req)]
    assert all(len(c) <= CFG.chunk_size for c in chunks)
    np.testing.assert_array_equal(np.concatenate(chunks), whole)


def test_stream_rejects_batched_eagerly():
    s, f = _problem(30, seed=5, batch=2)
    # a plain generator would only raise at the first next(); the session
    # API raises at the call
    with pytest.raises(ValueError, match="single request"):
        Reranker(CFG).stream(RerankRequest(scores=s, feats=f))


def test_stream_prep_is_hoisted(monkeypatch):
    """The O(M) prep — validation, shortlist, state build — runs once at
    the stream() call; generator resumes never re-shortlist."""
    import repro.serving.api as api

    calls = {"n": 0}
    real = api._shortlist_kernel

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(api, "_shortlist_kernel", counting)
    s, f = _problem(60, seed=6)
    gen = Reranker(CFG).stream(RerankRequest(scores=s, feats=f))
    assert calls["n"] == 1  # prep happened at the call, before any next()
    n_chunks = sum(1 for _ in gen)
    assert n_chunks == -(-CFG.slate_size // CFG.chunk_size)
    assert calls["n"] == 1  # and never again on resume


def test_sharded_dispatch_one_device():
    mesh = make_mesh_compat((1,), ("data",))
    cfg = DPPRerankConfig(slate_size=6, shortlist=24, alpha=3.0, mesh=mesh,
                          chunk_size=3)
    s, f = _problem(48, seed=7)
    rr = Reranker(cfg)
    new = rr.rerank(RerankRequest(scores=s, feats=f))
    # on a 1-device mesh the sharded path must select the same global
    # ids as the dense single-device dispatch (continuous scores — the
    # documented tie-break divergence is measure-zero)
    dense = Reranker(dataclasses.replace(cfg, mesh=None)).rerank(
        RerankRequest(scores=s, feats=f)
    )
    np.testing.assert_array_equal(np.asarray(new[0]), np.asarray(dense[0]))
    streamed = np.concatenate(
        [np.asarray(i) for i, _ in rr.stream(RerankRequest(scores=s, feats=f))]
    )
    np.testing.assert_array_equal(streamed, np.asarray(new[0]))


# ---------------------------------------------------------------------------
# The removal pin (ISSUE 8: the PR-6 shims' grace period has elapsed)
# ---------------------------------------------------------------------------


def test_legacy_shims_are_removed():
    """The five PR-6 deprecation shims are gone from every module that
    carried them — and stay gone.  Anything still importing one belongs
    on the session API (``repro.analysis``'s dead-shim rule flags such
    stragglers statically)."""
    import inspect

    import repro.serving as serving
    import repro.serving.reranker as reranker
    import repro.serving.sharded_rerank as sharded

    for mod, names in (
        (serving, ("rerank", "rerank_batch", "rerank_stream",
                   "sharded_rerank", "sharded_rerank_stream")),
        (reranker, ("rerank", "rerank_batch", "rerank_stream",
                    "_deprecated")),
        (sharded, ("sharded_rerank", "sharded_rerank_stream")),
    ):
        for name in names:
            # importing repro.serving.sharded_rerank binds the
            # *submodule* on the package under the same name the old
            # function used — a module attribute is fine, a callable
            # shim is the resurrection this test pins against
            leftover = getattr(mod, name, None)
            assert leftover is None or inspect.ismodule(leftover), (
                f"{mod.__name__}.{name} was removed in PR 8 after its "
                f"one-release deprecation window; use Reranker/"
                f"RerankRequest instead of resurrecting it"
            )
    for name in ("rerank", "rerank_batch", "rerank_stream",
                 "sharded_rerank", "sharded_rerank_stream"):
        assert name not in serving.__all__
    # the internal builders the session API dispatches through remain
    assert hasattr(reranker, "_shortlist_kernel")
    assert hasattr(sharded, "_sharded_kernel")
