"""Sweep tests for fm_interaction and scored_topk Pallas kernels."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.fm_interaction import fm_interaction, fm_interaction_ref
from repro.kernels.scored_topk import scored_topk, scored_topk_ref


@pytest.mark.parametrize("B,F,D", [(8, 4, 8), (64, 39, 16), (130, 26, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fm_interaction_sweep(B, F, D, dtype):
    rng = np.random.default_rng(B + F + D)
    emb = jnp.asarray(rng.normal(size=(B, F, D)), dtype)
    out = fm_interaction(emb, block_b=32, interpret=True)
    ref = fm_interaction_ref(emb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=1e-3)


@pytest.mark.parametrize("M,D,c,bm", [(1024, 16, 8, 256), (4096, 64, 128, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scored_topk_sweep(M, D, c, bm, dtype):
    rng = np.random.default_rng(M + D + c)
    emb = jnp.asarray(rng.normal(size=(M, D)), dtype)
    q = jnp.asarray(rng.normal(size=(D,)), dtype)
    vals, idx = scored_topk(emb, q, c=c, block_m=bm, interpret=True)
    rvals, ridx = scored_topk_ref(emb, q, c)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-5, atol=1e-5)
    # indices must match as *sets* (ties may permute within equal values)
    assert set(np.asarray(idx).tolist()) == set(np.asarray(ridx).tolist())


def test_scored_topk_small_runs_kernel():
    """M < 2 * block_m used to fall back to jnp; the kernel now pads to
    one block and masks the tail to -inf."""
    rng = np.random.default_rng(0)
    emb = jnp.asarray(rng.normal(size=(100, 8)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    vals, idx = scored_topk(emb, q, c=5)
    rvals, ridx = scored_topk_ref(emb, q, 5)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals), rtol=1e-6)
    assert (np.asarray(idx) < 100).all()  # padding never survives


@pytest.mark.parametrize("M,c,bm", [(1000, 8, 256), (130, 64, 128),
                                    (4097, 128, 1024)])
def test_scored_topk_ragged_m(M, c, bm):
    """Regression: M % block_m != 0 runs the kernel (padded, -inf-masked
    tail) instead of the old jnp fallback, and matches the reference."""
    rng = np.random.default_rng(M + c)
    emb = jnp.asarray(rng.normal(size=(M, 16)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
    vals, idx = scored_topk(emb, q, c=c, block_m=bm, interpret=True)
    rvals, ridx = scored_topk_ref(emb, q, c)
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals),
                               rtol=1e-5, atol=1e-5)
    assert set(np.asarray(idx).tolist()) == set(np.asarray(ridx).tolist())
    assert (np.asarray(idx) < M).all()
