"""Shape/dtype sweep of the dpp_greedy Pallas kernel (interpret mode)
against the pure-jnp oracle (inputs and the shared ``greedy_oracle``
fixture come from tests/conftest.py)."""
import numpy as np
import pytest
import jax.numpy as jnp

from conftest import assert_greedy_parity, make_greedy_inputs as make_inputs
from repro.kernels.dpp_greedy import (
    TilePolicy,
    VMEM_BUDGET_BYTES,
    dpp_greedy,
    dpp_greedy_ref,
    untiled_vmem_bytes,
)


@pytest.mark.parametrize("B", [1, 3])
@pytest.mark.parametrize("D,M", [(16, 64), (32, 256), (64, 512)])
@pytest.mark.parametrize("k", [4, 16])
def test_kernel_matches_ref_sweep(B, D, M, k):
    V = make_inputs(B * 7 + D + M + k, B, D, M)
    sel_k, dh_k = dpp_greedy(V, k, interpret=True)
    sel_r, dh_r = dpp_greedy_ref(V, jnp.ones((B, M), bool), k)
    np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))
    np.testing.assert_allclose(np.asarray(dh_k), np.asarray(dh_r), rtol=3e-4, atol=1e-6)


@pytest.mark.parametrize("window", [None, 4])
def test_kernel_matches_shared_oracle(greedy_oracle, window):
    """Both resident kernels (exact + windowed) against the one shared
    oracle fixture — the same ground truth every other backend suite
    asserts against."""
    B, D, M, k = 2, 16, 96, 8
    V = make_inputs(61, B, D, M)
    rng = np.random.default_rng(2)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.25)
    sel, dh = dpp_greedy(V, k, mask=mask, window=window, interpret=True)
    assert_greedy_parity(greedy_oracle, sel, dh, V, k, window=window,
                         eps=1e-3, mask=mask)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    V = make_inputs(3, 2, 16, 128, dtype=dtype)
    sel_k, _ = dpp_greedy(V, 8, interpret=True)
    sel_r, _ = dpp_greedy_ref(V.astype(jnp.float32), jnp.ones((2, 128), bool), 8)
    # bf16 inputs are upcast to f32 inside both paths; selections must agree
    np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))


def test_kernel_mask():
    B, D, M, k = 2, 16, 128, 8
    V = make_inputs(11, B, D, M)
    rng = np.random.default_rng(0)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.3)
    sel_k, _ = dpp_greedy(V, k, mask=mask, interpret=True)
    sel_r, _ = dpp_greedy_ref(V, mask, k)
    np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))
    for b in range(B):
        valid = np.asarray(sel_k[b])
        valid = valid[valid >= 0]
        assert np.asarray(mask[b])[valid].all()


def test_kernel_eps_stop():
    """Rank-deficient: kernel must stop exactly where the oracle stops."""
    B, D, M, k = 1, 6, 128, 16
    V = make_inputs(13, B, D, M)
    sel_k, dh_k = dpp_greedy(V, k, eps=1e-3, interpret=True)
    sel_r, dh_r = dpp_greedy_ref(V, jnp.ones((B, M), bool), k, eps=1e-3)
    np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))
    n = int((np.asarray(sel_k) >= 0).sum())
    assert n <= D + 2


def test_kernel_nonaligned_padding():
    """M, D not multiples of (128, 8): ops.py pads; result unchanged."""
    B, D, M, k = 2, 19, 200, 5
    V = make_inputs(17, B, D, M)
    sel_k, _ = dpp_greedy(V, k, interpret=True)
    sel_r, _ = dpp_greedy_ref(V, jnp.ones((B, M), bool), k)
    np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))


def test_dispatch_past_gate_is_tiled_not_jnp():
    """Huge M no longer falls back to jnp — TilePolicy dispatches the
    tiled streaming kernels; the jnp path needs an explicit force_jnp."""
    B, D, M, k = 1, 8, 4096, 4
    assert untiled_vmem_bytes(64, 1 << 20, 32) > VMEM_BUDGET_BYTES
    mode, tile = TilePolicy().decide(64, 1 << 20, 32, windowed=False)
    assert mode == "tiled" and tile is not None
    V = make_inputs(19, B, D, M)
    sel, _ = dpp_greedy(V, k, force_jnp=True)
    assert int((np.asarray(sel) >= 0).sum()) == k


# ---------------------------------------------------------------------------
# Sliding-window kernel mode (C shrinks to a (w, M) VMEM ring; N unbounded)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B", [1, 2])
@pytest.mark.parametrize("D,M,k,w", [(16, 64, 16, 4), (32, 256, 24, 6), (16, 128, 40, 1)])
def test_kernel_windowed_matches_ref(B, D, M, k, w):
    V = make_inputs(B * 5 + D + M + k + w, B, D, M)
    sel_k, dh_k = dpp_greedy(V, k, interpret=True, window=w)
    sel_r, dh_r = dpp_greedy_ref(V, jnp.ones((B, M), bool), k, window=w)
    np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))
    np.testing.assert_allclose(np.asarray(dh_k), np.asarray(dh_r), rtol=3e-4, atol=1e-5)


def test_kernel_windowed_full_window_is_exact():
    """window >= k dispatches to the exact whole-slate kernel."""
    B, D, M, k = 2, 16, 128, 8
    V = make_inputs(23, B, D, M)
    sel_w, _ = dpp_greedy(V, k, interpret=True, window=k)
    sel_e, _ = dpp_greedy(V, k, interpret=True)
    np.testing.assert_array_equal(np.asarray(sel_w), np.asarray(sel_e))


def test_kernel_windowed_unbounded_slate():
    """Slate length beyond the kernel rank: exact eps-stops, windowed
    keeps selecting with O(w M) VMEM state."""
    B, D, M, k, w = 1, 12, 128, 40, 6
    V = make_inputs(29, B, D, M, alpha=1.0)
    sel_e, _ = dpp_greedy(V, k, eps=1e-3, interpret=True)
    sel_w, _ = dpp_greedy(V, k, eps=1e-3, interpret=True, window=w)
    assert int((np.asarray(sel_e) >= 0).sum()) <= D + 3
    s = np.asarray(sel_w)[0]
    assert (s >= 0).all()
    assert len(set(s.tolist())) == k


def test_kernel_windowed_mask_and_padding():
    """Non-aligned M/D + mask through the windowed kernel path."""
    B, D, M, k, w = 2, 19, 200, 18, 5
    V = make_inputs(31, B, D, M)
    rng = np.random.default_rng(1)
    mask = jnp.asarray(rng.uniform(size=(B, M)) > 0.3)
    sel_k, _ = dpp_greedy(V, k, mask=mask, interpret=True, window=w)
    sel_r, _ = dpp_greedy_ref(V, mask, k, window=w)
    np.testing.assert_array_equal(np.asarray(sel_k), np.asarray(sel_r))
    for b in range(B):
        valid = np.asarray(sel_k[b])
        valid = valid[valid >= 0]
        assert np.asarray(mask[b])[valid].all()


def test_kernel_windowed_d_hist_parity_under_eviction():
    """Pins down the windowed d_hist convention against the jnp path on
    slates where eviction changes d2[j].

    Both the kernel and ``dpp_greedy_windowed_lowrank`` record the
    *pre-eviction* marginal ``dj`` (the value the argmax selected on)
    in d_hist, while the row append divides by the *post-eviction*
    ``djp`` — the two differ whenever the evicted pick was correlated
    with j, so equality here is meaningful, not vacuous.
    """
    from repro.core.windowed import dpp_greedy_windowed_lowrank

    B, D, M, k, w = 1, 8, 64, 16, 3
    V = make_inputs(37, B, D, M, alpha=1.0)
    # eviction must actually move the marginals: the same slate scored
    # with a full window differs from the windowed run past step w
    _, dh_exact = dpp_greedy(V, k, interpret=True)
    sel_k, dh_k = dpp_greedy(V, k, interpret=True, window=w)
    assert not np.allclose(
        np.asarray(dh_exact)[0, w:], np.asarray(dh_k)[0, w:], rtol=1e-4
    ), "eviction never changed a marginal — the case is vacuous"
    ref = dpp_greedy_windowed_lowrank(V[0], k, window=w, eps=1e-3)
    np.testing.assert_array_equal(np.asarray(sel_k[0]), np.asarray(ref.indices))
    np.testing.assert_allclose(
        np.asarray(dh_k[0]), np.asarray(ref.d_hist), rtol=3e-4, atol=1e-6
    )
    # d_hist is the selection-time marginal: reselecting each pick against
    # the pre-eviction window reproduces it (kernel side, spot check)
    assert np.asarray(dh_k[0]).min() > 0  # no eps-stop in this regime


def test_kernel_windowed_vmem_budget_uses_window():
    """Resident-mode accounting scales with w, not k: a long slate over
    a big M stays on the resident kernel only because the windowed
    state is (w, M) — the full kernel's (k, M) state dispatches tiled."""
    D, M, k, w = 32, 8192, 512, 8
    assert untiled_vmem_bytes(D, M, k) > VMEM_BUDGET_BYTES
    assert untiled_vmem_bytes(D, M, w) < VMEM_BUDGET_BYTES
    assert TilePolicy().decide(D, M, k, windowed=False)[0] == "tiled"
    assert TilePolicy().decide(D, M, w, windowed=True) == ("resident", None)
