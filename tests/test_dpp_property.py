"""Property-based tests (hypothesis) for the system's core invariants.

hypothesis is a dev-only dependency (declared in pyproject's ``dev``
extra and installed in CI); environments without it skip cleanly
instead of erroring at collection.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    build_kernel_dense_raw,
    dpp_greedy_dense,
    greedy_avg_select,
    greedy_map_naive,
    log_det_objective,
    mmr_select,
    normalize_columns,
    similarity_from_features,
    slate_diversity,
)


def _problem(seed, M, D):
    rng = np.random.default_rng(seed)
    r = rng.uniform(0.1, 1.0, size=M)
    F = normalize_columns(jnp.asarray(rng.normal(size=(D, M))))
    S = similarity_from_features(F)
    L = build_kernel_dense_raw(jnp.asarray(r), S)
    return r, np.asarray(S), L


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    M=st.integers(8, 48),
    D=st.integers(4, 32),
    k=st.integers(1, 8),
)
def test_fast_greedy_matches_naive(seed, M, D, k):
    """Algorithm 1 == eq.-(8) greedy for arbitrary PSD kernels."""
    _, _, L = _problem(seed, M, D)
    fast = dpp_greedy_dense(L, k, eps=1e-3)
    naive_idx, naive_gain = greedy_map_naive(np.asarray(L), k, eps=1e-3)
    n = int(fast.n_selected)
    # selections agree on the prefix both algorithms accepted
    m = min(n, int((naive_idx >= 0).sum()))
    assert m >= 1 or D < 1
    np.testing.assert_array_equal(np.asarray(fast.indices[:m]), naive_idx[:m])


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    M=st.integers(8, 64),
    k=st.integers(1, 10),
)
def test_dhist_positive_nonincreasing(seed, M, k):
    """Thm 4.1 invariant for arbitrary problems."""
    _, _, L = _problem(seed, M, max(k, 12))
    res = dpp_greedy_dense(L, k, eps=1e-6)
    d = np.asarray(res.d_hist)[: int(res.n_selected)]
    assert (d > 0).all()
    assert (np.diff(d) <= 1e-4).all()


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    M=st.integers(10, 64),
    k=st.integers(2, 8),
)
def test_selection_is_valid_subset(seed, M, k):
    """No duplicates, all within range, padding only at the tail."""
    _, _, L = _problem(seed, M, 16)
    res = dpp_greedy_dense(L, k)
    sel = np.asarray(res.indices)
    valid = sel[sel >= 0]
    assert len(set(valid.tolist())) == len(valid)
    assert ((valid >= 0) & (valid < M)).all()
    n = int(res.n_selected)
    assert (sel[:n] >= 0).all() and (sel[n:] == -1).all()


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    M=st.integers(10, 40),
    k=st.integers(2, 8),
    theta=st.floats(0.0, 1.0),
)
def test_baselines_valid_selections(seed, M, k, theta):
    r, S, _ = _problem(seed, M, 16)
    for fn in (mmr_select, greedy_avg_select):
        sel = np.asarray(fn(jnp.asarray(r), jnp.asarray(S), k, theta))
        assert len(set(sel.tolist())) == k
        assert ((sel >= 0) & (sel < M)).all()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), M=st.integers(12, 48))
def test_diversity_metric_bounds(seed, M):
    """min <= median <= ~max; all within [0, 2] for cosine similarity."""
    _, S, _ = _problem(seed, M, 8)
    rng = np.random.default_rng(seed)
    sel = rng.choice(M, size=6, replace=False)
    m = slate_diversity(sel, S)
    assert 0.0 <= m["min"] <= m["median"] <= 2.0
    assert m["min"] <= m["avg"] <= 2.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_greedy_objective_dominates_random(seed):
    """Greedy MAP log-det >= random subsets of the same size (high prob)."""
    _, _, L = _problem(seed, 40, 32)
    L64 = np.asarray(L, np.float64)
    res = dpp_greedy_dense(L, 6)
    ours = log_det_objective(L64, np.asarray(res.indices))
    rng = np.random.default_rng(seed)
    rand_best = max(
        log_det_objective(L64, rng.choice(40, size=6, replace=False))
        for _ in range(20)
    )
    # greedy has a (1/k!)^2 guarantee vs the optimum; random subsets should
    # essentially never beat it on these scales
    assert ours >= rand_best - 0.5
