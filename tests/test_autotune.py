"""Measured kernel-geometry autotuner: keying, bucketing, lookup
ladder, precedence, and the real sweep.

Property tests (M-bucketing monotone, key normalization, nearest-bucket
never over-budget) run under hypothesis when it is installed and over a
seeded deterministic sample otherwise — the invariants are checked
either way, the library only widens the search.

The telemetry assertions use a scoped obs session; everything else runs
with observability disabled (the recording hooks must be no-ops there).
"""
import json
import os
import random

import pytest

import repro.obs as obs
from repro.core import GreedySpec, GreedySpecError
from repro.kernels.dpp_greedy import (
    TilePolicy,
    VMEM_BUDGET_BYTES,
    bucket_m,
    cache_key,
    lookup_tile,
    run_sweep,
    tile_vmem_bytes,
)
from repro.kernels.dpp_greedy.autotune import (
    AutotuneCache,
    SweepCase,
    active_cache_path,
    candidate_tiles,
    default_cache_path,
    device_fingerprint,
)
from repro.kernels.dpp_greedy.ops import _resolve_tile_policy
from repro.kernels.dpp_greedy.tiling import (
    LANE,
    MAX_AUTO_TILE,
    validate_tile_m,
)
from repro.serving.reranker import DPPRerankConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container without hypothesis: seeded sample below
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed"
)


def _seed_cache(path, entries, device=None):
    """Write a cache at ``path`` holding ``entries`` (dicts of put()
    kwargs) for ``device`` (default: this process's real fingerprint,
    so lookup_tile can actually hit)."""
    cache = AutotuneCache(str(path), {})
    device = device or device_fingerprint()
    for e in entries:
        cache.put(interpret=True, best_us=1.0,
                  candidates={e["tile_m"]: 1.0}, device=device, **e)
    cache.save()
    return cache


# ---------------------------------------------------------------------------
# M-bucketing properties
# ---------------------------------------------------------------------------


def _check_bucket(M):
    b = bucket_m(M)
    assert b >= max(M, LANE)
    assert b & (b - 1) == 0, f"bucket {b} not a power of two"
    assert b % LANE == 0
    # tight: the next smaller power of two would not cover M
    assert b == LANE or b // 2 < max(M, LANE)


def _check_bucket_monotone(M1, M2):
    lo, hi = sorted((M1, M2))
    assert bucket_m(lo) <= bucket_m(hi)


def test_bucket_m_properties_seeded():
    rng = random.Random(0)
    sample = [1, 2, 127, 128, 129, 255, 256, 4095, 4096, 65536, 65537]
    sample += [rng.randrange(1, 1 << 22) for _ in range(500)]
    for M in sample:
        _check_bucket(M)
    for _ in range(500):
        _check_bucket_monotone(rng.randrange(1, 1 << 22),
                               rng.randrange(1, 1 << 22))


def test_bucket_m_rejects_nonpositive():
    for M in (0, -1, -128):
        with pytest.raises(ValueError, match="M must be"):
            bucket_m(M)


if HAVE_HYPOTHESIS:

    @needs_hypothesis
    @settings(max_examples=300, deadline=None)
    @given(st.integers(min_value=1, max_value=1 << 24))
    def test_bucket_m_properties_hypothesis(M):
        _check_bucket(M)

    @needs_hypothesis
    @settings(max_examples=300, deadline=None)
    @given(st.integers(min_value=1, max_value=1 << 24),
           st.integers(min_value=1, max_value=1 << 24))
    def test_bucket_m_monotone_hypothesis(M1, M2):
        _check_bucket_monotone(M1, M2)


# ---------------------------------------------------------------------------
# Key normalization properties
# ---------------------------------------------------------------------------


def _check_key_normalization(dk, plat, backend):
    base = cache_key(dk, plat, backend, 64, 1024, 8, False, False)
    # case/whitespace-insensitive on the free-text device fields
    assert base == cache_key(f"  {str(dk).upper()}  ", plat, backend,
                             64, 1024, 8, False, False)
    # exactly 8 fields regardless of what the device strings contain —
    # a "|" inside a field must not shift the structured fields
    assert base.count("|") == 7


def _check_key_injective(a, b):
    """Distinct structured fields -> distinct keys."""
    ka = cache_key("dev", "cpu", "cpu", *a)
    kb = cache_key("dev", "cpu", "cpu", *b)
    if a != b:
        assert ka != kb
    else:
        assert ka == kb


def test_cache_key_normalization_seeded():
    for dk in ("TPU v4", " tpu  v4 ", "NVIDIA A100-SXM4|80GB", "cpu"):
        _check_key_normalization(dk, "tpu", "tpu")
    # the pipe is sanitized out of fields, so these collapse to one key
    assert cache_key("a|b", "cpu", "cpu", 8, 128, 8, True, True) == \
        cache_key("a-b", "cpu", "cpu", 8, 128, 8, True, True)
    rng = random.Random(1)
    dims = lambda: (rng.choice((8, 64, 256)), rng.choice((128, 1024, 65536)),
                    rng.choice((8, 16)), rng.random() < 0.5,
                    rng.random() < 0.5)
    for _ in range(300):
        _check_key_injective(dims(), dims())


if HAVE_HYPOTHESIS:

    _field = st.text(min_size=1, max_size=20)
    _geom = st.tuples(st.integers(1, 512), st.integers(128, 1 << 20),
                      st.integers(1, 64), st.booleans(), st.booleans())

    @needs_hypothesis
    @settings(max_examples=200, deadline=None)
    @given(_field, _field, _field)
    def test_cache_key_normalization_hypothesis(dk, plat, backend):
        _check_key_normalization(dk, plat, backend)

    @needs_hypothesis
    @settings(max_examples=200, deadline=None)
    @given(_geom, _geom)
    def test_cache_key_injective_hypothesis(a, b):
        _check_key_injective(a, b)


# ---------------------------------------------------------------------------
# Lookup ladder: hits, nearest bucket, fallbacks
# ---------------------------------------------------------------------------


def test_lookup_exact_hit(tmp_path):
    path = tmp_path / "cache.json"
    _seed_cache(path, [dict(D=16, M_bucket=4096, state_rows=8,
                            windowed=False, chunked=False, tile_m=128)])
    tm = lookup_tile(D=16, M=4000, state_rows=8, windowed=False,
                     chunked=False, path=str(path))
    assert tm == 128


def test_lookup_nearest_bucket(tmp_path):
    path = tmp_path / "cache.json"
    _seed_cache(path, [
        dict(D=16, M_bucket=1024, state_rows=8, windowed=False,
             chunked=False, tile_m=128),
        dict(D=16, M_bucket=65536, state_rows=8, windowed=False,
             chunked=False, tile_m=512),
    ])
    # M=3000 buckets to 4096: no exact entry; 1024 is closer in log2
    # (2 octaves) than 65536 (4 octaves)
    tm = lookup_tile(D=16, M=3000, state_rows=8, windowed=False,
                     chunked=False, path=str(path))
    assert tm == 128
    # M=40000 buckets to 65536: exact hit on the other entry
    tm = lookup_tile(D=16, M=40000, state_rows=8, windowed=False,
                     chunked=False, path=str(path))
    assert tm == 512


def test_lookup_misses_fall_back_to_none(tmp_path):
    # missing file
    assert lookup_tile(D=16, M=4096, state_rows=8, windowed=False,
                       chunked=False,
                       path=str(tmp_path / "absent.json")) is None
    # corrupted JSON
    bad = tmp_path / "bad.json"
    bad.write_text("{not json", encoding="utf-8")
    assert lookup_tile(D=16, M=4096, state_rows=8, windowed=False,
                       chunked=False, path=str(bad)) is None
    # foreign schema
    foreign = tmp_path / "foreign.json"
    foreign.write_text(json.dumps({"schema": 99, "entries": {}}),
                       encoding="utf-8")
    assert lookup_tile(D=16, M=4096, state_rows=8, windowed=False,
                       chunked=False, path=str(foreign)) is None
    # entry for a different device never matches this process
    other = tmp_path / "other.json"
    _seed_cache(other, [dict(D=16, M_bucket=4096, state_rows=8,
                             windowed=False, chunked=False, tile_m=128)],
                device=("some-other-accelerator", "tpu", "tpu"))
    assert lookup_tile(D=16, M=4096, state_rows=8, windowed=False,
                       chunked=False, path=str(other)) is None


def test_lookup_revalidates_stale_entries(tmp_path):
    """A hand-edited entry degrades to a miss, never a bad launch."""
    path = tmp_path / "cache.json"
    cache = _seed_cache(path, [dict(D=256, M_bucket=65536, state_rows=128,
                                    windowed=False, chunked=True,
                                    tile_m=MAX_AUTO_TILE)])
    key = next(iter(cache.entries))
    # the model says this tile overflows the budget for this geometry
    assert tile_vmem_bytes(256, MAX_AUTO_TILE, 128, False,
                           True) > VMEM_BUDGET_BYTES
    assert lookup_tile(D=256, M=65536, state_rows=128, windowed=False,
                       chunked=True, path=str(path)) is None
    # non-LANE tile (hand-edited) likewise
    cache.entries[key]["tile_m"] = 100
    cache.entries[key]["D"] = 16
    cache.entries[key]["state_rows"] = 8
    cache.save()
    assert lookup_tile(D=16, M=65536, state_rows=8, windowed=False,
                       chunked=True, path=str(path)) is None


def _check_bucket_lookup_safe(path, D, M, R, windowed, chunked):
    tm = lookup_tile(D=D, M=M, state_rows=R, windowed=windowed,
                     chunked=chunked, path=str(path))
    if tm is not None:
        assert tm % LANE == 0 and LANE <= tm <= MAX_AUTO_TILE
        assert tile_vmem_bytes(D, tm, R, windowed, chunked) \
            <= VMEM_BUDGET_BYTES


def test_nearest_bucket_never_over_budget_seeded(tmp_path):
    """Whatever mix of sane and hand-mangled entries the cache holds,
    a lookup returns an in-budget LANE tile or None — never anything
    the VMEM model rejects."""
    rng = random.Random(2)
    path = tmp_path / "cache.json"
    cache = AutotuneCache(str(path), {})
    device = device_fingerprint()
    for i in range(40):
        D = rng.choice((8, 16, 64, 256))
        entry = dict(
            D=D, M_bucket=1 << rng.randrange(7, 18),
            state_rows=rng.choice((8, 16, 128)),
            windowed=rng.random() < 0.5, chunked=rng.random() < 0.5,
            tile_m=rng.choice((100, 128, 512, 4096, MAX_AUTO_TILE,
                               2 * MAX_AUTO_TILE)),
        )
        cache.put(interpret=True, best_us=1.0,
                  candidates={entry["tile_m"]: 1.0}, device=device, **entry)
    cache.save()
    for _ in range(200):
        _check_bucket_lookup_safe(
            path, rng.choice((8, 16, 64, 256)), rng.randrange(1, 1 << 18),
            rng.choice((8, 16, 128)), rng.random() < 0.5,
            rng.random() < 0.5)


def test_candidate_tiles_prefiltered_by_model():
    """Sweep candidates are exactly the in-budget pow2 LANE multiples,
    so the tuner cannot persist an over-budget geometry to begin with."""
    for (D, R, windowed, chunked) in [(16, 8, False, False),
                                      (64, 16, True, True),
                                      (256, 128, False, True)]:
        tiles = candidate_tiles(D, R, windowed, chunked, 1 << 16)
        for t in tiles:
            assert t % LANE == 0 and t & (t - 1) == 0
            assert tile_vmem_bytes(D, t, R, windowed, chunked) \
                <= VMEM_BUDGET_BYTES
    # limit keeps the widest N
    assert candidate_tiles(16, 8, False, False, 1 << 12, limit=2) == \
        candidate_tiles(16, 8, False, False, 1 << 12)[-2:]


# ---------------------------------------------------------------------------
# decide(): the full auto ladder end-to-end
# ---------------------------------------------------------------------------


def test_decide_auto_prefers_cache_then_model(tmp_path, monkeypatch):
    """With a small budget, D=16/M=4096/R=8 is past resident; the model
    picks 256 but a cached measurement of 128 must win — and with the
    cache gone, the model's 256 is the fallback."""
    budget = 1 << 17
    policy = TilePolicy(tile_m="auto", vmem_budget_bytes=budget)
    assert policy.auto_tile(16, 8, False, False) == 256

    path = tmp_path / "cache.json"
    _seed_cache(path, [dict(D=16, M_bucket=4096, state_rows=8,
                            windowed=False, chunked=False, tile_m=128)])
    monkeypatch.setenv("DPP_AUTOTUNE_CACHE", str(path))
    assert policy.decide(16, 4096, 8, False, False) == ("tiled", 128)

    monkeypatch.setenv("DPP_AUTOTUNE_CACHE", str(tmp_path / "absent.json"))
    assert policy.decide(16, 4096, 8, False, False) == ("tiled", 256)

    # resident-when-it-fits is unchanged by auto mode
    assert policy.decide(16, 512, 8, False, False) == ("resident", None)


def test_decide_auto_records_telemetry(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    _seed_cache(path, [dict(D=16, M_bucket=4096, state_rows=8,
                            windowed=False, chunked=False, tile_m=128)])
    monkeypatch.setenv("DPP_AUTOTUNE_CACHE", str(path))
    policy = TilePolicy(tile_m="auto", vmem_budget_bytes=1 << 17)
    with obs.session(obs.ObsConfig(enabled=True)):
        reg = obs.registry()
        policy.decide(16, 4096, 8, False, False)   # exact hit
        policy.decide(16, 9000, 8, False, False)   # bucket 16384 -> nearest
        monkeypatch.setenv("DPP_AUTOTUNE_CACHE",
                           str(tmp_path / "absent.json"))
        policy.decide(16, 4096, 8, False, False)       # miss -> model
        hits = reg.counter("autotune_cache_hits_total")
        misses = reg.counter("autotune_cache_misses_total")
        assert hits.value(kind="exact") == 1
        assert hits.value(kind="bucket") == 1
        assert misses.value(reason="empty") == 1
        assert reg.gauge("autotune_tile_m").value() == 128


# ---------------------------------------------------------------------------
# tile_m precedence (env > explicit > auto > model; policy bypasses)
# ---------------------------------------------------------------------------


def test_precedence_env_beats_explicit(monkeypatch):
    monkeypatch.setenv("DPP_TILE_M", "256")
    assert _resolve_tile_policy(384, None).tile_m == 256
    assert _resolve_tile_policy("auto", None).tile_m == 256
    assert _resolve_tile_policy(None, None).tile_m == 256


def test_precedence_env_auto(monkeypatch):
    monkeypatch.setenv("DPP_TILE_M", "auto")
    assert _resolve_tile_policy(None, None).tile_m == "auto"
    assert _resolve_tile_policy(384, None).tile_m == "auto"


def test_precedence_policy_object_bypasses_env(monkeypatch):
    monkeypatch.setenv("DPP_TILE_M", "256")
    policy = TilePolicy(tile_m=384)
    assert _resolve_tile_policy(None, policy) is policy


def test_precedence_without_env(monkeypatch):
    monkeypatch.delenv("DPP_TILE_M", raising=False)
    assert _resolve_tile_policy(384, None).tile_m == 384
    assert _resolve_tile_policy("auto", None).tile_m == "auto"
    assert _resolve_tile_policy(None, None).tile_m is None


def test_precedence_rejects_both_knobs():
    with pytest.raises(ValueError, match="at most one"):
        _resolve_tile_policy(128, TilePolicy())


def test_env_garbage_fails_loudly(monkeypatch):
    monkeypatch.setenv("DPP_TILE_M", "fast")
    with pytest.raises(ValueError, match="DPP_TILE_M"):
        _resolve_tile_policy(None, None)
    monkeypatch.setenv("DPP_TILE_M", "100")  # not a LANE multiple
    with pytest.raises(ValueError, match="tile_m"):
        _resolve_tile_policy(None, None)


def test_precedence_override_telemetry(monkeypatch):
    monkeypatch.setenv("DPP_TILE_M", "256")
    with obs.session(obs.ObsConfig(enabled=True)):
        reg = obs.registry()
        _resolve_tile_policy(384, None)
        _resolve_tile_policy("auto", None)
        over = reg.counter("dpp_tile_override_total")
        assert over.value(winner="env", lost="explicit") == 1
        assert over.value(winner="env", lost="auto") == 1
        assert reg.counter("dpp_tile_source_total").value(source="env") == 2


# ---------------------------------------------------------------------------
# "auto" validation across the config surfaces
# ---------------------------------------------------------------------------


def test_validate_tile_m_auto_gating():
    validate_tile_m("auto", allow_auto=True)
    with pytest.raises(ValueError, match="single-device Pallas dispatch"):
        validate_tile_m("auto")
    for bad in ("fast", 100, True, 0, -128):
        with pytest.raises(ValueError, match="tile_m"):
            validate_tile_m(bad, allow_auto=True)


def test_greedy_spec_auto_needs_pallas_backend():
    GreedySpec(k=4, backend="pallas", tile_m="auto")  # fine
    with pytest.raises(GreedySpecError, match="autotune cache"):
        GreedySpec(k=4, backend="jnp", tile_m="auto")
    with pytest.raises(GreedySpecError, match="autotune cache"):
        GreedySpec(k=4, backend="auto", tile_m="auto")


def test_rerank_config_auto_needs_kernel():
    DPPRerankConfig(use_kernel=True, tile_m="auto")  # fine
    with pytest.raises(ValueError, match="use_kernel"):
        DPPRerankConfig(tile_m="auto")


# ---------------------------------------------------------------------------
# The real sweep (tiny geometry) and cache hygiene
# ---------------------------------------------------------------------------


def test_run_sweep_writes_validating_cache(tmp_path):
    path = str(tmp_path / "cache.json")
    cases = [SweepCase("step_exact", D=16, M=256, state_rows=8)]
    results, out = run_sweep(cases, trials=1, limit=1, path=path)
    assert out == path and len(results) == 1
    r = results[0]
    assert r["tile_m"] % LANE == 0 and r["best_us"] > 0

    # the persisted winner round-trips through the lookup ladder
    assert lookup_tile(D=16, M=256, state_rows=8, windowed=False,
                       chunked=False, path=path) == r["tile_m"]

    # and passes the repro.analysis cache validator clean
    from repro.analysis.kernels import check_autotune_cache
    findings, summary = check_autotune_cache(path)
    assert findings == []
    assert summary["entries"] == summary["checked"] == 1

    # a second sweep merges (same key overwritten, file still valid)
    run_sweep(cases, trials=1, limit=1, path=path)
    doc = json.loads(open(path, encoding="utf-8").read())
    assert len(doc["entries"]) == 1


def test_run_sweep_replaces_corrupt_cache(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{not json", encoding="utf-8")
    cases = [SweepCase("step_exact", D=16, M=256, state_rows=8)]
    results, _ = run_sweep(cases, trials=1, limit=1, path=str(path))
    assert len(results) == 1
    doc = json.loads(path.read_text(encoding="utf-8"))
    assert doc["schema"] == 1 and len(doc["entries"]) == 1


def test_cache_paths(monkeypatch, tmp_path):
    monkeypatch.setenv("DPP_AUTOTUNE_CACHE", str(tmp_path / "c.json"))
    assert active_cache_path() == str(tmp_path / "c.json")
    monkeypatch.delenv("DPP_AUTOTUNE_CACHE", raising=False)
    assert active_cache_path() == default_cache_path()
    monkeypatch.setenv("XDG_CACHE_HOME", str(tmp_path / "xdg"))
    assert default_cache_path() == str(
        tmp_path / "xdg" / "repro" / "dpp_autotune.json")


def test_sweep_case_rejects_unknown_family():
    with pytest.raises(ValueError, match="unknown family"):
        SweepCase("warp_drive", D=16, M=256, state_rows=8)
