"""§Perf profile correctness: the optimized sharding profiles must
compute the same math as the single-device reference (subprocess with 8
virtual devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # subprocess multi-device suites dominate runtime

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_fsdp_ep_rules_match_reference_loss():
    """LM loss under fsdp_ep (seq-sharded activations, ZeRO-3 params,
    EP experts) == unsharded reference."""
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.context import axis_rules, fsdp_ep_rules, make_mesh_compat
        from repro.models.transformer import TransformerConfig, init_params, train_loss
        from repro.models.moe import MoEConfig
        cfg = TransformerConfig(name="t", n_layers=2, d_model=32, n_heads=4,
                                n_kv_heads=2, d_ff=64, vocab=64, dtype=jnp.float32,
                                chunk_q=8, aux_loss_coef=0.0,
                                moe=MoEConfig(n_experts=8, top_k=2, d_ff=32,
                                              capacity_factor=8.0))
        params = init_params(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 64)
        l0 = float(train_loss(params, {"tokens": toks}, cfg))
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        rules = dict(fsdp_ep_rules(False))
        with axis_rules(rules, mesh):
            l1 = float(jax.jit(lambda p, b: train_loss(p, b, cfg))(params, {"tokens": toks}))
        assert abs(l0 - l1) < 5e-3, (l0, l1)
        print("FSDP-EP-OK")
    """)


def test_a2a_recsys_profile_matches_reference_loss():
    run_subprocess("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.context import axis_rules, make_mesh_compat, recsys_a2a_rules
        from repro.models import recsys
        from repro.data import recsys_batches
        cfg = recsys.RecsysConfig(
            name="t", vocab_sizes=(50, 30, 80, 20), embed_dim=8,
            interaction="fm", mlp_dims=(16,), dtype=jnp.float32,
            emb_mode="alltoall")
        params = recsys.init_params(jax.random.PRNGKey(0), cfg)
        b = next(recsys_batches(cfg.vocab_sizes, batch=32, seed=0))
        ids = jnp.asarray(b["ids"]); y = jnp.asarray(b["labels"])
        ref_cfg = recsys.RecsysConfig(**{**cfg.__dict__, "emb_mode": "psum"})
        l0 = float(recsys.bce_loss(params, {"ids": ids, "labels": y}, ref_cfg))
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        with axis_rules(recsys_a2a_rules(False), mesh):
            l1 = float(jax.jit(lambda p: recsys.bce_loss(p, {"ids": ids, "labels": y}, cfg))(params))
        assert abs(l0 - l1) < 1e-4, (l0, l1)
        print("A2A-PROFILE-OK")
    """)
