"""Optimizer, compression, checkpointing, data-pipeline tests."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (
    Checkpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    compress_int8,
    cosine_warmup,
    decompress_int8,
    ef_compress_grads,
    ef_init,
)
from repro.data import lm_batches, recsys_batches


def test_adamw_matches_reference_math():
    """One AdamW step vs a hand-rolled numpy reference."""
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      grad_clip_norm=None)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    st = adamw_init(p)
    p2, st2, _ = adamw_update(p, g, st, cfg)
    gn = np.asarray([0.5, 0.5, -1.0])
    m = 0.1 * gn
    v = 0.01 * gn * gn
    mh, vh = m / (1 - 0.9), v / (1 - 0.99)
    ref = np.asarray([1.0, -2.0, 3.0]) - 0.1 * (mh / (np.sqrt(vh) + 1e-8)
                                                + 0.01 * np.asarray([1.0, -2.0, 3.0]))
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-6)
    assert int(st2["step"]) == 1


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, grad_clip_norm=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros(4)}
    g = {"w": jnp.full(4, 100.0)}
    _, _, metrics = adamw_update(p, g, adamw_init(p), cfg)
    assert float(metrics["grad_norm"]) > 100.0  # reported pre-clip


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    p = {"w": jnp.asarray([5.0, -3.0])}
    st = adamw_init(p)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        g = {"w": 2 * (p["w"] - target)}
        p, st, _ = adamw_update(p, g, st, cfg)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target), atol=0.05)


def test_int8_roundtrip_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = compress_int8(x)
    err = np.abs(np.asarray(decompress_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-7


def test_error_feedback_telescopes():
    """Sum of EF-compressed grads ~ sum of true grads (bias cancels)."""
    rng = np.random.default_rng(1)
    grads = [{"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
             for _ in range(50)]
    ef = ef_init(grads[0])
    total_c = np.zeros(64, np.float32)
    total_t = np.zeros(64, np.float32)
    for g in grads:
        cg, ef = ef_compress_grads(g, ef)
        total_c += np.asarray(cg["w"])
        total_t += np.asarray(g["w"])
    resid = np.abs(np.asarray(ef.residual["w"]))
    # telescoping: compressed sum = true sum - final residual
    np.testing.assert_allclose(total_c, total_t - np.asarray(ef.residual["w"]), rtol=1e-4, atol=1e-4)
    assert resid.max() < 0.1  # residual stays bounded (no divergence)


def test_cosine_warmup_shape():
    s = cosine_warmup(jnp.asarray(0), warmup=10, total=100)
    assert float(s) == 0.0
    s_mid = cosine_warmup(jnp.asarray(10), warmup=10, total=100)
    assert abs(float(s_mid) - 1.0) < 1e-6
    s_end = cosine_warmup(jnp.asarray(100), warmup=10, total=100)
    assert float(s_end) <= 0.11


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.asarray(np.arange(6, dtype=np.float32).reshape(2, 3)),
        "b": {"c": jnp.asarray(np.ones((4,), np.float32), jnp.bfloat16),
              "d": jnp.asarray(7, jnp.int32)},
    }
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 5, tree)
    assert latest_step(d) == 5
    skel = jax.tree.map(lambda a: jnp.zeros_like(a), tree)
    step, restored = restore_checkpoint(d, skel)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_async_and_gc(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = Checkpointer(d, keep=2)
    tree = {"w": jnp.zeros((8,))}
    for s in [1, 2, 3, 4]:
        ck.save_async(s, jax.tree.map(lambda a: a + s, tree))
    ck.wait()
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d) if x.startswith("step_"))
    assert steps == [3, 4]
    _, restored = restore_checkpoint(d, tree)
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"w": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(d, {"wrong": jnp.zeros(3)})


def test_data_determinism():
    a = next(lm_batches(100, 4, 16, seed=3))["tokens"]
    b = next(lm_batches(100, 4, 16, seed=3))["tokens"]
    np.testing.assert_array_equal(a, b)
    ra = next(recsys_batches((10, 20), 8, seed=5))
    rb = next(recsys_batches((10, 20), 8, seed=5))
    np.testing.assert_array_equal(ra["ids"], rb["ids"])
    np.testing.assert_array_equal(ra["labels"], rb["labels"])
