"""Session-aware incremental rerank (repro.serving.session) suite.

The core guarantee is differential: a session's conditioned next chunk
— after any interleaving of scroll events, pool extends and score
refreshes — matches, index for index, an independently-derived
from-scratch conditional greedy over the session's current pool and
shown history (``ref_next_picks``: per pick, a fresh float64 Cholesky
of the window's Gram plus a full candidate solve).  The device state is
delta-updated in O(w * dM); the reference recomputes everything — so
agreement proves both the resume path and the two delta primitives.

Around it: LRU eviction is transparent (an evicted session rebuilds
bit-compatibly and keeps matching a never-evicted control), hypothesis
drives random scroll/extend/rescore interleavings, and the serving-seam
regressions ride along — slot-state dtype threading (f64 router
parity), construction-time shared-M validation, and the stream
generator's post-eps-stop dead chunk dispatches.
"""
import dataclasses
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import obs
from repro.core import map_relevance
from repro.core.dispatch import GreedySpec
from repro.core.streaming import (
    greedy_init,
    greedy_slot_state,
    greedy_slots_init,
    greedy_state_extend,
    state_splice,
)
from repro.serving import (
    DPPRerankConfig,
    Reranker,
    RerankRequest,
    RouterConfig,
    SessionConfig,
)

BACKENDS = ["jnp", "pallas"]


def _cfg(backend="jnp", k=8, window=3, shortlist=32, chunk=3, eps=1e-3):
    return DPPRerankConfig(
        slate_size=k, shortlist=shortlist, alpha=3.0, window=window,
        use_kernel=(backend == "pallas"), chunk_size=chunk, eps=eps,
    )


def _request(seed, M, D=8, masked=False):
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(M, D)).astype(np.float32)
    f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    s = rng.uniform(0.1, 1.0, size=M).astype(np.float32)
    mask = None
    if masked:
        m = np.ones(M, bool)
        m[rng.choice(M, size=M // 4, replace=False)] = False
        mask = jnp.asarray(m)
    return RerankRequest(scores=jnp.asarray(s), feats=jnp.asarray(f),
                         mask=mask)


def _delta(seed, dm, D=8):
    """Extend payload: normalized feats (dm, D) + uniform scores."""
    rng = np.random.default_rng(seed)
    f = rng.normal(size=(dm, D)).astype(np.float32)
    f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    s = rng.uniform(0.1, 1.0, size=dm).astype(np.float32)
    return s, f


def ref_next_picks(Vf, shown, dead, n, w, eps):
    """From-scratch conditional greedy, independently derived.

    Per pick: Cholesky the last-``w`` shown items' Gram (float64) and
    solve every pool column against it — the O(k * w^2 * M) derivation
    the session's O(n * w * M) resume must match.  Returns (pool
    columns, sqrt-gains) with the same ``d2 <= eps^2`` stop gate the
    incremental path latches on.
    """
    Vf = np.asarray(Vf, np.float64)
    L = Vf.T @ Vf
    shown = list(shown)
    dead = np.asarray(dead, bool).copy()
    picks, gains = [], []
    for _ in range(n):
        win = shown[-w:]
        if win:
            F = np.linalg.cholesky(L[np.ix_(win, win)])
            Ci = np.linalg.solve(F, L[np.asarray(win), :])
            d2 = np.diag(L) - np.sum(Ci * Ci, axis=0)
        else:
            d2 = np.diag(L).copy()
        d2[dead] = -np.inf
        j = int(np.argmax(d2))
        if not d2[j] > eps * eps:
            break
        picks.append(j)
        gains.append(np.sqrt(d2[j]))
        shown.append(j)
        dead[j] = True
    return np.asarray(picks, np.int64), np.asarray(gains)


def check_next_chunk(sess, n):
    """Pull a chunk and assert it matches the from-scratch reference
    over the session's (authoritative, host-mirrored) pool + history."""
    Vf = sess._Vh.copy()
    shown = list(sess._shown)
    dead = sess._dead.copy()
    ids, gains = sess.next_chunk(n)
    cols, ref_g = ref_next_picks(Vf, shown, dead, n, sess.w, sess.cfg.eps)
    np.testing.assert_array_equal(np.asarray(ids), sess._gid[cols])
    np.testing.assert_allclose(np.asarray(gains), ref_g,
                               rtol=3e-4, atol=1e-5)
    return ids


@pytest.fixture
def fresh_obs():
    obs.disable()
    s = obs.enable(obs.ObsConfig(enabled=True))
    yield s
    obs.disable()


# ---------------------------------------------------------------------------
# Resume: session chunks == Reranker.stream, never replaying
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("window", [3, 5])
def test_session_chunks_match_stream(backend, window):
    cfg = _cfg(backend, k=8, window=window)
    req = _request(7, 40)
    ref_i, ref_d = [], []
    for c, d in Reranker(cfg).stream(req):
        ref_i.append(np.asarray(c))
        ref_d.append(np.asarray(d))
    ref_i, ref_d = np.concatenate(ref_i), np.concatenate(ref_d)

    sess = Reranker(cfg).session(req)
    got_i, got_d = [], []
    for n in (3, 3, 2):
        ids, gains = sess.next_chunk(n)
        got_i.append(np.asarray(ids))
        got_d.append(np.asarray(gains))
    np.testing.assert_array_equal(np.concatenate(got_i), ref_i)
    np.testing.assert_allclose(np.concatenate(got_d), ref_d,
                               rtol=1e-5, atol=1e-6)
    assert list(sess.shown) == list(ref_i)


@pytest.mark.parametrize("backend", BACKENDS)
def test_session_resume_matches_reference(backend):
    sess = Reranker(_cfg(backend)).session(_request(11, 36, masked=True))
    for n in (2, 3, 3):
        check_next_chunk(sess, n)


# ---------------------------------------------------------------------------
# Delta-updates: extend / rescore condition the next chunk correctly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_extend_conditions_next_chunk(backend):
    sess = Reranker(_cfg(backend)).session(_request(3, 24))
    check_next_chunk(sess, 3)
    s, f = _delta(101, 6)
    gids = sess.extend(s, f)
    # fresh global ids, dense above the request's candidate count
    np.testing.assert_array_equal(gids, np.arange(24, 30))
    check_next_chunk(sess, 3)  # may (and should be free to) pick new ids
    check_next_chunk(sess, 2)


@pytest.mark.parametrize("backend", BACKENDS)
def test_extend_with_mask(backend):
    sess = Reranker(_cfg(backend)).session(_request(5, 24))
    check_next_chunk(sess, 3)
    s, f = _delta(55, 5)
    mask = np.array([True, False, True, True, False])
    gids = sess.extend(s, f, mask=mask)
    ids = check_next_chunk(sess, 4)
    assert not ({int(gids[1]), int(gids[4])} & set(int(i) for i in ids))


@pytest.mark.parametrize("backend", BACKENDS)
def test_rescore_conditions_next_chunk(backend):
    sess = Reranker(_cfg(backend)).session(_request(9, 28))
    shown_before = list(check_next_chunk(sess, 3))
    # refresh a mix of shown and unshown ids: shown columns must keep
    # their exact old state (history is never rewritten), unshown ones
    # re-enter the running with their new relevance
    ids = np.asarray([shown_before[0], *sess._gid[10:14]], np.int64)
    rng = np.random.default_rng(77)
    sess.rescore(ids, rng.uniform(0.5, 1.0, size=ids.size).astype(np.float32))
    assert list(sess.shown) == shown_before
    check_next_chunk(sess, 3)
    check_next_chunk(sess, 2)


@pytest.mark.parametrize("backend", BACKENDS)
def test_extend_revives_eps_stopped_session(backend):
    # rank-2 pool: every candidate lives in a 2D feature subspace, so
    # the third conditioned gain collapses below eps and the session
    # latches stopped mid-chunk...
    rng = np.random.default_rng(13)
    basis = np.linalg.qr(rng.normal(size=(8, 2)))[0]
    coef = rng.normal(size=(16, 2)).astype(np.float32)
    f = (coef @ basis.T).astype(np.float32)
    f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    s = rng.uniform(0.1, 1.0, size=16).astype(np.float32)
    sess = Reranker(_cfg(backend)).session(
        RerankRequest(scores=jnp.asarray(s), feats=jnp.asarray(f))
    )
    ids, gains = sess.next_chunk(3)
    assert len(ids) == 2 and len(gains) == 2
    # ...stopped sessions answer from the host, empty, no device work
    ids2, _ = sess.next_chunk(3)
    assert ids2.size == 0
    # an extend with full-rank candidates revives it, conditioned on
    # the two shown items
    sd, fd = _delta(99, 4)
    sess.extend(sd, fd)
    ids3 = check_next_chunk(sess, 3)
    assert ids3.size == 3


def test_hypothesis_interleavings():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    op = st.one_of(
        st.tuples(st.just("chunk"), st.integers(1, 3)),
        st.tuples(st.just("extend"), st.integers(1, 4)),
        st.tuples(st.just("rescore"), st.integers(1, 5)),
    )

    @hyp.settings(max_examples=12, deadline=None)
    @hyp.given(ops=st.lists(op, min_size=3, max_size=8),
               seed=st.integers(0, 2**20))
    def run(ops, seed):
        rr = Reranker(_cfg("jnp"),
                      session_config=SessionConfig(capacity=80))
        sess = rr.session(_request(seed % 997, 24))
        rng = np.random.default_rng(seed)
        for i, (kind, arg) in enumerate(ops):
            if kind == "chunk":
                check_next_chunk(sess, arg)
            elif kind == "extend":
                s, f = _delta(seed + i, arg)
                sess.extend(s, f)
            else:
                live = sess._gid[sess._gid >= 0]
                ids = rng.choice(live, size=min(arg, live.size),
                                 replace=False)
                sess.rescore(ids, rng.uniform(
                    0.1, 1.0, size=ids.size).astype(np.float32))
        check_next_chunk(sess, 2)

    run()


# ---------------------------------------------------------------------------
# LRU store: eviction is transparent, budget is respected
# ---------------------------------------------------------------------------


def test_eviction_rebuild_matches_never_evicted_control():
    reqA, reqB = _request(21, 32), _request(22, 32)
    # budget of 1 byte: whichever session is being served evicts every
    # other resident one
    rr = Reranker(_cfg("jnp"),
                  session_config=SessionConfig(budget_bytes=1))
    ctl = Reranker(_cfg("jnp")).session(reqA)

    sa = rr.session(reqA, sid="a")
    ia1, da1 = sa.next_chunk(3)
    sb = rr.session(reqB, sid="b")  # creating b evicts a
    assert not sa.resident and sb.resident
    sb.next_chunk(3)

    # the evicted session rebuilds transparently and keeps matching a
    # control that was never evicted — across a later extend too
    ic1, dc1 = ctl.next_chunk(3)
    np.testing.assert_array_equal(ia1, ic1)
    ia2, da2 = sa.next_chunk(3)
    ic2, dc2 = ctl.next_chunk(3)
    np.testing.assert_array_equal(ia2, ic2)
    np.testing.assert_allclose(da2, dc2, rtol=1e-5, atol=1e-6)
    assert not sb.resident  # serving a evicted b right back

    s, f = _delta(42, 5)
    sa.extend(s, f)
    ctl.extend(s, f)
    ia3, _ = sa.next_chunk(2)
    ic3, _ = ctl.next_chunk(2)
    np.testing.assert_array_equal(ia3, ic3)
    assert rr.sessions.resident_bytes() == sa._resident_bytes


def test_eviction_emits_metrics(fresh_obs):
    rr = Reranker(_cfg("jnp"),
                  session_config=SessionConfig(budget_bytes=1))
    sa = rr.session(_request(31, 24), sid="a")
    sa.next_chunk(2)
    rr.session(_request(32, 24), sid="b").next_chunk(2)
    sa.next_chunk(2)  # touch the evicted session: rebuild delta
    snap = fresh_obs.registry.snapshot()
    assert sum(snap["counters"]["session_evictions_total"].values()) >= 1
    assert sum(snap["counters"]["session_deltas_total"].values()) >= 1
    assert "session_resident_bytes" in snap["gauges"]


def test_store_close_and_sid_bookkeeping():
    rr = Reranker(_cfg("jnp"))
    req = _request(41, 24)
    sess = rr.session(req, sid="u1")
    # resuming by sid returns the same live session, ignoring req
    assert rr.session(_request(42, 24), sid="u1") is sess
    with pytest.raises(ValueError, match="already exists"):
        rr.sessions.create(req, sid="u1")
    a, b = rr.session(_request(43, 24)), rr.session(_request(44, 24))
    assert a.sid != b.sid and len(rr.sessions) == 3
    rr.sessions.close("u1")
    assert "u1" not in rr.sessions and len(rr.sessions) == 2


# ---------------------------------------------------------------------------
# Pointed seams: configs and payloads that cannot work say why
# ---------------------------------------------------------------------------


def test_session_requires_windowed_config():
    for bad in (_cfg("jnp", window=None), _cfg("jnp", k=8, window=8)):
        with pytest.raises(ValueError, match="windowed config"):
            Reranker(bad).session(_request(1, 24))


def test_session_rejects_sharded_pools():
    cfg = dataclasses.replace(_cfg("jnp"), mesh=object())
    with pytest.raises(NotImplementedError, match="sharded"):
        Reranker(cfg).session(_request(1, 24))


def test_session_rejects_user_batches():
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.uniform(size=(2, 24)).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(24, 8)).astype(np.float32))
    with pytest.raises(ValueError, match="one session per user"):
        Reranker(_cfg("jnp")).session(RerankRequest(scores=s, feats=f))


def test_extend_capacity_exhausted():
    rr = Reranker(_cfg("jnp"), session_config=SessionConfig(capacity=1))
    sess = rr.session(_request(2, 24))  # cap clamps up to the shortlist
    s, f = _delta(1, 2)
    with pytest.raises(ValueError, match="pool exhausted"):
        sess.extend(s, f)


def test_rescore_unknown_id():
    sess = Reranker(_cfg("jnp")).session(_request(3, 24))
    with pytest.raises(ValueError, match="unknown global id"):
        sess.rescore(np.asarray([10**6]), np.asarray([0.5], np.float32))


def test_delta_update_requires_windowed_state():
    spec = GreedySpec(k=4, backend="jnp")  # exact Algorithm 1
    V = jnp.asarray(np.random.default_rng(0).normal(size=(8, 16)),
                    jnp.float32)
    st = greedy_init(spec, V=V)
    with pytest.raises(ValueError, match="windowed state"):
        greedy_state_extend(spec, st, V, 0, V[:, :2])


# ---------------------------------------------------------------------------
# Seam regression: slot-state dtype threading (router, non-f32 models)
# ---------------------------------------------------------------------------


def test_slot_primitives_thread_dtype():
    spec = GreedySpec(k=4, window=2, backend="jnp")
    st, Vs = greedy_slots_init(spec, 2, 8, 32, dtype=jnp.bfloat16)
    assert Vs.dtype == jnp.bfloat16
    assert st.C.dtype == st.d2.dtype == jnp.bfloat16
    V = jnp.asarray(np.random.default_rng(5).normal(size=(8, 32)),
                    jnp.bfloat16)
    single = greedy_slot_state(spec, V, dtype=jnp.bfloat16)
    assert single.C.dtype == jnp.bfloat16
    spliced = state_splice(st, single, 0)
    assert spliced.C.dtype == jnp.bfloat16  # no silent f32 upcast


def test_router_f64_parity():
    if not jax.config.jax_enable_x64:
        jax.config.update("jax_enable_x64", True)
        restore = True
    else:
        restore = False
    try:
        rng = np.random.default_rng(8)
        f = rng.normal(size=(40, 8))
        f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-12)
        s = rng.uniform(0.1, 1.0, size=40)
        req = RerankRequest(scores=jnp.asarray(s, jnp.float64),
                            feats=jnp.asarray(f, jnp.float64))
        rr = Reranker(
            DPPRerankConfig(slate_size=6, shortlist=32, alpha=3.0,
                            chunk_size=3),
            router_config=RouterConfig(slots=2, chunk_size=3,
                                       max_candidates=32),
        )
        ei, ed = (np.asarray(x) for x in rr.rerank(req))
        h = rr.submit(req)
        rr.router.drain()
        gi, gd = h.result()
        np.testing.assert_array_equal(gi, ei)
        np.testing.assert_allclose(gd, ed, rtol=1e-9, atol=1e-12)
    finally:
        if restore:
            jax.config.update("jax_enable_x64", False)


def test_router_rejects_mixed_precision():
    rr = Reranker(
        DPPRerankConfig(slate_size=6, shortlist=32, alpha=3.0,
                        chunk_size=3),
        router_config=RouterConfig(slots=2, chunk_size=3,
                                   max_candidates=32),
    )
    rng = np.random.default_rng(9)
    f32 = rng.normal(size=(24, 8)).astype(np.float32)
    s32 = rng.uniform(0.1, 1.0, size=24).astype(np.float32)
    rr.submit(RerankRequest(scores=s32, feats=f32))
    with pytest.raises(ValueError, match="one router serves one model"):
        rr.submit(RerankRequest(scores=s32.astype(np.float64),
                                feats=f32.astype(np.float64)))


# ---------------------------------------------------------------------------
# Seam regression: construction-time shared-M validation
# ---------------------------------------------------------------------------


def test_request_rejects_disagreeing_m():
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.uniform(size=40).astype(np.float32))
    f = jnp.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    with pytest.raises(ValueError, match="candidate count"):
        RerankRequest(scores=s, feats=f[:30])
    with pytest.raises(ValueError, match="candidate count"):
        RerankRequest(scores=s, feats=f, mask=jnp.ones((30,), bool))
    # batched: B must agree too
    sb = jnp.stack([s, s])
    with pytest.raises(ValueError, match="user batch"):
        RerankRequest(scores=sb, feats=jnp.stack([f, f, f]))
    with pytest.raises(ValueError, match="user batch"):
        RerankRequest(scores=sb, feats=f, mask=jnp.ones((3, 40), bool))
    # the good shapes still construct
    RerankRequest(scores=s, feats=f, mask=jnp.ones((40,), bool))
    RerankRequest(scores=sb, feats=f)


# ---------------------------------------------------------------------------
# Seam regression: stream stops dispatching after the eps-stop latch
# ---------------------------------------------------------------------------


def test_stream_stops_dispatching_after_eps_stop(fresh_obs):
    # rank-2 pool again: the slate eps-stops on pick 3 of 8, strictly
    # inside the first chunk — the generator must not launch the
    # remaining ceil(8/3) - 1 dead chunks
    rng = np.random.default_rng(17)
    basis = np.linalg.qr(rng.normal(size=(8, 2)))[0]
    f = (rng.normal(size=(16, 2)) @ basis.T).astype(np.float32)
    f /= np.maximum(np.linalg.norm(f, axis=1, keepdims=True), 1e-12)
    s = rng.uniform(0.1, 1.0, size=16).astype(np.float32)
    req = RerankRequest(scores=jnp.asarray(s), feats=jnp.asarray(f))

    cfg = DPPRerankConfig(slate_size=8, shortlist=16, alpha=3.0,
                          chunk_size=3)
    chunks = [np.asarray(c) for c, _ in Reranker(cfg).stream(req)]
    assert len(chunks) == 1  # stopped chunk yielded, then no more
    assert (chunks[0] >= 0).sum() == 2
    snap = fresh_obs.registry.snapshot()
    assert sum(snap["counters"]["greedy_chunks_total"].values()) == 1
