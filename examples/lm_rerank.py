"""LM-scored slate diversification: use a (reduced) transformer encoder's
final hidden states as item embeddings, score candidates against a query
context, and Div-DPP-diversify the slate — the LM-family integration of
the paper's technique (DESIGN.md §5).

  PYTHONPATH=src python examples/lm_rerank.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import slate_diversity, top_n_select
from repro.models import transformer as tfm
from repro.serving import DPPRerankConfig, Reranker, RerankRequest

cfg = get_arch("qwen1.5-4b").reduced()
params = tfm.init_params(jax.random.PRNGKey(0), cfg)

# "items" = token sequences; embedding = mean-pooled final hidden state
M, S = 256, 16
rng = np.random.default_rng(0)
items = jnp.asarray(rng.integers(0, cfg.vocab, size=(M, S)), jnp.int32)
hidden, _, _ = tfm.forward_hidden(params, items, cfg)
emb = np.array(hidden.mean(axis=1), np.float32)
emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-9)

query = emb[0]  # a context vector
scores = emb @ query

rr = Reranker(DPPRerankConfig(slate_size=10, shortlist=64, alpha=4.0))
slate, _ = rr.rerank(
    RerankRequest(scores=jnp.asarray(scores), feats=jnp.asarray(emb))
)
slate = np.asarray(slate)
Ssim = emb @ emb.T
print("DPP slate:", slate.tolist())
print("DPP diversity:", slate_diversity(slate, Ssim))
top = top_n_select(scores, 10)
print("Top slate:", top.tolist())
print("Top diversity:", slate_diversity(top, Ssim))
