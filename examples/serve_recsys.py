"""End-to-end serving driver: CTR scoring + Div-DPP slate diversification
over batched requests (the paper's production scenario).

  PYTHONPATH=src python examples/serve_recsys.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main([
        "--arch", "deepfm", "--requests", "16", "--candidates", "2000",
        "--slate", "10", "--shortlist", "200", "--alpha", "3.0",
    ])
