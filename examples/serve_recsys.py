"""End-to-end serving driver: CTR scoring + Div-DPP slate diversification
over batched requests (the paper's production scenario), followed by a
streaming-emission demo — a long windowed feed served chunk by chunk
through ``rerank_stream`` instead of blocking on the whole slate.

  PYTHONPATH=src python examples/serve_recsys.py
"""
from repro.launch.serve import main


def stream_demo():
    """Serve a long diversified feed incrementally: the sliding window
    only enforces repulsion among nearby items, so the first chunk ships
    after ``chunk_size`` greedy steps — the client can start rendering
    while the rest of the feed is still being selected.  The
    concatenated chunks are exactly the whole-slate ``rerank`` result.
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.serving import DPPRerankConfig, rerank_stream

    rng = np.random.default_rng(0)
    M, D = 2000, 32
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    scores = jnp.asarray(rng.uniform(size=M).astype(np.float32))
    cfg = DPPRerankConfig(
        slate_size=40,      # a feed, not a panel — longer than the window
        shortlist=500,
        alpha=3.0,
        window=8,           # diversity against the last 8 items only
        chunk_size=10,      # emit the feed 10 items at a time
        eps=1e-6,
    )
    print("# streaming feed (window=8, 10 items per chunk):")
    for n, (ids, d_hist) in enumerate(
        rerank_stream(scores, jnp.asarray(feats), cfg)
    ):
        shown = " ".join(f"{int(i):4d}" for i in ids)
        print(f"chunk {n}: [{shown}]  min marginal {float(d_hist.min()):.4f}")


if __name__ == "__main__":
    main([
        "--arch", "deepfm", "--requests", "16", "--candidates", "2000",
        "--slate", "10", "--shortlist", "200", "--alpha", "3.0",
    ])
    stream_demo()
