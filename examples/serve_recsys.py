"""End-to-end serving driver: CTR scoring + Div-DPP slate diversification
over batched requests (the paper's production scenario), followed by a
streaming-emission demo — a long windowed feed served chunk by chunk
through ``Reranker.stream`` instead of blocking on the whole slate —
a continuous-batching demo where heterogeneous live requests share
one micro-batch through ``Reranker.submit``, and a session demo where
one user's feed resumes the warm windowed state across scroll events
(``Reranker.session``) and delta-updates when new candidates arrive.

  PYTHONPATH=src python examples/serve_recsys.py
"""
from repro.launch.serve import main


def stream_demo():
    """Serve a long diversified feed incrementally: the sliding window
    only enforces repulsion among nearby items, so the first chunk ships
    after ``chunk_size`` greedy steps — the client can start rendering
    while the rest of the feed is still being selected.  The
    concatenated chunks are exactly the whole-slate ``rerank`` result.
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.serving import DPPRerankConfig, Reranker, RerankRequest

    rng = np.random.default_rng(0)
    M, D = 2000, 32
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    scores = jnp.asarray(rng.uniform(size=M).astype(np.float32))
    rr = Reranker(DPPRerankConfig(
        slate_size=40,      # a feed, not a panel — longer than the window
        shortlist=500,
        alpha=3.0,
        window=8,           # diversity against the last 8 items only
        chunk_size=10,      # emit the feed 10 items at a time
        eps=1e-6,
    ))
    print("# streaming feed (window=8, 10 items per chunk):")
    for n, (ids, d_hist) in enumerate(
        rr.stream(RerankRequest(scores=scores, feats=jnp.asarray(feats)))
    ):
        shown = " ".join(f"{int(i):4d}" for i in ids)
        print(f"chunk {n}: [{shown}]  min marginal {float(d_hist.min()):.4f}")


def router_demo():
    """Continuous batching: four users with different slate lengths and
    already-seen masks arrive together; ``submit`` coalesces them into
    one shared micro-batch (one compiled geometry) instead of serving
    them one slate at a time."""
    import numpy as np
    import jax.numpy as jnp

    from repro.serving import (
        DPPRerankConfig,
        Reranker,
        RerankRequest,
        RouterConfig,
    )

    rng = np.random.default_rng(1)
    M, D = 1000, 32
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    feats = jnp.asarray(feats)
    rr = Reranker(
        DPPRerankConfig(slate_size=16, shortlist=200, alpha=3.0,
                        chunk_size=4, eps=1e-6),
        router_config=RouterConfig(slots=4, chunk_size=4),
    )
    handles = []
    for u in range(4):
        mask = None
        if u % 2:  # some users have already seen part of the pool
            m = np.ones(M, bool)
            m[rng.choice(M, size=M // 5, replace=False)] = False
            mask = jnp.asarray(m)
        handles.append(rr.submit(RerankRequest(
            scores=jnp.asarray(rng.uniform(size=M).astype(np.float32)),
            feats=feats, slate_size=8 + 2 * u, mask=mask, rid=f"user{u}",
        )))
    rr.router.drain()
    print("# continuous-batching router (4 heterogeneous users, 4 slots):")
    for h in handles:
        ids, _ = h.slate()
        print(f"{h.rid}: k={len(ids)} slate={ids.tolist()}")
    st = rr.router.stats
    print(f"batch fill ratio {st.fill_ratio:.2f}, "
          f"mean TTFC {st.mean_ttfc * 1e3:.1f} ms")


def session_demo():
    """Session-aware incremental rerank: one user scrolls a feed across
    several requests while the candidate pool drifts.  ``rr.session``
    keeps the windowed greedy state warm between scroll events — each
    ``next_chunk`` resumes where the last stopped, and ``extend`` /
    ``rescore`` delta-update only the affected columns instead of
    re-running greedy over everything already shown."""
    import numpy as np
    import jax.numpy as jnp

    from repro.serving import (
        DPPRerankConfig,
        Reranker,
        RerankRequest,
        SessionConfig,
    )

    rng = np.random.default_rng(2)
    M, D = 1500, 32
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.linalg.norm(feats, axis=1, keepdims=True)
    rr = Reranker(
        DPPRerankConfig(slate_size=18, shortlist=300, alpha=3.0,
                        window=8, chunk_size=6, eps=1e-6),
        session_config=SessionConfig(budget_bytes=64 << 20),
    )
    sess = rr.session(RerankRequest(
        scores=jnp.asarray(rng.uniform(size=M).astype(np.float32)),
        feats=jnp.asarray(feats),
    ))
    print("# session feed (window=8, 6 items per scroll):")
    for event in range(2):
        ids, gains = sess.next_chunk(6)
        shown = " ".join(f"{int(i):4d}" for i in ids)
        print(f"scroll {event}: [{shown}]  min marginal "
              f"{float(np.min(gains)):.4f}")

    # fresh candidates land mid-session; the next scroll conditions on
    # everything already shown AND sees the new arrivals
    dm = 200
    sess.extend(
        jnp.asarray(rng.uniform(size=dm).astype(np.float32) + 0.5),
        jnp.asarray((lambda f: f / np.linalg.norm(f, axis=1, keepdims=True))(
            rng.normal(size=(dm, D)).astype(np.float32)
        )),
    )
    ids, gains = sess.next_chunk(6)
    fresh = sum(1 for i in ids if int(i) >= M)
    shown = " ".join(f"{int(i):4d}" for i in ids)
    print(f"scroll 2 after extend(+{dm}): [{shown}]  "
          f"({fresh} fresh candidates picked)")
    print(f"shown so far: {len(sess.shown)} items")


if __name__ == "__main__":
    main([
        "--arch", "deepfm", "--requests", "16", "--candidates", "2000",
        "--slate", "10", "--shortlist", "200", "--alpha", "3.0",
    ])
    stream_demo()
    router_demo()
    session_demo()
