"""Fault-tolerant training demo: train a reduced DeepFM for 120 steps with
async checkpointing, inject a failure at step 80, then auto-resume and
finish — the restart path a production fleet exercises on every node
failure.

  PYTHONPATH=src python examples/train_fault_tolerant.py
"""
import subprocess
import sys
import tempfile
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))

with tempfile.TemporaryDirectory() as ckpt:
    base = [sys.executable, "-m", "repro.launch.train", "--arch", "deepfm",
            "--reduced", "--steps", "120", "--batch", "128",
            "--ckpt-dir", ckpt, "--ckpt-every", "25", "--log-every", "25"]
    print("== run 1: fails at step 80 (injected) ==")
    r1 = subprocess.run(base + ["--fail-at-step", "80"], cwd=REPO, env=env)
    assert r1.returncode != 0, "expected the injected failure"
    print("\n== run 2: --resume auto continues from the last commit ==")
    r2 = subprocess.run(base + ["--resume", "auto"], cwd=REPO, env=env)
    assert r2.returncode == 0
    print("\nrestart test passed: training resumed and completed.")
