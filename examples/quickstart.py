"""Quickstart: diversify a top-N slate with fast greedy DPP MAP inference.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    build_kernel_dense,
    dpp_greedy_dense,
    normalize_columns,
    similarity_from_features,
    slate_diversity,
    top_n_select,
)

M, D, N = 500, 64, 10
rng = np.random.default_rng(0)

# item relevance scores (e.g. CTR model outputs) + item feature vectors
relevance = jnp.asarray(rng.uniform(size=M), jnp.float32)
feats = normalize_columns(jnp.asarray(rng.normal(size=(D, M)), jnp.float32))
S = similarity_from_features(feats)

print("alpha  recall-proxy(sum rel)  avg-dissim  min-dissim")
for alpha in [1.0, 2.0, 8.0, 64.0]:
    L = build_kernel_dense(relevance, S, alpha=alpha)  # paper eq. (22)
    res = dpp_greedy_dense(L, N)  # paper Algorithm 1
    sel = np.asarray(res.indices)
    div = slate_diversity(sel, np.asarray(S))
    rel_sum = float(np.asarray(relevance)[sel[sel >= 0]].sum())
    print(f"{alpha:5.1f}  {rel_sum:20.3f}  {div['avg']:.4f}      {div['min']:.4f}")

top = top_n_select(np.asarray(relevance), N)
div = slate_diversity(top, np.asarray(S))
print(f"top-N  {float(np.asarray(relevance)[top].sum()):20.3f}  "
      f"{div['avg']:.4f}      {div['min']:.4f}")
print("\nlarger alpha -> closer to pure Top-N; alpha=1 -> pure diversity.")
