"""Parameter / input PartitionSpec rule tables per model family.

Rules map param-tree paths to logical sharding:
  * LM: 2D megatron TP on "model" x ZeRO-3 FSDP on the data axes
    (column-parallel wq/wk/wv/wi/wg, row-parallel wo; embeddings
    vocab-sharded; MoE experts on "model", FSDP inside each expert);
  * recsys: embedding tables row-sharded on "model", towers replicated;
  * GNN: params replicated (small), node/edge arrays sharded over the
    whole device grid.
"""
from __future__ import annotations

from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _res(rules: Mapping, name: str):
    v = rules.get(name)
    if v is None:
        return None
    return tuple(v) if isinstance(v, (list, tuple)) else v


def lm_param_spec(path, leaf, rules, profile: str = "baseline") -> P:
    name = _path_str(path)
    F = _res(rules, "fsdp")
    M = _res(rules, "model")
    stacked = name.startswith("layers/")
    pre = (None,) if stacked else ()

    def spec(*axes):
        return P(*(pre + axes))

    if profile.startswith("fsdp_ep"):
        # no TP: every dense 2D weight ZeRO-3-sharded on d_in over ALL
        # axes; experts keep EP on "model" with FSDP inside each expert.
        Fe = _res(rules, "fsdp_expert")
        dp = _res(rules, "batch")
        if name == "embed":
            return P(M, dp)
        if name == "unembed":
            return P(dp, M)
        if "moe/router" in name:
            return spec(None, None)
        if name.endswith(("moe/wi", "moe/wg")):
            return spec(M, Fe, None)
        if name.endswith("moe/wo"):
            return spec(M, None, Fe)
        if name.endswith("/w") and len(leaf.shape) == len(pre) + 2:
            return spec(F, None)
        return P(*(pre + (None,) * (len(leaf.shape) - len(pre))))

    if name == "embed":
        return P(M, F)
    if name == "unembed":
        return P(F, M)
    if name.endswith(("wq/w", "wk/w", "wv/w")):
        return spec(F, M)
    if name.endswith(("wq/b", "wk/b", "wv/b")):
        return spec(M)
    if "attn/wo/w" in name:
        return spec(M, F)
    if name.endswith(("mlp/wi/w", "mlp/wg/w")):
        return spec(F, M)
    if name.endswith("mlp/wo/w"):
        return spec(M, F)
    if "moe/router" in name:
        return spec(None, None)
    if name.endswith(("moe/wi", "moe/wg")):
        return spec(M, F, None)
    if name.endswith("moe/wo"):
        return spec(M, None, F)
    # norms, biases, scalars
    return P(*(pre + (None,) * (len(leaf.shape) - len(pre))))


def recsys_param_spec(path, leaf, rules) -> P:
    name = _path_str(path)
    M = _res(rules, "rows")
    if name in ("table", "wide"):
        return P(M, None)
    return P(*(None,) * len(leaf.shape))


def gnn_param_spec(path, leaf, rules) -> P:
    return P(*(None,) * len(leaf.shape))


def _fix_spec(spec: P, shape, mesh) -> P:
    """Drop trailing mesh axes from any dim whose size they don't divide
    (e.g. d_ff=6912 over a 512-way FSDP axis group -> keep the largest
    divisible prefix)."""
    fixed = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            fixed.append(entry)
            continue
        axes = list(entry) if isinstance(entry, (list, tuple)) else [entry]
        while axes:
            prod = int(np.prod([mesh.shape[a] for a in axes]))
            if shape[i] % prod == 0 and shape[i] >= prod:
                break
            axes.pop()
        fixed.append(tuple(axes) if len(axes) != 1 else axes[0])
        if not axes:
            fixed[-1] = None
    return P(*fixed)


def param_shardings(family: str, tree, mesh, rules, profile: str = "baseline"):
    if family == "lm":
        fn = lambda p, l: lm_param_spec(p, l, rules, profile)
    elif family == "recsys":
        fn = lambda p, l: recsys_param_spec(p, l, rules)
    else:
        fn = lambda p, l: gnn_param_spec(p, l, rules)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, _fix_spec(fn(p, l), l.shape, mesh)), tree
    )


def opt_shardings(param_sh, mesh):
    """AdamW state: moments shard like params; step is replicated."""
    return {
        "m": param_sh,
        "v": param_sh,
        "step": NamedSharding(mesh, P()),
    }


def batch_axes_for(rules, n: int, mesh) -> tuple:
    """Data axes if the leading dim divides evenly, else replicate."""
    v = _res(rules, "batch") or ()
    axes = (v,) if isinstance(v, str) else tuple(v)
    size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
    return axes if axes and n % size == 0 and n >= size else ()
