"""Cell builders: (architecture x input shape x mesh) -> a lowered-ready
step function with fully-specified in/out shardings and ShapeDtypeStruct
inputs (the shannon/kernels dry-run pattern: weak-type-correct, shardable,
zero allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec
from repro.configs.shapes import ShapeSpec
from repro.launch.shardings import (
    batch_axes_for,
    opt_shardings,
    param_shardings,
    _res,
)
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.serving import DPPRerankConfig, Reranker, RerankRequest


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    step_fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    notes: str = ""
    model_flops_per_step: float = 0.0  # 6*N*D (train) / 2*N*D (serve) etc.


def _struct(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _scalar(mesh):
    return NamedSharding(mesh, P())


def _train_wrapper(loss_fn, acfg: AdamWConfig):
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, metrics = adamw_update(params, grads, opt, acfg)
        return params, opt, {"loss": loss, **metrics}

    return step


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(arch: ArchSpec, shape: ShapeSpec, mesh, rules, acfg: AdamWConfig, profile: str = "baseline") -> Cell:
    cfg: tfm.TransformerConfig = arch.config
    B, S = shape.global_batch, shape.seq_len
    p_struct = jax.eval_shape(lambda: tfm.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = param_shardings("lm", p_struct, mesh, rules, profile)
    b_axes = batch_axes_for(rules, B, mesh)
    M = _res(rules, "model")
    kv_seq = _res(rules, "kv_seq")
    seq_ax = _res(rules, "seq")  # fsdp_ep: sequence sharded on "model"

    def cache_shardings(cache_struct):
        def one(path, leaf):
            name = "/".join(str(getattr(k, "key", k)) for k in path)
            if name.endswith("pos"):
                return _scalar(mesh)
            # (n_layers_in_group, B, W, KV, dh): seq on model, batch on dp
            return NamedSharding(mesh, P(None, b_axes or None, kv_seq, None, None))

        return jax.tree_util.tree_map_with_path(one, cache_struct)

    if shape.kind == "train":
        loss_fn = lambda p, b: tfm.train_loss(p, b, cfg)
        step = _train_wrapper(loss_fn, acfg)
        o_struct = jax.eval_shape(lambda: adamw_init(p_struct))
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        b_sh = {"tokens": NamedSharding(mesh, P(b_axes or None, seq_ax))}
        o_sh = opt_shardings(p_sh, mesh)
        m_sh = {"loss": _scalar(mesh), "grad_norm": _scalar(mesh)}
        flops = 6.0 * cfg.active_param_count() * B * S
        return Cell(arch.id, shape.name, step, (p_struct, o_struct, batch),
                    (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh),
                    model_flops_per_step=flops)

    if shape.kind == "prefill":
        def step(params, batch):
            return tfm.prefill(params, batch["tokens"], cfg, max_seq=S)

        batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        b_sh = {"tokens": NamedSharding(mesh, P(b_axes or None, None))}
        c_struct = jax.eval_shape(lambda: tfm.init_cache(cfg, B, S))
        logits_sh = NamedSharding(mesh, P(b_axes or None, M))
        flops = 2.0 * cfg.active_param_count() * B * S
        return Cell(arch.id, shape.name, step, (p_struct, batch),
                    (p_sh, b_sh), (logits_sh, cache_shardings(c_struct)),
                    model_flops_per_step=flops)

    # decode (decode_32k / long_500k)
    c_struct = jax.eval_shape(lambda: tfm.init_cache(cfg, B, S))
    c_sh = cache_shardings(c_struct)

    def step(params, cache, batch):
        return tfm.decode_step(params, cache, batch["tokens"], cfg)

    batch = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    b_sh = {"tokens": NamedSharding(mesh, P(b_axes or None, None))}
    logits_sh = NamedSharding(mesh, P(b_axes or None, M))
    flops = 2.0 * cfg.active_param_count() * B
    return Cell(arch.id, shape.name, step, (p_struct, c_struct, batch),
                (p_sh, c_sh, b_sh), (logits_sh, c_sh),
                model_flops_per_step=flops)


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_dims(shape: ShapeSpec) -> Tuple[int, int, str]:
    if shape.name == "minibatch_lg":
        b, (f1, f2) = shape.batch_nodes, shape.fanout
        n = b * (1 + f1 + f1 * f2)
        e = b * f1 + b * f1 * f2
        note = f"sampled subgraph: {b} seeds, fanout {shape.fanout}, padded"
    elif shape.name == "molecule":
        n = shape.n_graphs * shape.nodes_per_graph
        e = shape.n_graphs * shape.edges_per_graph
        note = f"{shape.n_graphs} disjoint molecules"
    else:
        n, e = shape.n_nodes, shape.n_edges
        note = "full graph"
    return _round_up(n, 512), _round_up(e, 512), note + " (padded to /512)"


def _gnn_cell(arch: ArchSpec, shape: ShapeSpec, mesh, rules, acfg: AdamWConfig, profile: str = "baseline") -> Cell:
    cfg0: gnn_mod.GNNConfig = arch.config
    cfg = dataclasses.replace(cfg0, d_feat=shape.d_feat)
    N, E, note = _gnn_dims(shape)
    p_struct = jax.eval_shape(lambda: gnn_mod.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = param_shardings("gnn", p_struct, mesh, rules)
    o_struct = jax.eval_shape(lambda: adamw_init(p_struct))
    o_sh = opt_shardings(p_sh, mesh)

    nodes_ax = _res(rules, "nodes")
    edges_ax = _res(rules, "edges")
    batch = {
        "node_feats": jax.ShapeDtypeStruct((N, cfg.d_feat), jnp.float32),
        "edges": jax.ShapeDtypeStruct((E, 2), jnp.int32),
        "targets": jax.ShapeDtypeStruct((N, cfg.n_vars), jnp.float32),
        "node_mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
        "edge_mask": jax.ShapeDtypeStruct((E,), jnp.bool_),
    }
    b_sh = {
        "node_feats": NamedSharding(mesh, P(nodes_ax, None)),
        "edges": NamedSharding(mesh, P(edges_ax, None)),
        "targets": NamedSharding(mesh, P(nodes_ax, None)),
        "node_mask": NamedSharding(mesh, P(nodes_ax)),
        "edge_mask": NamedSharding(mesh, P(edges_ax)),
    }
    loss_fn = lambda p, b: gnn_mod.mse_loss(p, b, cfg)
    step = _train_wrapper(loss_fn, acfg)
    m_sh = {"loss": _scalar(mesh), "grad_norm": _scalar(mesh)}
    # processor: per edge ~2*(2h+de)*h MLP flops x2 (fwd+... ) -> use 6x fwd
    fwd = cfg.n_layers * (
        E * 2 * (2 * cfg.d_hidden + cfg.d_edge) * cfg.d_hidden
        + N * 2 * (cfg.d_hidden + cfg.d_edge) * cfg.d_hidden
    )
    return Cell(arch.id, shape.name, step, (p_struct, o_struct, batch),
                (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh), notes=note,
                model_flops_per_step=3.0 * fwd)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _recsys_cell(arch: ArchSpec, shape: ShapeSpec, mesh, rules, acfg: AdamWConfig, profile: str = "baseline") -> Cell:
    cfg: recsys_mod.RecsysConfig = arch.config
    F, H = cfg.n_fields, cfg.hot_size
    p_struct = jax.eval_shape(lambda: recsys_mod.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = param_shardings("recsys", p_struct, mesh, rules)
    # dense-tower flops per example (fwd), dominated by the MLP
    d_in = F * cfg.embed_dim
    dims = (d_in,) + tuple(cfg.mlp_dims) + (1,)
    mlp_flops = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))

    if shape.kind == "train":
        B = shape.batch
        b_axes = batch_axes_for(rules, B, mesh)
        o_struct = jax.eval_shape(lambda: adamw_init(p_struct))
        batch = {
            "ids": jax.ShapeDtypeStruct((B, F, H), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B,), jnp.float32),
        }
        b_sh = {
            "ids": NamedSharding(mesh, P(b_axes or None, None, None)),
            "labels": NamedSharding(mesh, P(b_axes or None)),
        }
        loss_fn = lambda p, b: recsys_mod.bce_loss(p, b, cfg)
        step = _train_wrapper(loss_fn, acfg)
        m_sh = {"loss": _scalar(mesh), "grad_norm": _scalar(mesh)}
        return Cell(arch.id, shape.name, step, (p_struct, o_struct, batch),
                    (p_sh, opt_shardings(p_sh, mesh), b_sh),
                    (p_sh, opt_shardings(p_sh, mesh), m_sh),
                    model_flops_per_step=3.0 * B * mlp_flops)

    if shape.kind == "serve":
        B = shape.batch
        b_axes = batch_axes_for(rules, B, mesh)

        def step(params, batch):
            return recsys_mod.serve_scores(params, batch["ids"], cfg)

        batch = {"ids": jax.ShapeDtypeStruct((B, F, H), jnp.int32)}
        b_sh = {"ids": NamedSharding(mesh, P(b_axes or None, None, None))}
        out_sh = NamedSharding(mesh, P(b_axes or None))
        return Cell(arch.id, shape.name, step, (p_struct, batch),
                    (p_sh, b_sh), out_sh,
                    model_flops_per_step=1.0 * B * mlp_flops)

    # retrieval_cand: score 1M candidates for one user, then Div-DPP rerank
    # — the paper's serving scenario, inside the lowered graph.
    Mc = shape.n_candidates
    Mc_p = _round_up(Mc, 512)  # pad so the candidate axis shards evenly
    b_axes = batch_axes_for(rules, Mc_p, mesh)
    rr = DPPRerankConfig(slate_size=50, shortlist=1000, alpha=4.0)
    rr_session = Reranker(rr)

    def step(params, batch):
        user = batch["user_ids"]  # (1, F, H)
        cand = batch["cand_ids"]  # (Mc_p,) — pipeline pads to /512
        pad_mask = jnp.arange(Mc_p) < Mc
        ids = jnp.broadcast_to(user, (Mc_p, F, H)).astype(jnp.int32)
        ids = jnp.concatenate(
            [
                ids[:, : cfg.item_field],
                jnp.concatenate(
                    [cand[:, None], jnp.full((Mc_p, H - 1), -1, jnp.int32)], axis=1
                )[:, None] if H > 1 else cand[:, None, None],
                ids[:, cfg.item_field + 1 :],
            ],
            axis=1,
        )
        from repro.distributed.context import constrain

        ids = constrain(ids, "batch", None, None)
        scores = recsys_mod.serve_scores(params, ids, cfg)
        scores = jnp.where(pad_mask, scores, -jnp.inf)  # padding never wins
        feats = recsys_mod.item_embeddings(params, cand, cfg)
        slate, dh = rr_session.rerank(RerankRequest(scores=scores, feats=feats))
        return slate, dh

    batch = {
        "user_ids": jax.ShapeDtypeStruct((1, F, H), jnp.int32),
        # candidate list padded to /512 by the input pipeline (scores for
        # padding are masked to -inf before the shortlist top-k)
        "cand_ids": jax.ShapeDtypeStruct((Mc_p,), jnp.int32),
    }
    b_sh = {
        "user_ids": NamedSharding(mesh, P(None, None, None)),
        "cand_ids": NamedSharding(mesh, P(b_axes or None)),
    }
    out_sh = (NamedSharding(mesh, P(None)), NamedSharding(mesh, P(None)))
    return Cell(arch.id, shape.name, step, (p_struct, batch),
                (p_sh, b_sh), out_sh,
                notes=f"DPP rerank: shortlist={rr.shortlist} N={rr.slate_size} "
                      f"alpha={rr.alpha} (paper Algorithm 1 in-graph)",
                model_flops_per_step=1.0 * Mc * mlp_flops)


def build_cell(
    arch: ArchSpec, shape: ShapeSpec, mesh, rules,
    acfg: Optional[AdamWConfig] = None,
    profile: str = "baseline",
) -> Cell:
    acfg = acfg or AdamWConfig()
    if profile != "baseline" and arch.family == "lm":
        if profile == "flash_remat":
            arch = dataclasses.replace(
                arch, config=dataclasses.replace(arch.config, remat_chunks=True))
        elif profile in ("fsdp_ep", "fsdp_ep_remat"):
            cfgx = arch.config
            if profile == "fsdp_ep_remat":
                cfgx = dataclasses.replace(cfgx, remat_chunks=True)
            arch = dataclasses.replace(arch, config=cfgx)
    if profile == "a2a_emb" and arch.family == "recsys":
        arch = dataclasses.replace(
            arch, config=dataclasses.replace(arch.config, emb_mode="alltoall"))
    fn = {"lm": _lm_cell, "gnn": _gnn_cell, "recsys": _recsys_cell}[arch.family]
    cell = fn(arch, shape, mesh, rules, acfg, profile)
    return cell
