"""Batched serving driver with DPP slate diversification.

  PYTHONPATH=src python -m repro.launch.serve --arch deepfm --reduced \
      --requests 32 --candidates 2000 --slate 10 --alpha 3.0

Serving pipeline per request batch (the paper's §5 scenario end-to-end):
  1. score all candidates with the CTR model (batched forward);
  2. shortlist top-C;
  3. Div-DPP (Algorithm 1) re-ranks the shortlist into a diverse slate.

Reports throughput and slate diversity metrics (average/min/median
dissimilarity — the paper's metrics) vs a pure Top-N baseline.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import mean_slate_diversity, top_n_select
from repro.data import recsys_batches
from repro.models import recsys as recsys_mod
from repro.serving import DPPRerankConfig, Reranker, RerankRequest


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepfm")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--candidates", type=int, default=2000)
    ap.add_argument("--slate", type=int, default=10)
    ap.add_argument("--shortlist", type=int, default=200)
    ap.add_argument("--alpha", type=float, default=3.0)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    spec = get_arch(args.arch)
    assert spec.family == "recsys", "serving driver targets the recsys family"
    cfg = spec.reduced() if args.reduced else spec.config
    params = recsys_mod.init_params(jax.random.PRNGKey(0), cfg)
    Mc = min(args.candidates, cfg.vocab_sizes[cfg.item_field])
    B = args.requests
    rr = Reranker(DPPRerankConfig(
        slate_size=args.slate, shortlist=min(args.shortlist, Mc),
        alpha=args.alpha, use_kernel=args.use_kernel,
    ))

    # candidate item ids are shared; user contexts vary per request
    cand = jnp.arange(Mc, dtype=jnp.int32)
    gen = recsys_batches(cfg.vocab_sizes, B, seed=1)
    user = jnp.asarray(next(gen)["ids"])  # (B, F, H)

    @jax.jit
    def serve(params, user_ids):
        def score_one(u):
            ids = jnp.broadcast_to(u[None], (Mc,) + u.shape).astype(jnp.int32)
            ids = jnp.concatenate(
                [ids[:, : cfg.item_field],
                 cand[:, None, None] if u.shape[-1] == 1 else
                 jnp.concatenate([cand[:, None],
                                  jnp.full((Mc, u.shape[-1] - 1), -1, jnp.int32)],
                                 axis=1)[:, None],
                 ids[:, cfg.item_field + 1:]],
                axis=1,
            )
            return recsys_mod.serve_scores(params, ids, cfg)

        scores = jax.vmap(score_one)(user_ids)  # (B, Mc)
        feats = recsys_mod.item_embeddings(params, cand, cfg)  # (Mc, D)
        slates, dh = rr.rerank(RerankRequest(scores=scores, feats=feats))
        return scores, slates

    t0 = time.time()
    scores, slates = jax.block_until_ready(serve(params, user))
    t_first = time.time() - t0
    t0 = time.time()
    scores, slates = jax.block_until_ready(serve(params, user))
    t_steady = time.time() - t0

    feats = np.asarray(recsys_mod.item_embeddings(params, cand, cfg))
    S = feats @ feats.T
    slates_np = np.asarray(slates)
    top_slates = np.stack(
        [top_n_select(np.asarray(scores[b]), args.slate) for b in range(B)]
    )
    div_dpp = mean_slate_diversity(slates_np, S)
    div_top = mean_slate_diversity(top_slates, S)
    out = {
        "arch": args.arch,
        "requests": B,
        "candidates": Mc,
        "first_batch_s": round(t_first, 3),
        "steady_batch_s": round(t_steady, 3),
        "req_per_s": round(B / t_steady, 1),
        "diversity_dpp": div_dpp,
        "diversity_top": div_top,
        "mean_rel_dpp": float(np.take_along_axis(np.asarray(scores), slates_np, 1).mean()),
        "mean_rel_top": float(np.take_along_axis(np.asarray(scores), top_slates, 1).mean()),
    }
    print(json.dumps(out, indent=1))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(out, f)
    return out


if __name__ == "__main__":
    main()
