"""Driver: run every (arch x shape x mesh) dry-run cell in a subprocess
(fresh jax per cell; incremental — completed cells are skipped).

  PYTHONPATH=src python -m repro.launch.run_dryruns [--mesh pod multipod]
      [--only arch1,arch2] [--timeout 3600] [--force]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time


def cell_done(out_dir: str, arch: str, shape: str, mesh: str) -> bool:
    p = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
    if not os.path.isfile(p):
        return False
    try:
        with open(p) as f:
            return json.load(f).get("status") in ("ok", "skipped")
    except json.JSONDecodeError:
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", nargs="+", default=["pod", "multipod"])
    ap.add_argument("--only", default="")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_arch, list_archs

    archs = args.only.split(",") if args.only else list_archs()
    cells = []
    for a in archs:
        spec = get_arch(a)
        for s in spec.shapes:
            for m in args.mesh:
                cells.append((a, s, m))

    print(f"{len(cells)} cells")
    failures = []
    for i, (a, s, m) in enumerate(cells):
        if not args.force and cell_done(args.out, a, s, m):
            print(f"[{i+1}/{len(cells)}] {a} {s} {m}: cached")
            continue
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--mesh", m, "--out", args.out]
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env=dict(os.environ, PYTHONPATH="src"))
            ok = r.returncode == 0
        except subprocess.TimeoutExpired:
            ok = False
            with open(os.path.join(args.out, f"{a}__{s}__{m}.json"), "w") as f:
                json.dump({"arch": a, "shape": s, "mesh": m,
                           "status": "timeout", "timeout_s": args.timeout}, f)
        dt = time.time() - t0
        status = "OK" if ok else "FAIL"
        if not ok:
            failures.append((a, s, m))
        print(f"[{i+1}/{len(cells)}] {a} {s} {m}: {status} ({dt:.0f}s)")
        if not ok and 'r' in dir():
            tail = (r.stderr or "")[-800:]
            print("  stderr tail:", tail.replace("\n", "\n  "))
    print(f"done; {len(failures)} failures: {failures}")


if __name__ == "__main__":
    main()
