"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real (single) device.
"""
from __future__ import annotations

from repro.distributed.context import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2 pods x 256 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over host devices (tests / CPU smoke runs)."""
    return make_mesh_compat((data, model), ("data", "model"))
