"""Continuous-batching serving driver: live heterogeneous requests
through ``RerankRouter`` behind a CTR scorer.

  PYTHONPATH=src python -m repro.launch.serve_router --arch deepfm \
      --requests 24 --candidates 2000 --slots 4 --chunk 4 --qps 50

A synthetic open-loop client offers one request every ``1/qps`` seconds:
each request is one user scored against the shared candidate pool by
the recsys model (as in ``repro.launch.serve``), with a per-request
slate length drawn from ``[slate/2, slate]``, an already-seen mask for
every third user, and an optional per-request ``--deadline``.  Requests
are submitted to one ``Reranker.submit`` session; the driver pumps the
router, measuring completion latency percentiles, time-to-first-chunk,
sustained QPS and the batch fill ratio, and cross-checks a sample of
completed slates index-for-index against per-request ``rerank``.

``--trace-out trace.json`` writes every span of the run (the
``router.pump`` decomposition among them) as Chrome ``trace_event``
JSON — load it in https://ui.perfetto.dev.  ``--metrics-out`` then also
embeds the metrics snapshot (kernel dispatch counts, marginal
evaluations, jit cache misses) next to the driver numbers; the
``jit_misses_after_warmup`` field is the structural no-re-jit check —
0 means the measured loop ran entirely on cached computations.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_arch
from repro.models import recsys as recsys_mod
from repro.data import recsys_batches
from repro.serving import (
    DPPRerankConfig,
    ObsConfig,
    Reranker,
    RerankRequest,
    RouterConfig,
)
from repro.serving.router import RouterQueueFull


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepfm")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--candidates", type=int, default=2000)
    ap.add_argument("--slate", type=int, default=16)
    ap.add_argument("--shortlist", type=int, default=200)
    ap.add_argument("--alpha", type=float, default=3.0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--qps", type=float, default=50.0)
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="per-request latency budget in seconds (0 = none)")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--parity-sample", type=int, default=4)
    ap.add_argument("--metrics-out", default="")
    ap.add_argument("--trace-out", default="",
                    help="write the run's spans as Chrome trace_event JSON "
                         "(Perfetto-loadable)")
    args = ap.parse_args(argv)

    # observability is threaded through the serving configs, not turned
    # on globally here — the run exercises the same wiring users get
    ocfg = (
        ObsConfig(enabled=True)
        if (args.metrics_out or args.trace_out) else None
    )

    spec = get_arch(args.arch)
    assert spec.family == "recsys", "serving driver targets the recsys family"
    cfg = spec.reduced() if args.reduced else spec.config
    params = recsys_mod.init_params(jax.random.PRNGKey(0), cfg)
    Mc = min(args.candidates, cfg.vocab_sizes[cfg.item_field])
    shortlist = min(args.shortlist, Mc)

    rcfg = DPPRerankConfig(
        slate_size=args.slate, shortlist=shortlist, alpha=args.alpha,
        use_kernel=args.use_kernel, chunk_size=args.chunk,
    )
    rr = Reranker(rcfg, router_config=RouterConfig(
        slots=args.slots, chunk_size=args.chunk, max_queue=args.requests,
        max_candidates=shortlist, obs=ocfg,
    ))

    # score every user against the shared candidate pool up front — the
    # scorer is not what this driver measures
    cand = jnp.arange(Mc, dtype=jnp.int32)
    gen = recsys_batches(cfg.vocab_sizes, args.requests, seed=1)
    user = jnp.asarray(next(gen)["ids"])

    @jax.jit
    def score_all(params, user_ids):
        def score_one(u):
            ids = jnp.broadcast_to(u[None], (Mc,) + u.shape).astype(jnp.int32)
            ids = jnp.concatenate(
                [ids[:, : cfg.item_field],
                 cand[:, None, None] if u.shape[-1] == 1 else
                 jnp.concatenate([cand[:, None],
                                  jnp.full((Mc, u.shape[-1] - 1), -1,
                                           jnp.int32)], axis=1)[:, None],
                 ids[:, cfg.item_field + 1:]],
                axis=1,
            )
            return recsys_mod.serve_scores(params, ids, cfg)

        return jax.vmap(score_one)(user_ids)

    scores = jax.block_until_ready(score_all(params, user))  # (B, Mc)
    feats = recsys_mod.item_embeddings(params, cand, cfg)  # (Mc, D)

    rng = np.random.default_rng(0)
    reqs = []
    for b in range(args.requests):
        mask = None
        if b % 3 == 2:
            m = np.ones(Mc, bool)
            m[rng.choice(Mc, size=Mc // 5, replace=False)] = False
            mask = jnp.asarray(m)
        reqs.append(RerankRequest(
            scores=scores[b], feats=feats,
            slate_size=int(rng.integers(max(args.slate // 2, 1),
                                        args.slate + 1)),
            mask=mask,
            deadline=args.deadline or None,
            rid=b,
        ))

    # warm the slot geometry's compile out of the measurement; the warm
    # set must cover the masked-admission program too (mask presence is
    # a host-side branch — a distinct one-time compile the miss counter
    # would otherwise report when the first masked request lands
    # mid-measurement)
    warm_reqs = list(reqs[: args.slots])
    if warm_reqs and not any(r.mask is not None for r in warm_reqs):
        masked = next((r for r in reqs if r.mask is not None), None)
        if masked is not None:
            warm_reqs[-1] = masked
    warm = [rr.submit(r) for r in warm_reqs]
    rr.router.drain()
    rr = Reranker(rcfg, router_config=RouterConfig(
        slots=args.slots, chunk_size=args.chunk, max_queue=args.requests,
        max_candidates=shortlist, obs=ocfg,
    ))
    cm = obs.compile_monitor()
    if cm is not None:
        cm.mark()  # every compile past here is a measured-loop re-jit

    gap = 1.0 / args.qps
    t0 = time.perf_counter()
    handles, arrived, done_at = [], {}, {}
    pending = list(reqs)
    offered = 0
    while pending or any(not h.done for h in handles):
        now = time.perf_counter() - t0
        while pending and offered * gap <= now:
            try:
                h = rr.submit(pending[0])
            except RouterQueueFull:
                break
            arrived[id(h)] = now
            handles.append(h)
            pending.pop(0)
            offered += 1
        rr.router.pump()
        now = time.perf_counter() - t0
        for h in handles:
            if h.done and id(h) not in done_at:
                done_at[id(h)] = now
    makespan = max(done_at.values())

    lat = np.array([done_at[id(h)] - arrived[id(h)] for h in handles])
    ttfc = np.array([h.ttfc for h in handles if h.ttfc is not None])
    # read the miss counter BEFORE the parity sample: per-request rerank
    # below legitimately compiles one whole-slate program per distinct k
    misses_after_warmup = int(cm.since_mark()) if cm is not None else None
    parity_ok = True
    for h, req in list(zip(handles, reqs))[: args.parity_sample]:
        if h.timed_out:
            continue
        ei, _ = rr.rerank(req)
        parity_ok &= bool(np.array_equal(h.slate()[0], np.asarray(ei)))
    st = rr.router.stats
    out = {
        "arch": args.arch,
        "requests": len(handles),
        "candidates": Mc,
        "slots": args.slots,
        "chunk": args.chunk,
        "offered_qps": args.qps,
        "sustained_qps": round(len(handles) / makespan, 1),
        "p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 2),
        "p95_ms": round(float(np.percentile(lat, 95)) * 1e3, 2),
        "p99_ms": round(float(np.percentile(lat, 99)) * 1e3, 2),
        "mean_ttfc_ms": round(float(ttfc.mean()) * 1e3, 2),
        "fill_ratio": round(st.fill_ratio, 3),
        "completed": st.completed,
        "timed_out": st.timed_out,
        "eps_stopped": st.eps_stopped,
        "parity_sample_ok": parity_ok,
    }
    if misses_after_warmup is not None:
        out["jit_misses_after_warmup"] = misses_after_warmup
    print(json.dumps(out, indent=1))
    if obs.registry() is not None:
        out["obs"] = obs.registry().snapshot()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(out, f)
    if args.trace_out and obs.tracer() is not None:
        obs.tracer().write_chrome(args.trace_out)
        print(f"trace: {args.trace_out} ({obs.tracer().total} spans)")
    if not parity_ok:
        raise SystemExit("router slates diverged from per-request rerank")
    return out


if __name__ == "__main__":
    main()
