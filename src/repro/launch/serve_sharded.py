"""Sharded serving driver: diversified slates drawn from a candidate
set far larger than any single device would hold.

  PYTHONPATH=src python -m repro.launch.serve_sharded \
      --devices 8 --candidates 1000000 --dim 32 --slate 20 --window 8

Forces ``--devices`` host (CPU) devices via XLA_FLAGS — which must
happen before the first jax import, so this module keeps its top-level
imports jax-free (same contract as ``repro.launch.dryrun``) — builds a
("data",) mesh over them, synthesizes scores/features for M candidates,
and runs the full sharded pipeline end to end: sharded top-k shortlist
mask -> candidate-sharded greedy MAP (exact or sliding-window).  Each
device only ever holds a (D, M/P) column shard of the scaled feature
matrix plus its slice of the greedy state.

``--batch B`` serves a request batch of B users through the same mesh
in one ``Reranker.rerank`` call (per-user scores over shared features):
the candidate axis stays sharded and the per-step collectives batch
over B, so per-slate latency amortizes against the mesh instead of
paying B sequential round-trips.

``--stream N`` switches to **chunked slate emission**: the slate is
served through ``Reranker.stream`` in N-item chunks — the greedy state
stays sharded and device-resident between chunks, so the first chunk
ships after N greedy steps instead of after the whole slate.  The
report then carries ``first_chunk_s`` (time-to-first-chunk) next to
the whole-slate ``steady_call_s``, and ``--check`` verifies the
concatenated chunks equal the whole-slate slate index for index.
``--stream`` serves a single request (``--batch`` must stay 1).

``--check`` additionally runs the single-device ``rerank`` (vmapped
when ``--batch > 1``) on the same inputs and asserts the slates are
identical (the sharded path's bit-exactness guarantee); keep M modest
when checking.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8,
                    help="force N host devices before jax init (0 = leave as-is)")
    ap.add_argument("--candidates", type=int, default=1_000_000)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--slate", type=int, default=20)
    ap.add_argument("--shortlist", type=int, default=0,
                    help="top-C shortlist mask (0 = rank the full candidate set)")
    ap.add_argument("--window", type=int, default=0,
                    help="sliding diversity window (0 = exact Algorithm 1)")
    ap.add_argument("--alpha", type=float, default=3.0)
    ap.add_argument("--batch", type=int, default=1,
                    help="request batch: B users' slates in one mesh call")
    ap.add_argument("--stream", type=int, default=0,
                    help="emit the slate in chunks of this size (0 = whole)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--check", action="store_true",
                    help="verify against the single-device rerank (small M only)")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    if args.devices:
        from repro.launch.hostdev import force_host_device_flags

        # replace any inherited device-count flag so --devices always wins
        os.environ["XLA_FLAGS"] = force_host_device_flags(
            os.environ.get("XLA_FLAGS", ""), args.devices
        )

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed.context import make_mesh_compat
    from repro.serving import DPPRerankConfig, Reranker, RerankRequest

    if args.stream and args.batch > 1:
        raise SystemExit("--stream serves a single request; keep --batch 1")

    ndev = jax.device_count()
    mesh = make_mesh_compat((ndev,), ("data",))
    M, D, N, B = args.candidates, args.dim, args.slate, args.batch

    rng = np.random.default_rng(args.seed)
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.maximum(np.linalg.norm(feats, axis=1, keepdims=True), 1e-12)
    scores = rng.uniform(size=(B, M)).astype(np.float32)
    feats, scores = jnp.asarray(feats), jnp.asarray(scores)

    cfg = DPPRerankConfig(
        slate_size=N,
        shortlist=args.shortlist or M,
        alpha=args.alpha,
        eps=1e-6,
        window=args.window or None,
        mesh=mesh,
    )
    def serve(s, f, c):
        # one mesh call for the whole user batch; a single request drops
        # the batch axis so the (M,) fast path serves it
        req = RerankRequest(scores=s if B > 1 else s[0], feats=f)
        return Reranker(c).rerank(req)

    t0 = time.time()
    slate, dh = serve(scores, feats, cfg)
    slate.block_until_ready()
    t_first = time.time() - t0
    t0 = time.time()
    slate, dh = serve(scores, feats, cfg)
    slate.block_until_ready()
    t_steady = time.time() - t0

    stream_stats = None
    if args.stream:
        scfg = dataclasses.replace(cfg, chunk_size=args.stream)
        session = Reranker(scfg)
        sreq = RerankRequest(scores=scores[0], feats=feats)
        # warm pass compiles the chunk executors; timed pass measures
        # time-to-first-chunk and whole-stream wall clock
        for c, _ in session.stream(sreq):
            c.block_until_ready()
        t0 = time.time()
        chunks = []
        t_chunk1 = None
        for c, _ in session.stream(sreq):
            c.block_until_ready()
            if t_chunk1 is None:
                t_chunk1 = time.time() - t0
            chunks.append(np.asarray(c))
        t_stream = time.time() - t0
        stream_stats = {
            "chunk_size": args.stream,
            "first_chunk_s": round(t_chunk1, 3),
            "stream_total_s": round(t_stream, 3),
            "first_chunk_vs_whole": round(t_chunk1 / max(t_steady, 1e-9), 3),
        }
        if args.check:
            assert np.array_equal(
                np.concatenate(chunks), np.asarray(slate).reshape(-1)
            ), "streamed chunks diverged from the whole-slate slate"
            stream_stats["check"] = "ok (chunks concatenate to the slate)"

    slate_np = np.asarray(slate)
    n_sel = int((slate_np >= 0).sum())
    out = {
        "devices": ndev,
        "candidates": M,
        "per_device_candidates": -(-M // ndev),
        "dim": D,
        "slate": N,
        "batch": B,
        "window": args.window or None,
        "shortlist": args.shortlist or None,
        "n_selected": n_sel,
        "first_call_s": round(t_first, 3),
        "steady_call_s": round(t_steady, 3),
        "us_per_step": round(t_steady / max(N, 1) * 1e6, 1),
        "us_per_user_slate": round(t_steady / max(B, 1) * 1e6, 1),
    }
    if stream_stats is not None:
        out["stream"] = stream_stats

    if args.check:
        ref_cfg = DPPRerankConfig(
            slate_size=N, shortlist=args.shortlist or M, alpha=args.alpha,
            eps=1e-6, window=args.window or None,
        )
        ref, _ = serve(scores, feats, ref_cfg)
        assert np.array_equal(np.asarray(ref), slate_np), (
            "sharded slate diverged from the single-device path"
        )
        out["check"] = "ok (identical slate to single-device rerank)"

    print(json.dumps(out, indent=1))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(out, f)
    return out


if __name__ == "__main__":
    main()
