import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks on first init).
#   This is the ONLY entry point that fakes devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production mesh and record memory / cost / collective /
roofline evidence.

  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
      --shape train_4k --mesh pod          # 16x16 single pod
  PYTHONPATH=src python -m repro.launch.dryrun --arch ... --mesh multipod
  PYTHONPATH=src python -m repro.launch.dryrun --list

Results append to experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import numpy as np
import json
import time
import traceback


def run_cell(arch_id: str, shape_name: str, mesh_name: str, out_dir: str,
             keep_hlo: bool = False, profile: str = "baseline") -> dict:
    import jax

    from repro.configs import get_arch
    from repro.distributed.context import (
        axis_rules, fsdp_ep_rules, multi_pod_rules, recsys_a2a_rules,
        single_pod_rules,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.roofline.analysis import analyze, roofline_fraction

    arch = get_arch(arch_id)
    if shape_name in arch.skips:
        rec = {
            "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": arch.skips[shape_name],
        }
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}.json"), "w") as f:
            json.dump(rec, f, indent=1)
        return rec
    shape = arch.shapes[shape_name]
    multi = mesh_name == "multipod"
    mesh = make_production_mesh(multi_pod=multi)
    if profile in ("fsdp_ep", "fsdp_ep_remat"):
        rules = fsdp_ep_rules(multi)
    elif profile == "a2a_emb":
        rules = recsys_a2a_rules(multi)
    else:
        rules = multi_pod_rules() if multi else single_pod_rules()
    chips = mesh.devices.size

    t0 = time.time()
    with axis_rules(rules, mesh):
        cell = build_cell(arch, shape, mesh, rules, profile=profile)
        jitted = jax.jit(
            cell.step_fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_stats = None
    if mem is not None:
        mem_stats = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
        }
    # CompiledMemoryStats on the CPU backend under-reports sharded args;
    # compute the static per-chip residency analytically from the actual
    # in_shardings: sum over args of bytes(arg) / num_devices(sharding).
    static_per_chip = 0.0
    for arg, sh in zip(
        jax.tree.leaves(cell.args),
        jax.tree.leaves(cell.in_shardings,
                        is_leaf=lambda x: hasattr(x, "num_devices")),
    ):
        n_shards = getattr(sh, "num_devices", chips)
        # NamedSharding: shard count = product of mesh axes used in spec
        try:
            shard_shape = sh.shard_shape(arg.shape)
            frac = 1.0
            for a, b in zip(shard_shape, arg.shape):
                frac *= a / max(b, 1)
        except Exception:
            frac = 1.0
        static_per_chip += float(np.prod(arg.shape) if arg.shape else 1) \
            * arg.dtype.itemsize * frac
    if mem_stats is None:
        mem_stats = {}
    mem_stats["static_args_per_chip_bytes"] = int(static_per_chip)
    mem_stats["fits_16gb_v5e_args"] = bool(static_per_chip < 16e9)
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    report = analyze(
        arch=arch_id, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost=cost, hlo_text=hlo, model_flops=cell.model_flops_per_step,
        memory_stats=mem_stats, notes=cell.notes,
    )
    rec = report.to_dict()
    rec.update({
        "status": "ok",
        "profile": profile,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "roofline_fraction": roofline_fraction(report),
        "hlo_bytes_len": len(hlo),
    })
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if profile == "baseline" else f"__{profile}"
    path = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if keep_hlo:
        with open(path.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=False)
    ap.add_argument("--shape", required=False)
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--profile", default="baseline",
                    choices=["baseline", "fsdp_ep", "fsdp_ep_remat",
                             "flash_remat", "a2a_emb"])
    args = ap.parse_args()

    if args.list:
        from repro.configs import get_arch, list_archs

        for a in list_archs():
            spec = get_arch(a)
            for s in spec.shapes:
                mark = " [SKIP: " + spec.skips[s] + "]" if s in spec.skips else ""
                print(f"{a:18s} {s}{mark}")
        return

    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.out,
                       args.keep_hlo, args.profile)
    except Exception:
        traceback.print_exc()
        rec = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "profile": args.profile,
            "status": "error", "error": traceback.format_exc()[-2000:],
        }
        os.makedirs(args.out, exist_ok=True)
        sfx = "" if args.profile == "baseline" else f"__{args.profile}"
        path = os.path.join(args.out, f"{args.arch}__{args.shape}__{args.mesh}{sfx}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        raise SystemExit(1)

    if rec.get("status") == "ok":
        print(json.dumps({k: rec[k] for k in (
            "arch", "shape", "mesh", "chips", "t_compute", "t_memory",
            "t_collective", "bottleneck", "roofline_fraction", "compile_s",
        )}, indent=1))
        if rec.get("memory_stats"):
            print("memory_analysis:", rec["memory_stats"])
        print("cost_analysis flops (per-chip):", rec["hlo_flops_global"] / rec["chips"])
    else:
        print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
