"""Fault-tolerant training driver.

  PYTHONPATH=src python -m repro.launch.train --arch deepfm --reduced \
      --steps 200 --batch 256 --ckpt-dir /tmp/ckpt --resume auto \
      --ckpt-every 50 [--fail-at-step 120] [--grad-compression int8_ef]

Features exercised end-to-end on CPU (and unchanged at scale):
  * auto-resume from the latest committed checkpoint;
  * failure injection (--fail-at-step raises mid-run; rerunning with
    --resume auto continues from the last commit — the restart test);
  * async atomic checkpointing every K steps;
  * int8 error-feedback gradient compression (optional);
  * straggler/heartbeat policies wired to (simulated) host reports;
  * cosine LR schedule, grad clipping, loss/throughput logging.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, latest_step, restore_checkpoint
from repro.configs import get_arch
from repro.data import lm_batches, random_graph, recsys_batches
from repro.distributed.fault_tolerance import HeartbeatMonitor, StragglerPolicy
from repro.models import gnn as gnn_mod
from repro.models import recsys as recsys_mod
from repro.models import transformer as tfm
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_warmup,
    ef_compress_grads,
    ef_init,
)


def build_family(arch_id: str, reduced: bool, batch: int, seq: int):
    spec = get_arch(arch_id)
    cfg = spec.reduced() if reduced else spec.config
    if spec.family == "lm":
        loss_fn = lambda p, b: tfm.train_loss(p, b, cfg)
        init_fn = lambda rng: tfm.init_params(rng, cfg)
        data = lm_batches(cfg.vocab, batch, seq, seed=0)
    elif spec.family == "recsys":
        loss_fn = lambda p, b: recsys_mod.bce_loss(p, b, cfg)
        init_fn = lambda rng: recsys_mod.init_params(rng, cfg)
        data = recsys_batches(cfg.vocab_sizes, batch, seed=0)
    else:
        g = random_graph(512, 2048, cfg.d_feat, cfg.n_vars, seed=0)
        const = {
            "node_feats": jnp.asarray(g.node_feats),
            "edges": jnp.asarray(g.edges),
            "targets": jnp.asarray(g.targets),
        }
        loss_fn = lambda p, b: gnn_mod.mse_loss(p, b, cfg)
        init_fn = lambda rng: gnn_mod.init_params(rng, cfg)

        def graph_gen():
            while True:
                yield const

        data = graph_gen()
    return spec, cfg, loss_fn, init_fn, data


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", choices=["auto", "none"], default="none")
    ap.add_argument("--fail-at-step", type=int, default=-1)
    ap.add_argument("--grad-compression", choices=["none", "int8_ef"], default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    spec, cfg, loss_fn, init_fn, data = build_family(
        args.arch, args.reduced, args.batch, args.seq
    )
    acfg = AdamWConfig(lr=args.lr)
    params = init_fn(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    ef = ef_init(params) if args.grad_compression == "int8_ef" else None
    start = 0

    if args.resume == "auto" and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, state = restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt}
        )
        params, opt = state["params"], state["opt"]
        print(f"resumed from step {start}")

    use_compression = args.grad_compression == "int8_ef"

    @jax.jit
    def step_fn(params, opt, ef_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if use_compression:
            grads, ef_state = ef_compress_grads(grads, ef_state)
        lr_scale = cosine_warmup(opt["step"], warmup=args.warmup, total=args.steps)
        params, opt, metrics = adamw_update(params, grads, opt, acfg, lr_scale)
        return params, opt, ef_state, {"loss": loss, **metrics}

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    hb = HeartbeatMonitor(n_hosts=jax.process_count(), timeout=300.0)
    straggle = StragglerPolicy()
    losses = []
    t_start = time.time()
    for s in range(start, args.steps):
        if s == args.fail_at_step:
            if ck:
                ck.wait()
            raise RuntimeError(f"injected failure at step {s} (restart test)")
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        t0 = time.time()
        params, opt, ef, metrics = step_fn(params, opt, ef, batch)
        dt = time.time() - t0
        hb.beat(jax.process_index())
        straggle.report(jax.process_index(), dt)
        losses.append(float(metrics["loss"]))
        if (s + 1) % args.log_every == 0:
            print(f"step {s+1}: loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        if ck and (s + 1) % args.ckpt_every == 0:
            ck.save_async(s + 1, {"params": params, "opt": opt})
    if ck:
        ck.save_async(args.steps, {"params": params, "opt": opt})
        ck.wait()
    wall = time.time() - t_start
    summary = {
        "arch": args.arch,
        "steps_run": args.steps - start,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "wall_s": round(wall, 2),
        "stragglers": straggle.stragglers(),
        "dead_hosts": hb.dead_hosts(),
    }
    print(json.dumps(summary))
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"losses": losses, **summary}, f)
    return summary


if __name__ == "__main__":
    main()
