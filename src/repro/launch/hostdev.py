"""Forcing the host (CPU) device count — kept jax-import-free.

XLA pins the host device count at first jax init, so the flag must be
in the environment before any jax import: set it in a parent process's
subprocess env, or at the very top of a ``main()`` whose module never
imports jax at module level (the ``repro.launch.dryrun`` contract).
"""
from __future__ import annotations

_FLAG = "--xla_force_host_platform_device_count"


def force_host_device_flags(flags: str, devices: int) -> str:
    """Return ``flags`` with any existing device-count flag replaced."""
    kept = [f for f in flags.split() if not f.startswith(_FLAG)]
    return " ".join(kept + [f"{_FLAG}={devices}"])
