"""Named counters / gauges / histograms with label sets.

The registry is the repo's one metrics substrate: the serving router's
``RouterStats`` is a view over it, the dispatch telemetry
(``repro.obs.dispatch``) counts kernel-path decisions and marginal
evaluations into it, and the compile monitor counts jit cache misses
into it.  Two exports:

* :meth:`MetricsRegistry.snapshot` — a JSON-able dict (what
  ``serve_router --metrics-out`` and ``BENCH_<fig>.json`` write);
* :meth:`MetricsRegistry.expose` — Prometheus text exposition, one
  sample line per label set, so a scrape endpoint is a two-liner.

Metrics are plain dict arithmetic under the GIL — cheap enough to stay
always-on inside the router (its stats were always on), and zero-cost
for everything else when no registry is installed (see ``repro.obs``).
Counters are monotonic; gauges hold the last set value; histograms keep
cumulative bucket counts plus sum/count (mean = sum/count).
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple

# Generic latency buckets (seconds), spanning ~100us host phases to
# multi-second drains; +Inf is implicit.
DEFAULT_BUCKETS = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5,
)

_LabelKey = Tuple[Tuple[str, str], ...]


def _key(labels: dict) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _key_str(key: _LabelKey) -> str:
    return ",".join(f"{k}={v}" for k, v in key)


def _prom_labels(key: _LabelKey, extra: Iterable[Tuple[str, str]] = ()) -> str:
    items = list(key) + list(extra)
    if not items:
        return ""
    body = ",".join(
        f'{k}="{v.replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
        for k, v in items
    )
    return "{" + body + "}"


class Counter:
    """Monotonic counter, one value per label set."""

    kind = "counter"
    __slots__ = ("name", "help", "_vals")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._vals: Dict[_LabelKey, float] = {}

    def inc(self, value: float = 1, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name} cannot decrease ({value})")
        k = _key(labels)
        self._vals[k] = self._vals.get(k, 0) + value

    def value(self, **labels) -> float:
        return self._vals.get(_key(labels), 0)

    def total(self) -> float:
        """Sum over every label set."""
        return sum(self._vals.values())

    def _snapshot(self):
        return {_key_str(k): v for k, v in self._vals.items()}

    def _expose(self):
        for k, v in sorted(self._vals.items()):
            yield f"{self.name}{_prom_labels(k)} {v}"


class Gauge:
    """Last-set value, one per label set."""

    kind = "gauge"
    __slots__ = ("name", "help", "_vals")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._vals: Dict[_LabelKey, float] = {}

    def set(self, value: float, **labels) -> None:
        self._vals[_key(labels)] = value

    def inc(self, value: float = 1, **labels) -> None:
        k = _key(labels)
        self._vals[k] = self._vals.get(k, 0) + value

    def value(self, **labels) -> float:
        return self._vals.get(_key(labels), 0)

    def _snapshot(self):
        return {_key_str(k): v for k, v in self._vals.items()}

    def _expose(self):
        for k, v in sorted(self._vals.items()):
            yield f"{self.name}{_prom_labels(k)} {v}"


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics) per label set."""

    kind = "histogram"
    __slots__ = ("name", "help", "buckets", "_vals")

    def __init__(self, name: str, help: str = "",
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        # per label set: [per-bucket counts (+Inf last), sum, count]
        self._vals: Dict[_LabelKey, list] = {}

    def _cell(self, labels) -> list:
        k = _key(labels)
        cell = self._vals.get(k)
        if cell is None:
            cell = [[0] * (len(self.buckets) + 1), 0.0, 0]
            self._vals[k] = cell
        return cell

    def observe(self, value: float, **labels) -> None:
        cell = self._cell(labels)
        counts, _, _ = cell
        for i, ub in enumerate(self.buckets):
            if value <= ub:
                counts[i] += 1
                break
        else:
            counts[-1] += 1
        cell[1] += value
        cell[2] += 1

    def sum(self, **labels) -> float:
        cell = self._vals.get(_key(labels))
        return cell[1] if cell else 0.0

    def count(self, **labels) -> int:
        cell = self._vals.get(_key(labels))
        return cell[2] if cell else 0

    def mean(self, **labels) -> float:
        cell = self._vals.get(_key(labels))
        return cell[1] / cell[2] if cell and cell[2] else 0.0

    def _snapshot(self):
        out = {}
        for k, (counts, s, n) in self._vals.items():
            cum, buckets = 0, {}
            for ub, c in zip(self.buckets, counts):
                cum += c
                buckets[repr(ub)] = cum
            buckets["+Inf"] = cum + counts[-1]
            out[_key_str(k)] = {"sum": s, "count": n, "buckets": buckets}
        return out

    def _expose(self):
        for k, (counts, s, n) in sorted(self._vals.items()):
            cum = 0
            for ub, c in zip(self.buckets, counts):
                cum += c
                yield (f"{self.name}_bucket"
                       f"{_prom_labels(k, [('le', repr(ub))])} {cum}")
            yield (f"{self.name}_bucket"
                   f"{_prom_labels(k, [('le', '+Inf')])} {cum + counts[-1]}")
            yield f"{self.name}_sum{_prom_labels(k)} {s}"
            yield f"{self.name}_count{_prom_labels(k)} {n}"


class MetricsRegistry:
    """Get-or-create home for named metrics.

    ``counter`` / ``gauge`` / ``histogram`` return the existing metric
    when the name is already registered (and raise if it is registered
    as a different kind), so call sites never coordinate registration.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, cls, name: str, help: str, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, help, **kw)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise ValueError(
                f"metric {name!r} already registered as a {m.kind}, "
                f"requested as a {cls.kind}"
            )
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Tuple[float, ...]] = None) -> Histogram:
        if buckets is None:
            return self._get(Histogram, name, help)
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        """The registered metric, or None."""
        return self._metrics.get(name)

    def names(self):
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-able snapshot: ``{kind: {name: {label_str: value}}}``
        (histogram values are ``{sum, count, buckets}`` dicts)."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(self._metrics.items()):
            out[m.kind + "s"][name] = m._snapshot()
        return out

    def expose(self) -> str:
        """Prometheus text exposition (one HELP/TYPE header + one line
        per label set per metric)."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            lines.extend(m._expose())
        return "\n".join(lines) + "\n"
