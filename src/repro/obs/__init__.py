"""Zero-dependency observability: span tracing, a metrics registry, and
dispatch/compile telemetry.

Three pillars (see DESIGN.md §8 for the span taxonomy and metric names):

* ``repro.obs.trace``    — nestable spans, ring-buffered, exported as
  Chrome ``trace_event`` JSON (Perfetto-loadable), optional
  ``jax.profiler.TraceAnnotation`` bridge;
* ``repro.obs.metrics``  — named counters/gauges/histograms with label
  sets, Prometheus text exposition + JSON snapshot;
* ``repro.obs.dispatch`` — which kernel path actually ran, launched
  steps / marginal-evaluation counts, and jit cache misses observed
  through ``jax.monitoring``.

**Off by default, near-zero when off.**  The module holds one
process-global session (``_ACTIVE``); every hook in the hot paths is a
single global read when no session is installed — ``span()`` returns a
shared no-op singleton (no allocation), ``inc``/``gauge_set``/
``observe`` return immediately.  Enable it:

    from repro import obs

    with obs.session(obs.ObsConfig(enabled=True)):
        ...                                  # scoped
    obs.enable(obs.ObsConfig(enabled=True))  # or process-wide

or thread an ``ObsConfig`` through the serving configs —
``DPPRerankConfig(obs=...)`` / ``RouterConfig(obs=...)`` install it
when the ``Reranker``/router is constructed, and
``repro.launch.serve_router --trace-out trace.json --metrics-out
metrics.json`` surfaces both exports from the CLI.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
)
from repro.obs.trace import SpanTracer, validate_chrome_trace  # noqa: F401


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """What to observe.  ``enabled=False`` (the default) is a hard off
    switch: nothing is installed and every hook is a cheap no-op."""

    enabled: bool = False
    trace: bool = True  # span tracer
    metrics: bool = True  # metrics registry
    compile_monitor: bool = True  # jit cache-miss counting (needs metrics)
    ring_size: int = 65536  # span ring buffer capacity
    jax_annotations: bool = False  # bridge spans to jax.profiler

    def __post_init__(self):
        if self.ring_size < 1:
            raise ValueError(
                f"ring_size must be >= 1, got {self.ring_size}"
            )


class Obs:
    """One installed observability session (tracer + registry +
    compile monitor, each optional per :class:`ObsConfig`)."""

    def __init__(self, config: ObsConfig):
        self.config = config
        self.tracer = (
            SpanTracer(config.ring_size, config.jax_annotations)
            if config.trace else None
        )
        self.registry = MetricsRegistry() if config.metrics else None
        self.compile_monitor = None
        if config.compile_monitor and self.registry is not None:
            from repro.obs.dispatch import CompileMonitor

            self.compile_monitor = CompileMonitor(self.registry).install()

    def close(self) -> None:
        if self.compile_monitor is not None:
            self.compile_monitor.uninstall()


_ACTIVE: Optional[Obs] = None


class _NullSpan:
    """The disabled-path span: one shared instance, no state."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs):
        return self


NULL_SPAN = _NullSpan()


def enable(config: Optional[ObsConfig] = None) -> Optional[Obs]:
    """Install a process-global observability session and return it.

    ``None`` defaults to everything on.  A config with
    ``enabled=False`` is a no-op returning None (so callers can thread
    user configs through unconditionally).  If a session is already
    installed it is kept and returned — ``disable()`` first to replace
    it.
    """
    global _ACTIVE
    if config is None:
        config = ObsConfig(enabled=True)
    if not config.enabled:
        return None
    if _ACTIVE is None:
        _ACTIVE = Obs(config)
    return _ACTIVE


def disable() -> None:
    """Tear down the global session (hooks go back to no-ops)."""
    global _ACTIVE
    if _ACTIVE is not None:
        _ACTIVE.close()
        _ACTIVE = None


def enabled() -> bool:
    return _ACTIVE is not None


def active() -> Optional[Obs]:
    return _ACTIVE


def tracer() -> Optional[SpanTracer]:
    a = _ACTIVE
    return a.tracer if a is not None else None


def registry() -> Optional[MetricsRegistry]:
    a = _ACTIVE
    return a.registry if a is not None else None


def compile_monitor():
    a = _ACTIVE
    return a.compile_monitor if a is not None else None


@contextlib.contextmanager
def session(config: Optional[ObsConfig] = None):
    """Scoped ``enable``/``disable`` (no-op if a session already runs,
    or if ``config.enabled`` is False)."""
    installed = _ACTIVE is None and enable(config) is not None
    try:
        yield _ACTIVE
    finally:
        if installed:
            disable()


# ---------------------------------------------------------------------------
# Hot-path hooks (all a single global read when disabled)
# ---------------------------------------------------------------------------


def span(name: str, **attrs):
    """A tracer span, or the shared no-op singleton when tracing is off
    — the hot path allocates nothing while disabled."""
    a = _ACTIVE
    if a is None or a.tracer is None:
        return NULL_SPAN
    return a.tracer.span(name, **attrs)


def inc(name: str, value: float = 1, **labels) -> None:
    a = _ACTIVE
    if a is None or a.registry is None:
        return
    a.registry.counter(name).inc(value, **labels)


def gauge_set(name: str, value: float, **labels) -> None:
    a = _ACTIVE
    if a is None or a.registry is None:
        return
    a.registry.gauge(name).set(value, **labels)


def observe(name: str, value: float, **labels) -> None:
    a = _ACTIVE
    if a is None or a.registry is None:
        return
    a.registry.histogram(name).observe(value, **labels)


__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_SPAN",
    "Obs",
    "ObsConfig",
    "SpanTracer",
    "active",
    "compile_monitor",
    "disable",
    "enable",
    "enabled",
    "gauge_set",
    "inc",
    "observe",
    "registry",
    "session",
    "span",
    "tracer",
    "validate_chrome_trace",
]
