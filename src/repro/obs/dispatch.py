"""Dispatch and compile telemetry.

Two halves:

* **Compile monitoring** — :class:`CompileMonitor` counts XLA backend
  compiles (jit cache misses) and tracing passes through
  ``jax.monitoring``: jax emits a
  ``/jax/core/compile/backend_compile_duration`` duration event for
  every computation it actually compiles and *nothing* for a cache
  hit, so ``jit_cache_misses_total`` is a direct observation, not an
  inference.  ``mark()`` / ``since_mark()`` bracket a warmup: "the
  router never re-jits" becomes ``since_mark() == 0`` after the slot
  geometry compiled once, while per-k serial streaming shows >= 1 miss
  per distinct k (the fig8 gate and the acceptance criterion).

  jax only exposes process-global listeners (and only a clear-all), so
  one forwarder pair is registered once per process and routes events
  to whichever monitor is currently installed (none -> no-op).

* **Dispatch recording** — small helpers the greedy dispatch layers
  call to count *which path actually ran*: the kernel execution mode
  ``ops.py`` picked (jnp / resident / tiled and the ``TilePolicy``
  tile/VMEM numbers behind it), the backend ``greedy_map`` routed to,
  and the launched work in greedy steps and per-step marginal
  evaluations (each greedy step updates and argmaxes over M candidate
  marginals; lazy/stochastic greedy variants exist to shrink exactly
  this number, so it is recorded rather than inferred).  All helpers
  no-op (one global read) when observability is disabled, and consume
  only static shapes/config — they are safe inside traced code and
  count one dispatch per trace, not per device replay.

Metric names are documented in DESIGN.md §8.
"""
from __future__ import annotations

from typing import Optional

import repro.obs as _obs

# one process-global forwarder pair; jax.monitoring has no per-listener
# deregistration, so the active monitor is swapped under these instead
_ACTIVE_MONITOR: Optional["CompileMonitor"] = None
_LISTENERS_REGISTERED = False

_BACKEND_COMPILE = "backend_compile"
_TRACE = "jaxpr_trace"


def _forward_event(event: str, **kw) -> None:
    m = _ACTIVE_MONITOR
    if m is not None:
        m._on_event(event)


def _forward_duration(event: str, duration: float, **kw) -> None:
    m = _ACTIVE_MONITOR
    if m is not None:
        m._on_duration(event, duration)


def _ensure_listeners() -> None:
    global _LISTENERS_REGISTERED
    if _LISTENERS_REGISTERED:
        return
    import jax.monitoring

    jax.monitoring.register_event_listener(_forward_event)
    jax.monitoring.register_event_duration_secs_listener(_forward_duration)
    _LISTENERS_REGISTERED = True


class CompileMonitor:
    """Counts jit cache misses (XLA backend compiles) into a registry.

    Counters:

    * ``jit_cache_misses_total`` — backend compiles observed;
    * ``jit_compile_seconds_total`` — wall seconds spent in them;
    * ``jit_traces_total`` — jaxpr tracing passes (re-traces that hit
      the compile cache still show up here).
    """

    def __init__(self, registry):
        self.registry = registry
        self._misses = registry.counter(
            "jit_cache_misses_total",
            "XLA backend compiles observed via jax.monitoring "
            "(a cached jit call emits none)",
        )
        self._secs = registry.counter(
            "jit_compile_seconds_total", "wall seconds spent compiling"
        )
        self._traces = registry.counter(
            "jit_traces_total", "jaxpr tracing passes"
        )
        self._mark = 0.0

    def install(self) -> "CompileMonitor":
        global _ACTIVE_MONITOR
        _ensure_listeners()
        _ACTIVE_MONITOR = self
        return self

    def uninstall(self) -> None:
        global _ACTIVE_MONITOR
        if _ACTIVE_MONITOR is self:
            _ACTIVE_MONITOR = None

    def _on_event(self, event: str) -> None:
        pass  # compile facts arrive as duration events; nothing to count

    def _on_duration(self, event: str, duration: float) -> None:
        if _BACKEND_COMPILE in event:
            self._misses.inc()
            self._secs.inc(duration)
        elif _TRACE in event:
            self._traces.inc()

    # -- warmup bracketing ---------------------------------------------------

    def misses(self) -> float:
        return self._misses.value()

    def mark(self) -> None:
        """Remember the current miss count (call when warmup is done)."""
        self._mark = self._misses.value()

    def since_mark(self) -> float:
        """Misses since :meth:`mark` — 0 proves a serving loop ran
        entirely on cached computations."""
        return self._misses.value() - self._mark


# ---------------------------------------------------------------------------
# Dispatch recording (called by core/dispatch, core/streaming, kernel ops)
# ---------------------------------------------------------------------------


def record_kernel_dispatch(
    mode: str,
    *,
    D: int,
    M: int,
    state_rows: int,
    windowed: bool,
    tile_m: Optional[int] = None,
    vmem_bytes: Optional[int] = None,
) -> None:
    """One ``ops.py`` execution-mode decision: which kernel path won
    (``jnp`` / ``resident`` / ``tiled`` / ``fused_chunk``) and the
    ``TilePolicy`` numbers behind it."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.counter(
        "dpp_kernel_dispatch_total", "kernel execution modes chosen by ops.py"
    ).inc(mode=mode, windowed=str(bool(windowed)))
    g = reg.gauge(
        "dpp_tile_m", "candidate-axis tile of the last tiled dispatch (0 = "
        "whole-M resident)"
    )
    g.set(0 if tile_m is None else tile_m)
    if vmem_bytes is not None:
        reg.gauge(
            "dpp_vmem_bytes_est",
            "TilePolicy VMEM working-set estimate of the last dispatch",
        ).set(vmem_bytes)


def record_tile_resolution(source: str) -> None:
    """Which source won one tile_m precedence resolution in ``ops.py``
    (``env`` > ``explicit`` int > requested ``auto`` > ``model``;
    ``policy`` = an explicit TilePolicy object bypassed the ladder)."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.counter(
        "dpp_tile_source_total",
        "tile_m precedence winners by source "
        "(env/explicit/auto/model/policy)",
    ).inc(source=source)


def record_tile_override(winner: str, lost: str) -> None:
    """A tile_m request that *lost* the precedence resolution (e.g. a
    call-site ``tile_m=`` shadowed by the ``DPP_TILE_M`` env override) —
    recorded instead of silently ignored."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.counter(
        "dpp_tile_override_total",
        "tile_m requests shadowed by a higher-precedence source",
    ).inc(winner=winner, lost=lost)


def record_autotune_lookup(
    outcome: str, *, reason: str = "", tile_m: Optional[int] = None
) -> None:
    """One ``tile_m=\"auto\"`` cache consultation: an ``exact`` or
    nearest-``bucket`` hit (with the chosen geometry), or a ``miss``
    with its reason (empty/corrupt/no_entry/error) — the miss falls
    back to the analytical VMEM model, never an error."""
    reg = _obs.registry()
    if reg is None:
        return
    if outcome in ("exact", "bucket"):
        reg.counter(
            "autotune_cache_hits_total",
            "tile_m='auto' lookups that produced a measured tile",
        ).inc(kind=outcome)
        if tile_m is not None:
            reg.gauge(
                "autotune_tile_m",
                "tile chosen by the last autotune cache hit",
            ).set(tile_m)
    else:
        reg.counter(
            "autotune_cache_misses_total",
            "tile_m='auto' lookups that fell back to the VMEM model",
        ).inc(reason=reason or "unknown")


def record_greedy_map(backend: str, *, B: int, k: int, M: int,
                      chunked: bool = False) -> None:
    """One whole-slate ``greedy_map`` dispatch.  Launched work (steps,
    marginal evaluations) is counted here for unchunked runs; chunked
    runs count it per chunk in :func:`record_chunk` instead."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.counter(
        "greedy_dispatch_total", "greedy_map dispatches by backend"
    ).inc(backend=backend, chunked=str(bool(chunked)))
    if not chunked:
        _count_steps(reg, backend, B * k, B * k * M)


def record_chunk(backend: str, *, B: int, chunk: int, M: int) -> None:
    """One resumable chunk launch: ``B`` lanes x ``chunk`` greedy steps
    over ``M`` candidate columns."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.counter(
        "greedy_chunks_total", "resumable chunk launches by backend"
    ).inc(backend=backend)
    _count_steps(reg, backend, B * chunk, B * chunk * M)


def record_session_delta(op: str, *, w: int, dm: int) -> None:
    """One session delta update (``extend`` / ``rescore`` / ``rebuild``):
    ``dm`` candidate columns re-solved against a ``w``-row window —
    O(w * dm) device work where a from-scratch rerank would pay
    O(k * M)."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.counter(
        "session_deltas_total", "session delta updates by op"
    ).inc(op=op)
    reg.counter(
        "session_delta_cols_total",
        "candidate columns re-solved by session delta updates",
    ).inc(dm, op=op)


def record_session_evict(resident_bytes: int, *, evicted: int = 1) -> None:
    """``evicted`` sessions dropped to the LRU byte budget;
    ``resident_bytes`` is the store's device footprint *after* the
    eviction (also exported on every resume via
    :func:`record_session_resident`)."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.counter(
        "session_evictions_total",
        "session states dropped by the LRU byte budget",
    ).inc(evicted)
    reg.gauge(
        "session_resident_bytes",
        "device bytes held by resident session states",
    ).set(resident_bytes)


def record_session_resident(resident_bytes: int, *, sessions: int) -> None:
    """Current store footprint: ``sessions`` resident states holding
    ``resident_bytes`` on device."""
    reg = _obs.registry()
    if reg is None:
        return
    reg.gauge(
        "session_resident_bytes",
        "device bytes held by resident session states",
    ).set(resident_bytes)
    reg.gauge(
        "session_resident_count", "resident session states"
    ).set(sessions)


def _count_steps(reg, backend: str, steps: int, evals: int) -> None:
    reg.counter(
        "greedy_steps_total", "greedy steps launched (padded/parked lanes "
        "included — this is device work, not delivered selections)"
    ).inc(steps, backend=backend)
    reg.counter(
        "marginal_evals_total", "candidate marginals evaluated: every "
        "launched step updates and argmaxes M candidate gains (the count "
        "lazy-greedy variants exist to shrink)"
    ).inc(evals, backend=backend)
