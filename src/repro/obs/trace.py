"""Nestable span tracing with Chrome ``trace_event`` export.

``SpanTracer.span("router.pump", **attrs)`` is a context manager that
records one complete span — monotonic start/end (``perf_counter_ns``)
plus a wall-clock anchor so absolute timestamps can be reconstructed —
into a bounded in-process ring buffer.  Export with
:meth:`SpanTracer.export_chrome` / :meth:`SpanTracer.write_chrome`:
the output is the Chrome ``trace_event`` JSON array format
(``{"traceEvents": [...]}`` with ``"ph": "X"`` complete events), which
Perfetto and ``chrome://tracing`` load directly; span nesting is
reconstructed by the viewer from ts/dur containment per thread.

With ``jax_annotations=True`` every span also enters a
``jax.profiler.TraceAnnotation`` of the same name, so when a device
profile is being captured (``jax.profiler.trace``), the host spans
line up with the device timeline in the same viewer.

Recording is append-of-a-tuple cheap; the dict/JSON work happens at
export.  When tracing is disabled the tracer is never constructed at
all — ``repro.obs.span`` returns a shared no-op (see ``repro.obs``).
"""
from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Optional


class Span:
    """One in-flight span; records itself into the tracer on exit."""

    __slots__ = ("_tracer", "name", "attrs", "_start_ns", "_ann")

    def __init__(self, tracer: "SpanTracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._start_ns = 0
        self._ann = None

    def set(self, **attrs) -> "Span":
        """Attach/overwrite key-value attributes (shown as Chrome
        ``args``)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        if self._tracer._annotate is not None:
            self._ann = self._tracer._annotate(self.name)
            self._ann.__enter__()
        self._start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        end_ns = time.perf_counter_ns()
        if self._ann is not None:
            self._ann.__exit__(exc_type, exc, tb)
        self._tracer._record(self.name, self._start_ns, end_ns, self.attrs)
        return False


class SpanTracer:
    """Ring-buffered span recorder with Chrome trace_event export.

    ring_size bounds memory: the buffer keeps the newest ``ring_size``
    spans and counts what it dropped (``dropped``).
    """

    def __init__(self, ring_size: int = 65536, jax_annotations: bool = False):
        if ring_size < 1:
            raise ValueError(f"ring_size must be >= 1, got {ring_size}")
        self.ring_size = ring_size
        self._events = deque(maxlen=ring_size)
        self._total = 0
        self._t0_ns = time.perf_counter_ns()
        self._wall0 = time.time()
        self._pid = os.getpid()
        self._tids: dict = {}
        self._annotate = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation

                self._annotate = TraceAnnotation
            except Exception:  # profiler unavailable: spans still record
                self._annotate = None

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    # -- recording -----------------------------------------------------------

    def _record(self, name: str, start_ns: int, end_ns: int, attrs: dict):
        tid = threading.get_ident()
        small = self._tids.get(tid)
        if small is None:
            small = self._tids[tid] = len(self._tids)
        self._events.append((name, start_ns, end_ns, small, attrs))
        self._total += 1

    # -- introspection -------------------------------------------------------

    def __len__(self) -> int:
        return len(self._events)

    @property
    def total(self) -> int:
        """Spans recorded over the tracer's lifetime (including dropped)."""
        return self._total

    @property
    def dropped(self) -> int:
        return self._total - len(self._events)

    def finished(self):
        """The buffered spans as dicts: ``name``, ``start_us`` / ``dur_us``
        (monotonic, relative to the tracer origin), ``wall_ts`` (epoch
        seconds), ``tid``, ``attrs`` — the in-process view fig8 reads."""
        out = []
        for name, s, e, tid, attrs in list(self._events):
            out.append({
                "name": name,
                "start_us": (s - self._t0_ns) / 1e3,
                "dur_us": (e - s) / 1e3,
                "wall_ts": self._wall0 + (s - self._t0_ns) / 1e9,
                "tid": tid,
                "attrs": attrs,
            })
        return out

    # -- Chrome trace_event export -------------------------------------------

    def export_chrome(self, process_name: str = "repro-divdpp") -> dict:
        """The buffered spans as a Chrome ``trace_event`` JSON object
        (Perfetto-loadable): complete ``"ph": "X"`` events with ``ts`` /
        ``dur`` in microseconds, attributes under ``args``."""
        events = [{
            "name": "process_name", "ph": "M", "pid": self._pid, "tid": 0,
            "args": {"name": process_name},
        }]
        for name, s, e, tid, attrs in list(self._events):
            ev = {
                "name": name,
                "cat": "repro",
                "ph": "X",
                "ts": (s - self._t0_ns) / 1e3,
                "dur": (e - s) / 1e3,
                "pid": self._pid,
                "tid": tid,
            }
            args = dict(attrs)
            args["wall_ts"] = self._wall0 + (s - self._t0_ns) / 1e9
            ev["args"] = args
            events.append(ev)
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "wall_time_origin": self._wall0,
                "monotonic_origin_ns": self._t0_ns,
                "spans_total": self._total,
                "spans_dropped": self.dropped,
            },
        }

    def write_chrome(self, path: str, process_name: str = "repro-divdpp"):
        """Write :meth:`export_chrome` JSON to ``path`` (load it in
        https://ui.perfetto.dev or ``chrome://tracing``)."""
        with open(path, "w") as f:
            # default=str: attrs are caller-supplied and may hold opaque
            # rids — stringify rather than crash the exporter
            json.dump(self.export_chrome(process_name), f, default=str)


def validate_chrome_trace(doc: dict) -> Optional[str]:
    """Schema check for an exported trace: returns None when valid, else
    a description of the first violation.  Used by fig8's --smoke gate
    and the round-trip test."""
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return "missing traceEvents"
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return "traceEvents is not a list"
    for i, ev in enumerate(evs):
        for field in ("name", "ph", "pid", "tid"):
            if field not in ev:
                return f"event {i} missing {field!r}"
        if ev["ph"] == "X":
            if not isinstance(ev.get("ts"), (int, float)):
                return f"event {i} has non-numeric ts"
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                return f"event {i} has bad dur"
            if not isinstance(ev.get("args", {}), dict):
                return f"event {i} args is not a dict"
    return None
