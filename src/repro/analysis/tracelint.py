"""AST trace-safety lint (rules trace-cast, trace-pyif, host-sync-hot,
obs-nonstatic, dead-shim).

Scopes considered *traced*: functions recognised as jitted by
``astutil.jit_statics`` / module-level ``jax.jit(fn, ...)`` bindings,
Pallas kernel bodies (``*_ref`` parameters), and functions nested
inside either (their parameters are traced carry values).  Inside a
traced scope, names proven host-valued by :class:`astutil.StaticNames`
(statics, shapes, ``is None`` checks ...) are exempt; everything else
is presumed traced.
"""
from __future__ import annotations

import ast

from repro.analysis.astutil import (
    TracedNames,
    _target_names,
    dotted_name,
    is_kernel_fn,
    jit_call_assignments,
    jit_statics,
    param_names,
)
from repro.analysis.findings import Finding

# --- dead-shim registry (PR-6 serving surface, removed this release) ---
REMOVED_IMPORTS: dict[str, frozenset[str]] = {
    "repro.serving": frozenset({
        "rerank", "rerank_batch", "rerank_stream",
        "sharded_rerank", "sharded_rerank_stream",
    }),
    "repro.serving.reranker": frozenset({
        "rerank", "rerank_batch", "rerank_stream", "_deprecated",
    }),
    "repro.serving.sharded_rerank": frozenset({
        "sharded_rerank", "sharded_rerank_stream",
    }),
}
# attribute form: `import repro.serving as serving; serving.rerank(...)`
_REMOVED_DOTTED = frozenset(
    f"{prefix}.{name}"
    for prefix in ("serving", "repro.serving")
    for name in REMOVED_IMPORTS["repro.serving"]
)

_HOST_SYNC_FUNCS = frozenset({
    "np.asarray", "numpy.asarray", "np.array", "numpy.array",
    "jax.device_get", "jax.block_until_ready",
})
_HOST_SYNC_ATTRS = frozenset({"block_until_ready", "item", "tolist"})
# pump phases that exist to pay the sync cost, by span-name suffix
_SYNC_SPAN_SUFFIXES = (".sync", ".materialize")

_DEVICE_PREFIXES = ("jnp.", "jax.", "np.", "numpy.")


def check_module(path: str, tree: ast.Module) -> list[Finding]:
    findings: list[Finding] = []
    _check_dead_shims(path, tree, findings)
    _check_obs_callsites(path, tree, findings)

    jit_assigned = {name: statics for name, statics, _ in
                    jit_call_assignments(tree)}
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        statics = jit_statics(node)
        if statics is None and node.name in jit_assigned:
            statics = jit_assigned[node.name]
        kernel = is_kernel_fn(node)
        if statics is None and not kernel:
            continue
        if kernel:
            # the *_ref Refs are the traced operands; scalar params
            # (bound via functools.partial) are static
            traced = {a for a in param_names(node) if a.endswith("_ref")}
        else:
            traced = param_names(node) - set(statics)
        _scan_traced_scope(path, node, traced, findings)

    for node in ast.walk(tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name == "pump"):
            _check_pump_syncs(path, node, findings)
    return findings


# --------------------------------------------------------------------------
# trace-cast / trace-pyif
# --------------------------------------------------------------------------


def _scan_traced_scope(
    path: str, fn: ast.FunctionDef, traced: set[str],
    findings: list[Finding],
) -> None:
    sn = TracedNames(traced)

    def check_casts(node: ast.AST) -> None:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            callee = dotted_name(sub.func)
            if (callee in ("float", "int", "bool")
                    and len(sub.args) == 1 and not sub.keywords
                    and sn.is_traced(sub.args[0])):
                findings.append(Finding(
                    path, sub.lineno, "trace-cast",
                    f"{callee}() on a traced value inside traced scope "
                    f"{fn.name!r} — concretizes the tracer (use jnp "
                    f"ops, or hoist to the host side)",
                ))
            elif (isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in ("item", "tolist")
                    and not sub.args
                    and sn.is_traced(sub.func.value)):
                findings.append(Finding(
                    path, sub.lineno, "trace-cast",
                    f".{sub.func.attr}() on a traced value inside "
                    f"traced scope {fn.name!r}",
                ))

    def scan(stmts: list[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                # nested def: a traced closure — its params receive
                # traced carry values, its free vars keep their taint
                for dec in st.decorator_list:
                    check_casts(dec)
                _scan_traced_scope(
                    path, st, set(sn.names) | param_names(st), findings
                )
                continue
            if isinstance(st, ast.ClassDef):
                continue
            if isinstance(st, (ast.If, ast.While)):
                check_casts(st.test)
                if sn.is_traced(st.test):
                    findings.append(Finding(
                        path, st.lineno, "trace-pyif",
                        f"Python `{'if' if isinstance(st, ast.If) else 'while'}`"
                        f" on a traced value inside traced scope "
                        f"{fn.name!r} — use lax.cond/jnp.where",
                    ))
                scan(st.body)
                scan(st.orelse)
            elif isinstance(st, ast.For):
                check_casts(st.iter)
                if sn.is_traced(st.iter):
                    for name in _target_names(st.target):
                        sn.names.add(name)
                scan(st.body)
                scan(st.orelse)
            elif isinstance(st, ast.With):
                for item in st.items:
                    check_casts(item.context_expr)
                scan(st.body)
            elif isinstance(st, ast.Try):
                scan(st.body)
                for handler in st.handlers:
                    scan(handler.body)
                scan(st.orelse)
                scan(st.finalbody)
            else:
                check_casts(st)
                sn.observe_assign(st)

    scan(fn.body)


# --------------------------------------------------------------------------
# host-sync-hot
# --------------------------------------------------------------------------


def _is_sync_call(node: ast.Call) -> bool:
    callee = dotted_name(node.func)
    if callee in _HOST_SYNC_FUNCS:
        return True
    return (isinstance(node.func, ast.Attribute)
            and node.func.attr in _HOST_SYNC_ATTRS)


def _span_name(item: ast.withitem) -> str | None:
    ctx = item.context_expr
    if (isinstance(ctx, ast.Call) and isinstance(ctx.func, ast.Attribute)
            and ctx.func.attr == "span" and ctx.args
            and isinstance(ctx.args[0], ast.Constant)
            and isinstance(ctx.args[0].value, str)):
        return ctx.args[0].value
    return None


def _check_pump_syncs(
    path: str, fn: ast.FunctionDef, findings: list[Finding]
) -> None:
    """Inside a router ``pump()`` the only phases allowed to touch the
    host are the designated ``*.sync`` / ``*.materialize`` spans — a
    stray ``np.asarray``/``block_until_ready`` anywhere else serializes
    the double-buffered pipeline."""

    def scan_flat(stmts: list[ast.stmt], allowed: bool) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue
            if isinstance(st, ast.With):
                inner = allowed
                for item in st.items:
                    name = _span_name(item)
                    if name and name.endswith(_SYNC_SPAN_SUFFIXES):
                        inner = True
                    if not allowed:
                        _flag_syncs(item.context_expr)
                scan_flat(st.body, inner)
            elif isinstance(st, (ast.If, ast.While)):
                if not allowed:
                    _flag_syncs(st.test)
                scan_flat(st.body, allowed)
                scan_flat(st.orelse, allowed)
            elif isinstance(st, ast.For):
                if not allowed:
                    _flag_syncs(st.iter)
                scan_flat(st.body, allowed)
                scan_flat(st.orelse, allowed)
            elif isinstance(st, ast.Try):
                scan_flat(st.body, allowed)
                for handler in st.handlers:
                    scan_flat(handler.body, allowed)
                scan_flat(st.orelse, allowed)
                scan_flat(st.finalbody, allowed)
            elif not allowed:
                _flag_syncs(st)

    def _flag_syncs(node: ast.AST) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call) and _is_sync_call(sub):
                findings.append(Finding(
                    path, sub.lineno, "host-sync-hot",
                    "host sync in pump() outside a *.sync/"
                    "*.materialize span — serializes the "
                    "double-buffered pump",
                ))

    scan_flat(fn.body, False)


# --------------------------------------------------------------------------
# obs-nonstatic
# --------------------------------------------------------------------------


def _check_obs_callsites(
    path: str, tree: ast.Module, findings: list[Finding]
) -> None:
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "span"):
            continue
        owner = dotted_name(node.func.value) or ""
        if "obs" not in owner.split("."):
            continue
        for value in list(node.args) + [kw.value for kw in node.keywords]:
            for sub in ast.walk(value):
                if not isinstance(sub, ast.Call):
                    continue
                callee = dotted_name(sub.func) or ""
                device = callee.startswith(_DEVICE_PREFIXES) or (
                    isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in _HOST_SYNC_ATTRS
                )
                if device:
                    findings.append(Finding(
                        path, sub.lineno, "obs-nonstatic",
                        f"device work ({callee or sub.func.attr}) in an "
                        f"obs.span(...) argument — hook arguments run "
                        f"even when tracing is off; pass host scalars",
                    ))


# --------------------------------------------------------------------------
# dead-shim
# --------------------------------------------------------------------------


def _check_dead_shims(
    path: str, tree: ast.Module, findings: list[Finding]
) -> None:
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            removed = REMOVED_IMPORTS.get(node.module)
            if not removed:
                continue
            for alias in node.names:
                if alias.name in removed:
                    findings.append(Finding(
                        path, node.lineno, "dead-shim",
                        f"{alias.name!r} was removed from "
                        f"{node.module} (PR-6 deprecation grace period "
                        f"ended) — use Reranker/RerankRequest from "
                        f"repro.serving.api",
                    ))
        elif isinstance(node, ast.Attribute):
            dotted = dotted_name(node)
            if dotted in _REMOVED_DOTTED:
                findings.append(Finding(
                    path, node.lineno, "dead-shim",
                    f"{dotted} no longer exists — use Reranker/"
                    f"RerankRequest from repro.serving.api",
                ))
