"""Static analysis for the repro codebase (``python -m repro.analysis``).

Three checker families over one findings/suppression framework:

* ``repro.analysis.kernels`` — Pallas kernel contracts: BlockSpec
  coverage/divisibility, revisit contiguity (the Mosaic hazard), and
  the TilePolicy VMEM model checked against the specs the kernels
  actually declare;
* ``repro.analysis.jitgeo`` — jit boundary hygiene and the router's
  single-compiled-geometry proof;
* ``repro.analysis.tracelint`` — AST trace-safety lint (tracer leaks,
  hot-path host syncs, non-static obs hooks, dead shims).

Findings carry rule ids (``repro.analysis.findings.RULES``) anchored
to ``path:line`` and are suppressible with ``# repro: ignore[rule-id]``.
Rule catalog: DESIGN.md §9.
"""
from repro.analysis.cli import main, run_analysis
from repro.analysis.findings import RULES, Finding

__all__ = ["Finding", "RULES", "main", "run_analysis"]
