"""Pallas kernel contract checker (rules pallas-coverage-gap,
pallas-block-divisibility, pallas-revisit-gap, pallas-vmem-budget,
pallas-vmem-model).

This checker is *static analysis by abstract execution*: it never runs
a kernel body.  ``pl.pallas_call`` is temporarily replaced with a
recorder that captures ``(grid, BlockSpecs, operand shapes)`` and
returns zeros, then the tiled seams (``_full_sweep`` /
``_windowed_sweep`` / the fused chunk wrappers, via ``__wrapped__`` to
bypass jit) are driven over representative ``(D, state_rows, windowed,
chunked)`` geometries.  Each recorded launch's ``index_map``s are then
evaluated over the full grid product — plain Python ints in, block
indices out — which makes every property below decidable exactly:

* **coverage** — the union of visited block indices equals the full
  block grid of every operand (nothing is silently never read or
  written);
* **divisibility** — every block shape divides its (padded) operand
  dimension;
* **revisit contiguity** — an output block revisited at
  *non-consecutive* grid steps (the fused chunk kernels' cross-step
  C/d2 state when ``nt > 1``) is only legal behind the interpret-mode
  guard: the checker re-drives the seam with ``interpret=False`` and
  requires ``NotImplementedError`` (ROADMAP's Mosaic hazard, made
  unreachable rather than latent);
* **VMEM model faithfulness** — ``tiling.tile_vmem_bytes``'s per-lane
  slope must cover the streamed rows the BlockSpecs actually declare
  (an undercount makes ``TilePolicy.auto_tile`` pick overflowing
  tiles);
* **VMEM budget** — for every geometry ``TilePolicy`` can choose, the
  decided tile's working set (model *and* recorded-spec actuals) fits
  ``vmem_budget_bytes``, and the non-streamed replicated cells stay
  bounded.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Callable, Optional

from repro.analysis.findings import Finding

LANE = 128
SUBLANE = 8
_BIG_M = 1 << 22  # forces decide() off the resident path
_CELL_BYTES_BOUND = 1 << 20  # replicated cells must not scale


@dataclasses.dataclass
class RecordedCall:
    """One captured ``pallas_call`` launch."""

    name: str
    grid: tuple[int, ...]
    in_specs: tuple
    out_specs: tuple
    in_shapes: tuple[tuple[int, ...], ...]
    out_shapes: tuple[tuple[int, ...], ...]
    interpret: bool


@dataclasses.dataclass
class DrivenSeam:
    """A recorded launch plus the geometry/meta it was driven with."""

    call: RecordedCall
    family: str
    D: int
    state_rows: int
    windowed: bool
    chunked: bool
    path: str
    line: int
    # re-drives the same geometry compiled; must raise
    # NotImplementedError whenever the launch has revisit gaps
    compiled_probe: Optional[Callable[[], None]] = None


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _kernel_name(kernel) -> str:
    fn = getattr(kernel, "func", kernel)
    return getattr(fn, "__name__", repr(fn))


class _Recorder:
    """Stand-in for ``pl.pallas_call``: records the launch geometry and
    returns zeros without executing the kernel."""

    def __init__(self):
        self.calls: list[RecordedCall] = []

    def __call__(self, kernel, *, grid, in_specs, out_specs, out_shape,
                 interpret=False, **_kw):
        import jax.numpy as jnp

        def run(*ins):
            self.calls.append(RecordedCall(
                name=_kernel_name(kernel),
                grid=tuple(grid),
                in_specs=tuple(in_specs),
                out_specs=tuple(out_specs),
                in_shapes=tuple(tuple(x.shape) for x in ins),
                out_shapes=tuple(tuple(s.shape) for s in out_shape),
                interpret=bool(interpret),
            ))
            return [jnp.zeros(s.shape, s.dtype) for s in out_shape]

        return run


# --------------------------------------------------------------------------
# Drivers
# --------------------------------------------------------------------------

# the geometries TilePolicy can be asked to tile: feature dims across
# the sublane boundary, state rows from tiny windows to full slates
SWEEP_D = (8, 64, 256)
SWEEP_R = (8, 48, 128)
_DRIVE_TILE = LANE  # smallest legal tile; streamed rows are
_DRIVE_NT = 2  # tile-width-independent, and nt=2 exposes revisit gaps


def _drive_family(tiled, family: str, D: int, R: int,
                  recorder: _Recorder) -> DrivenSeam:
    import inspect
    import os

    import jax.numpy as jnp

    B, tile = 1, _DRIVE_TILE
    Mp = _DRIVE_NT * tile
    V = jnp.zeros((B, D, Mp), jnp.float32)
    C = jnp.zeros((B, R, Mp), jnp.float32)
    stopped = jnp.zeros((B,), bool)
    windowed = family.endswith("windowed")
    chunked = family.startswith("chunk")
    probe = None

    if family == "step_exact":
        target = tiled._full_sweep
        run = lambda: tiled._full_sweep(  # noqa: E731
            V, C, jnp.zeros((B, 1, Mp), jnp.float32),
            jnp.zeros((B, 1, D), jnp.float32),
            jnp.zeros((B, 1, R), jnp.float32),
            jnp.zeros((B, 1, 2), jnp.float32),
            jnp.zeros((B, 1, 2), jnp.int32),
            tile_m=tile, interpret=True,
        )
    elif family == "step_windowed":
        target = tiled._windowed_sweep
        nf = 3 + 2 * (R - 1)
        run = lambda: tiled._windowed_sweep(  # noqa: E731
            V, C, jnp.zeros((B, 1, Mp), jnp.float32),
            jnp.zeros((B, 1, D), jnp.float32),
            jnp.zeros((B, 1, R), jnp.float32),
            jnp.zeros((B, 1, nf), jnp.float32),
            jnp.zeros((B, 1, 3), jnp.int32),
            w=R, tile_m=tile, interpret=True,
        )
    elif family == "chunk_exact":
        target = tiled.fused_chunk_exact.__wrapped__
        d2 = jnp.zeros((B, Mp), jnp.float32)
        run = lambda: target(  # noqa: E731
            V, C, d2, 0, stopped, chunk=2, eps=1e-3, tile_m=tile,
            interpret=True,
        )
        probe = lambda: target(  # noqa: E731
            V, C, d2, 0, stopped, chunk=2, eps=1e-3, tile_m=tile,
            interpret=False,
        )
    elif family == "chunk_windowed":
        target = tiled.fused_chunk_windowed.__wrapped__
        d2 = jnp.zeros((B, Mp), jnp.float32)
        win = jnp.full((B, R), -1, jnp.int32)
        run = lambda: target(  # noqa: E731
            V, C, d2, win, 0, stopped, chunk=2, eps=1e-3, w=R,
            tile_m=tile, interpret=True,
        )
        probe = lambda: target(  # noqa: E731
            V, C, d2, win, 0, stopped, chunk=2, eps=1e-3, w=R,
            tile_m=tile, interpret=False,
        )
    else:  # pragma: no cover - driver misuse
        raise ValueError(f"unknown family {family!r}")

    before = len(recorder.calls)
    run()
    if len(recorder.calls) != before + 1:  # pragma: no cover
        raise RuntimeError(
            f"driving {family} recorded {len(recorder.calls) - before} "
            f"pallas_call launches, expected exactly 1"
        )
    path = os.path.relpath(inspect.getsourcefile(tiled))
    line = target.__code__.co_firstlineno
    return DrivenSeam(
        call=recorder.calls[-1], family=family, D=D, state_rows=R,
        windowed=windowed, chunked=chunked, path=path, line=line,
        compiled_probe=probe,
    )


def harvest_seams() -> list[DrivenSeam]:
    """Drive every kernel family over the sweep geometries with the
    recorder patched in."""
    from repro.kernels.dpp_greedy import tiled

    recorder = _Recorder()
    seams: list[DrivenSeam] = []
    orig = tiled.pl.pallas_call
    tiled.pl.pallas_call = recorder
    try:
        for family in ("step_exact", "step_windowed", "chunk_exact",
                       "chunk_windowed"):
            for D, R in itertools.product(SWEEP_D, SWEEP_R):
                seams.append(_drive_family(tiled, family, D, R, recorder))
    finally:
        tiled.pl.pallas_call = orig
    return seams


# --------------------------------------------------------------------------
# Abstract index_map evaluation
# --------------------------------------------------------------------------


def _norm_block(spec) -> tuple[int, ...]:
    return tuple(1 if b is None else int(b) for b in spec.block_shape)


def _index_seq(spec, grid) -> list[tuple[int, ...]]:
    return [tuple(int(i) for i in spec.index_map(*pt))
            for pt in itertools.product(*(range(g) for g in grid))]


def _is_streamed(spec, grid) -> bool:
    """Does the block index vary along the tile (last grid) axis?"""
    base = tuple(0 for _ in grid)
    alt = base[:-1] + (1,)
    return (tuple(spec.index_map(*base))
            != tuple(spec.index_map(*alt)))


def _revisit_gaps(seq: list[tuple[int, ...]]) -> list[tuple[int, ...]]:
    last: dict[tuple[int, ...], int] = {}
    gapped = []
    for pos, ib in enumerate(seq):
        prev = last.get(ib)
        if prev is not None and pos - prev > 1:
            gapped.append(ib)
        last[ib] = pos
    return sorted(set(gapped))


def check_launch_geometry(seam: DrivenSeam) -> list[Finding]:
    """Coverage, divisibility and revisit-contiguity for one recorded
    launch (pure combinatorics over the captured BlockSpecs)."""
    findings: list[Finding] = []
    rec = seam.call
    operands = (
        [("in", i, s, sh) for i, (s, sh) in
         enumerate(zip(rec.in_specs, rec.in_shapes))]
        + [("out", i, s, sh) for i, (s, sh) in
           enumerate(zip(rec.out_specs, rec.out_shapes))]
    )
    gapped_outputs = []
    for role, idx, spec, shape in operands:
        block = _norm_block(spec)
        if len(block) != len(shape):  # pragma: no cover - malformed spec
            findings.append(Finding(
                seam.path, seam.line, "pallas-coverage-gap",
                f"{rec.name} {role}[{idx}]: block rank {len(block)} vs "
                f"operand rank {len(shape)}",
            ))
            continue
        for d, (dim, b) in enumerate(zip(shape, block)):
            if dim % b != 0:
                findings.append(Finding(
                    seam.path, seam.line, "pallas-block-divisibility",
                    f"{rec.name} {role}[{idx}] dim {d}: block {b} does "
                    f"not divide padded extent {dim} "
                    f"(family={seam.family}, D={seam.D}, "
                    f"R={seam.state_rows})",
                ))
        nblocks = tuple(-(-dim // b) for dim, b in zip(shape, block))
        seq = _index_seq(spec, rec.grid)
        visited = set(seq)
        full = set(itertools.product(*(range(n) for n in nblocks)))
        stray = sorted(visited - full)
        missing = sorted(full - visited)
        if stray:
            findings.append(Finding(
                seam.path, seam.line, "pallas-coverage-gap",
                f"{rec.name} {role}[{idx}]: index_map leaves the block "
                f"grid {nblocks} at {stray[:4]} "
                f"(family={seam.family}, D={seam.D}, "
                f"R={seam.state_rows})",
            ))
        if missing:
            findings.append(Finding(
                seam.path, seam.line, "pallas-coverage-gap",
                f"{rec.name} {role}[{idx}]: blocks never visited over "
                f"the full grid {rec.grid}: {missing[:4]} "
                f"(family={seam.family}, D={seam.D}, "
                f"R={seam.state_rows})",
            ))
        if role == "out" and _revisit_gaps(seq):
            gapped_outputs.append(idx)

    if gapped_outputs:
        guarded = False
        if seam.compiled_probe is not None:
            try:
                seam.compiled_probe()
            except NotImplementedError:
                guarded = True
        if not guarded:
            findings.append(Finding(
                seam.path, seam.line, "pallas-revisit-gap",
                f"{rec.name} outputs {gapped_outputs} are revisited at "
                f"non-consecutive grid steps over grid {rec.grid} and "
                f"compiling is not guarded — compiled Mosaic does not "
                f"preserve a revisited output block across intervening "
                f"steps (family={seam.family})",
            ))
    return findings


# --------------------------------------------------------------------------
# VMEM model / budget
# --------------------------------------------------------------------------


def _stream_accounting(rec: RecordedCall) -> tuple[int, int]:
    """(streamed padded rows per tile, non-streamed cell bytes) from
    the recorded BlockSpecs — f32/i32, rank-3 blocks."""
    rows = 0
    cell_bytes = 0
    for spec, _shape in itertools.chain(
        zip(rec.in_specs, rec.in_shapes), zip(rec.out_specs, rec.out_shapes)
    ):
        block = _norm_block(spec)
        lead = 1
        for b in block[:-2]:
            lead *= b
        if _is_streamed(spec, rec.grid):
            rows += lead * _round_up(block[-2], SUBLANE)
        else:
            cell_bytes += (
                4 * lead * _round_up(block[-2], SUBLANE)
                * _round_up(block[-1], LANE)
            )
    return rows, cell_bytes


def check_vmem_contract(seam: DrivenSeam) -> list[Finding]:
    from repro.kernels.dpp_greedy.tiling import TilePolicy, tile_vmem_bytes

    findings: list[Finding] = []
    D, R = seam.D, seam.state_rows
    rows, cell_bytes = _stream_accounting(seam.call)
    model = functools.partial(
        tile_vmem_bytes, D, state_rows=R, windowed=seam.windowed,
        chunked=seam.chunked,
    )
    model_rows = (model(tile_m=2 * LANE) - model(tile_m=LANE)) // (8 * LANE)
    geom = (f"family={seam.family}, D={D}, R={R}, "
            f"windowed={seam.windowed}, chunked={seam.chunked}")
    if model_rows < rows:
        findings.append(Finding(
            seam.path, seam.line, "pallas-vmem-model",
            f"tile_vmem_bytes counts {model_rows} streamed rows/tile "
            f"but the recorded BlockSpecs stream {rows} ({geom}) — "
            f"auto_tile would pick an overflowing tile",
        ))

    policy = TilePolicy()
    mode, tm = policy.decide(D, _BIG_M, R, seam.windowed,
                             chunked=seam.chunked)
    if mode == "tiled" and tm:
        if model(tile_m=tm) > policy.vmem_budget_bytes:
            findings.append(Finding(
                seam.path, seam.line, "pallas-vmem-budget",
                f"TilePolicy picked tile_m={tm} whose own model "
                f"estimate {model(tile_m=tm)} exceeds the "
                f"{policy.vmem_budget_bytes}-byte budget ({geom})",
            ))
        actual_stream = 4 * 2 * rows * tm
        if actual_stream > policy.vmem_budget_bytes:
            findings.append(Finding(
                seam.path, seam.line, "pallas-vmem-budget",
                f"TilePolicy picked tile_m={tm} but the recorded "
                f"BlockSpecs stream {actual_stream} double-buffered "
                f"bytes/tile, over the {policy.vmem_budget_bytes}-byte "
                f"budget ({geom})",
            ))
    if cell_bytes > _CELL_BYTES_BOUND:
        findings.append(Finding(
            seam.path, seam.line, "pallas-vmem-budget",
            f"replicated (non-streamed) cells occupy {cell_bytes} "
            f"bytes — they must stay within the model's fixed "
            f"headroom (< {_CELL_BYTES_BOUND}) ({geom})",
        ))
    return findings


# --------------------------------------------------------------------------
# Autotune cache validation (rule autotune-cache-invalid)
# --------------------------------------------------------------------------

_ENTRY_FIELDS = (
    ("D", int), ("M_bucket", int), ("state_rows", int), ("tile_m", int),
    ("windowed", bool), ("chunked", bool),
)


def _seam_rows(family: str, D: int, R: int,
               memo: dict[tuple[str, int, int], int]) -> int:
    """Streamed padded rows/tile the family's BlockSpecs actually
    declare at (D, R) — driven through the recorder like
    :func:`harvest_seams`, memoized per geometry."""
    key = (family, D, R)
    if key not in memo:
        from repro.kernels.dpp_greedy import tiled

        recorder = _Recorder()
        orig = tiled.pl.pallas_call
        tiled.pl.pallas_call = recorder
        try:
            seam = _drive_family(tiled, family, D, R, recorder)
        finally:
            tiled.pl.pallas_call = orig
        memo[key] = _stream_accounting(seam.call)[0]
    return memo[key]


def check_autotune_cache(
    path: Optional[str] = None,
) -> tuple[list[Finding], dict]:
    """Abstractly re-validate every persisted autotune cache entry.

    The runtime lookup ladder already refuses out-of-contract entries
    (it degrades them to a model-fallback miss); this rule makes the
    same contract a *blocking CI fact* about the cache file itself, so
    a stale or hand-edited cache is repaired at review time instead of
    silently mistuning the fleet.  Checks per entry: the tile is a
    LANE multiple; the key reproduces from the entry's own structured
    fields; the analytical model fits the VMEM budget; the rows the
    family's declared BlockSpecs actually stream fit the budget at
    that tile; and a compiled (non-interpret) fused-chunk entry never
    spans multiple tiles (Mosaic does not preserve non-consecutively
    revisited output blocks — the pallas-revisit-gap hazard).
    """
    import json
    import os

    from repro.kernels.dpp_greedy import autotune
    from repro.kernels.dpp_greedy.tiling import (
        VMEM_BUDGET_BYTES,
        tile_vmem_bytes,
    )

    path = path or autotune.active_cache_path()
    summary = {"path": path, "present": False, "entries": 0, "checked": 0}
    if not os.path.exists(path):
        return [], summary
    summary["present"] = True

    def finding(msg: str) -> Finding:
        return Finding(path, 1, "autotune-cache-invalid", msg)

    try:
        with open(path, encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, UnicodeDecodeError, ValueError) as e:
        return [finding(f"cache file is not parseable JSON ({e})")], summary
    if not isinstance(doc, dict) or not isinstance(doc.get("entries"), dict):
        return [finding("cache document must be an object with an "
                        "'entries' mapping")], summary
    if doc.get("schema") != autotune.SCHEMA_VERSION:
        return [finding(
            f"cache schema {doc.get('schema')!r} != supported "
            f"{autotune.SCHEMA_VERSION} — re-run "
            f"python -m repro.kernels.autotune"
        )], summary

    findings: list[Finding] = []
    rows_memo: dict[tuple[str, int, int], int] = {}
    entries = doc["entries"]
    summary["entries"] = len(entries)
    for key, e in sorted(entries.items()):
        if not isinstance(e, dict):
            findings.append(finding(f"entry {key!r} is not an object"))
            continue
        bad_field = False
        for name, typ in _ENTRY_FIELDS:
            v = e.get(name)
            if not isinstance(v, typ) or (typ is int and isinstance(v, bool)):
                findings.append(finding(
                    f"entry {key!r}: field {name!r} must be {typ.__name__}, "
                    f"got {v!r}"
                ))
                bad_field = True
        if bad_field:
            continue
        D, mb, R = e["D"], e["M_bucket"], e["state_rows"]
        tm, windowed, chunked = e["tile_m"], e["windowed"], e["chunked"]
        summary["checked"] += 1
        if mb < LANE or mb & (mb - 1):
            findings.append(finding(
                f"entry {key!r}: M_bucket {mb} is not a power-of-two "
                f">= {LANE} (bucket lookup would never match it)"
            ))
        if tm < LANE or tm % LANE != 0:
            findings.append(finding(
                f"entry {key!r}: tile_m {tm} is not a positive multiple "
                f"of the {LANE}-lane register width"
            ))
            continue
        expect = autotune.cache_key(
            e.get("device_kind"), e.get("platform"), e.get("backend"),
            D, mb, R, windowed, chunked,
        )
        if key != expect:
            findings.append(finding(
                f"entry key {key!r} does not reproduce from its own "
                f"fields ({expect!r}) — hand-edited or corrupted; the "
                f"lookup ladder will never match it"
            ))
        model = tile_vmem_bytes(D, tm, R, windowed, chunked)
        if model > VMEM_BUDGET_BYTES:
            findings.append(finding(
                f"entry {key!r}: tile_m={tm} has a model working set of "
                f"{model} bytes, over the {VMEM_BUDGET_BYTES}-byte VMEM "
                f"budget (D={D}, R={R}, windowed={windowed}, "
                f"chunked={chunked})"
            ))
        family = (("chunk_" if chunked else "step_")
                  + ("windowed" if windowed else "exact"))
        try:
            rows = _seam_rows(family, D, R, rows_memo)
        except Exception as err:
            findings.append(finding(
                f"entry {key!r}: cannot drive seam family {family} at "
                f"D={D}, R={R} to validate its declared BlockSpecs "
                f"({type(err).__name__}: {err})"
            ))
            continue
        declared = 4 * 2 * rows * tm
        if declared > VMEM_BUDGET_BYTES:
            findings.append(finding(
                f"entry {key!r}: the {family} BlockSpecs stream "
                f"{declared} double-buffered bytes at tile_m={tm}, over "
                f"the {VMEM_BUDGET_BYTES}-byte VMEM budget"
            ))
        if chunked and not e.get("interpret", True) and mb > tm:
            findings.append(finding(
                f"entry {key!r}: a compiled (interpret=false) fused-chunk "
                f"geometry with {mb // tm} tiles — compiled Mosaic does "
                f"not preserve non-consecutively revisited output blocks "
                f"(pallas-revisit-gap); tune compiled chunk kernels "
                f"whole-M or in interpret mode"
            ))
    return findings, summary


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------


def check_kernel_contracts() -> tuple[list[Finding], dict]:
    """Drive, record, and verify every kernel family.  Returns
    (deduplicated findings, summary)."""
    seams = harvest_seams()
    findings: list[Finding] = []
    for seam in seams:
        findings.extend(check_launch_geometry(seam))
        findings.extend(check_vmem_contract(seam))
    seen = set()
    unique = []
    for f in findings:
        key = (f.rule, f.path, f.line)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    summary = {
        "families": sorted({s.family for s in seams}),
        "geometries": len(seams),
        "launches_recorded": len(seams),
    }
    return unique, summary
