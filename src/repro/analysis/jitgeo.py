"""jit-geometry / recompile-hazard checker (rules jit-static-missing,
jit-static-unhashable, router-geometry, session-geometry).

Three jobs:

1. **jit boundary hygiene** — every ``static_argnames`` entry must name
   a real parameter (a typo leaves the intended argument traced, which
   silently re-specializes nothing and hides geometry churn), and no
   static parameter may be array-typed or receive an unhashable
   literal (jit raises at call time — or worse, hashes a request-
   varying value and recompiles per request).

2. **router geometry proof** — in the class that launches the slot
   chunk step (``greedy_chunk_slots``), every attribute feeding the
   compiled geometry must be written exactly once: in ``__init__``, or
   (for the lazily-materialised ones) under an ``if self.x is None:``
   guard.  With exactly one launch site and write-once geometry, every
   launch after warmup reuses the same compiled signature — the static
   counterpart of the fig8 ``jit_misses_after_warmup == 0`` gate.

3. **session geometry proof** — the same property for the session
   layer (the class calling ``greedy_state_extend``): the resume chunk
   and the delta-update primitives may specialise only on (state
   shape, chunk width, delta width); one launch site per family and
   write-once geometry attributes prove a resumed session never
   recompiles beyond those axes.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.astutil import (
    dotted_name,
    jit_call_assignments,
    jit_statics,
    param_names,
)
from repro.analysis.findings import Finding

# the slot-batched chunk launch and the state initialiser whose
# arguments pin the router's compiled geometry
CHUNK_LAUNCH = "greedy_chunk_slots"
STATE_INIT = "greedy_slots_init"

# the session layer's launch families: the resume chunk and the two
# delta-update primitives.  greedy_state_extend is the marker — only
# the session class calls it (greedy_chunk alone is also the plain
# streaming path)
SESSION_MARKER = "greedy_state_extend"
SESSION_LAUNCHES = ("greedy_chunk", "greedy_state_extend",
                    "greedy_state_rescore")

_ARRAYISH = ("ndarray", "Array", "jnp.", "jax.")
_UNHASHABLE_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                        ast.DictComp, ast.SetComp)


def check_module(path: str, tree: ast.Module) -> list[Finding]:
    findings: list[Finding] = []
    _check_jit_statics(path, tree, findings)
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            summary = router_geometry_summary(node)
            if summary is not None:
                for line, message in summary["violations"]:
                    findings.append(
                        Finding(path, line, "router-geometry", message)
                    )
            summary = session_geometry_summary(node)
            if summary is not None:
                for line, message in summary["violations"]:
                    findings.append(
                        Finding(path, line, "session-geometry", message)
                    )
    return findings


# --------------------------------------------------------------------------
# jit-static-missing / jit-static-unhashable
# --------------------------------------------------------------------------


def _check_jit_statics(
    path: str, tree: ast.Module, findings: list[Finding]
) -> None:
    defs = {n.name: n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}

    jitted: dict[str, set[str]] = {}
    for node in defs.values():
        statics = jit_statics(node)
        if statics is not None:
            jitted[node.name] = statics
    for name, statics, call in jit_call_assignments(tree):
        jitted[name] = jitted.get(name, set()) | statics

    for name, statics in jitted.items():
        fn = defs.get(name)
        if fn is None:
            continue
        params = param_names(fn)
        anchor = fn.lineno
        for static in sorted(statics):
            if static not in params:
                findings.append(Finding(
                    path, anchor, "jit-static-missing",
                    f"static_argnames entry {static!r} is not a "
                    f"parameter of {name}() — the intended argument "
                    f"stays traced",
                ))
        for arg in fn.args.args + fn.args.kwonlyargs + fn.args.posonlyargs:
            if arg.arg not in statics:
                continue
            ann = arg.annotation
            ann_src = ast.unparse(ann) if ann is not None else ""
            if ann_src and any(tok in ann_src for tok in _ARRAYISH):
                findings.append(Finding(
                    path, arg.lineno, "jit-static-unhashable",
                    f"static parameter {arg.arg!r} of {name}() is "
                    f"annotated {ann_src!r} — arrays are unhashable "
                    f"and must be traced, not static",
                ))

    # call sites in this module passing unhashable literals to a static
    # keyword of a locally-jitted function
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func)
        statics = jitted.get(callee or "")
        if not statics:
            continue
        for kw in node.keywords:
            if kw.arg in statics and isinstance(
                kw.value, _UNHASHABLE_LITERALS
            ):
                findings.append(Finding(
                    path, kw.value.lineno, "jit-static-unhashable",
                    f"unhashable literal passed to static parameter "
                    f"{kw.arg!r} of {callee}() — jit raises at call "
                    f"time",
                ))


# --------------------------------------------------------------------------
# router-geometry
# --------------------------------------------------------------------------


def _self_attr(node: ast.AST) -> Optional[str]:
    """``self.x`` (or the ``self.x`` root of ``self.x.y``) -> ``"x"``."""
    while isinstance(node, ast.Attribute):
        if isinstance(node.value, ast.Name) and node.value.id == "self":
            return node.attr
        node = node.value
    return None


def _calls_named(tree: ast.AST, name: str) -> list[ast.Call]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.Call)
            and (dotted_name(n.func) or "").split(".")[-1] == name]


def router_geometry_summary(cls: ast.ClassDef) -> Optional[dict]:
    """Prove (or refute) the single-compiled-geometry property for a
    class that launches ``greedy_chunk_slots``.

    Returns None when the class has no launch site.  Otherwise a dict:
    ``launch_sites`` (count), ``geometry_attrs`` (write-once, from
    ``__init__``), ``lazy_attrs`` (write-once under ``is None`` guard),
    ``violations`` ([(line, message)]), and ``reachable_geometries``
    (1 when no violations — the static fig8 counterpart).
    """
    launches = _calls_named(cls, CHUNK_LAUNCH)
    if not launches:
        return None
    inits = _calls_named(cls, STATE_INIT)

    violations: list[tuple[int, str]] = []
    if len(launches) > 1:
        for call in launches[1:]:
            violations.append((
                call.lineno,
                f"{len(launches)} {CHUNK_LAUNCH} launch sites in class "
                f"{cls.name} — a second site can carry a second "
                f"compiled geometry; route every chunk through one",
            ))

    geometry: set[str] = set()  # write-once-in-__init__ attrs
    lazy: set[str] = set()  # write-once-under-guard attrs
    for call in launches:
        for arg in call.args + [kw.value for kw in call.keywords]:
            attr = _self_attr(arg)
            if attr is not None and not attr.startswith("_"):
                geometry.add(attr)
            # underscore launch args are the mutable slot state — their
            # shapes are pinned by the STATE_INIT arguments below
    for call in inits:
        for arg in call.args + [kw.value for kw in call.keywords]:
            attr = _self_attr(arg)
            if attr is None:
                continue
            (lazy if attr.startswith("_") else geometry).add(attr)

    writes = _attr_writes(cls)
    for attr in sorted(geometry):
        for line, where, guarded_by in writes.get(attr, []):
            if where != "__init__":
                violations.append((
                    line,
                    f"geometry attribute self.{attr} written outside "
                    f"__init__ (in {where}) — the compiled chunk "
                    f"signature could change after warmup",
                ))
    for attr in sorted(lazy):
        for line, where, guarded_by in writes.get(attr, []):
            if where != "__init__" and attr not in guarded_by:
                violations.append((
                    line,
                    f"lazy geometry attribute self.{attr} written in "
                    f"{where} outside its `if self.{attr} is None:` "
                    f"guard — it must materialise exactly once",
                ))

    return {
        "class": cls.name,
        "launch_sites": len(launches),
        "geometry_attrs": sorted(geometry),
        "lazy_attrs": sorted(lazy),
        "violations": violations,
        "reachable_geometries": 1 if not violations else None,
    }


def session_geometry_summary(cls: ast.ClassDef) -> Optional[dict]:
    """Prove (or refute) that a session class's resume path reaches no
    compiled geometry beyond (state shape, chunk).

    Fires on any class calling ``greedy_state_extend`` (the session
    marker — only the session layer delta-updates a resumable state).
    The resume chunk and the two delta primitives jit-specialize on the
    state/operand shapes, the chunk width and the delta width; every
    *other* knob reaching a launch must therefore be an attribute
    written exactly once, in ``__init__``, and each launch family must
    have exactly one call site.  Underscore launch arguments are the
    mutable device state (``_state`` / ``_V``) — rewritten every call
    (and dropped/rebuilt by the LRU store), but always inside the
    geometry pinned at construction.

    Returns None for classes without the marker, else a dict like
    :func:`router_geometry_summary` (``launch_sites`` maps family ->
    count; ``reachable_geometries`` is 1 per (shape, chunk) when the
    proof holds).
    """
    if not _calls_named(cls, SESSION_MARKER):
        return None

    violations: list[tuple[int, str]] = []
    geometry: set[str] = set()
    sites: dict[str, int] = {}
    for family in SESSION_LAUNCHES:
        calls = _calls_named(cls, family)
        sites[family] = len(calls)
        for call in calls[1:]:
            violations.append((
                call.lineno,
                f"{len(calls)} {family} launch sites in class {cls.name} "
                f"— a second site can carry a second compiled geometry; "
                f"route every {family.split('_')[-1]} through one",
            ))
        for call in calls:
            for arg in call.args + [kw.value for kw in call.keywords]:
                attr = _self_attr(arg)
                if attr is not None and not attr.startswith("_"):
                    geometry.add(attr)

    writes = _attr_writes(cls)
    for attr in sorted(geometry):
        for line, where, guarded_by in writes.get(attr, []):
            if where != "__init__":
                violations.append((
                    line,
                    f"session geometry attribute self.{attr} written "
                    f"outside __init__ (in {where}) — a resume after the "
                    f"write could carry a new compiled signature",
                ))

    return {
        "class": cls.name,
        "launch_sites": sites,
        "geometry_attrs": sorted(geometry),
        "violations": violations,
        "reachable_geometries": 1 if not violations else None,
    }


def _attr_writes(
    cls: ast.ClassDef,
) -> dict[str, list[tuple[int, str, frozenset[str]]]]:
    """All ``self.x = ...`` writes in the class:
    attr -> [(line, method name, attrs guarded by `is None` here)]."""
    writes: dict[str, list[tuple[int, str, frozenset[str]]]] = {}

    def guard_attrs(test: ast.AST) -> set[str]:
        """Attrs ``a`` with ``self.a is None`` asserted by ``test``."""
        out: set[str] = set()
        if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
            for v in test.values:
                out |= guard_attrs(v)
        elif (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.ops[0], ast.Is)
                and isinstance(test.comparators[0], ast.Constant)
                and test.comparators[0].value is None):
            attr = _self_attr(test.left)
            if attr is not None:
                out.add(attr)
        return out

    def scan(stmts: list[ast.stmt], method: str,
             guarded: frozenset[str]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scan(st.body, method, guarded)
                continue
            targets: list[ast.AST] = []
            if isinstance(st, ast.Assign):
                targets = st.targets
            elif isinstance(st, (ast.AugAssign, ast.AnnAssign)):
                targets = [st.target]
            for target in targets:
                for t in (target.elts if isinstance(
                        target, (ast.Tuple, ast.List)) else [target]):
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        writes.setdefault(t.attr, []).append(
                            (st.lineno, method, guarded)
                        )
            if isinstance(st, ast.If):
                scan(st.body, method,
                     guarded | frozenset(guard_attrs(st.test)))
                scan(st.orelse, method, guarded)
            elif isinstance(st, (ast.While, ast.For)):
                scan(st.body, method, guarded)
                scan(st.orelse, method, guarded)
            elif isinstance(st, ast.With):
                scan(st.body, method, guarded)
            elif isinstance(st, ast.Try):
                scan(st.body, method, guarded)
                for handler in st.handlers:
                    scan(handler.body, method, guarded)
                scan(st.orelse, method, guarded)
                scan(st.finalbody, method, guarded)

    for node in cls.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            scan(node.body, node.name, frozenset())
    return writes
