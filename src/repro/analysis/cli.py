"""``python -m repro.analysis`` — run every checker, print findings,
exit nonzero when any survive suppression.

Zero third-party dependencies beyond what the repo already ships: the
AST rules are pure stdlib; the kernel contract checker imports jax (to
abstractly drive the Pallas seams) only when the kernel sources are in
scope and ``--no-kernel-checks`` is not given.
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import sys

from repro.analysis import jitgeo, tracelint
from repro.analysis.astutil import iter_py_files
from repro.analysis.findings import (
    RULES,
    Finding,
    apply_suppressions,
    scan_suppressions,
)

DEFAULT_PATHS = ("src", "benchmarks", "examples")
_KERNEL_SOURCE = os.path.join("kernels", "dpp_greedy", "tiled.py")


def run_analysis(
    paths: list[str], kernel_checks: bool = True
) -> tuple[list[Finding], dict]:
    """Run all checkers over ``paths``.  Returns (findings after
    suppression, summary dict)."""
    files = list(iter_py_files(paths))
    findings: list[Finding] = []
    suppressions: dict[str, dict[int, set[str]]] = {}
    geometry_summaries: list[dict] = []
    session_summaries: list[dict] = []
    skipped: list[str] = []

    for path in files:
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        supp, bad = scan_suppressions(path, text)
        suppressions[path] = supp
        findings.extend(bad)
        try:
            tree = ast.parse(text, filename=path)
        except SyntaxError:
            skipped.append(path)
            continue
        findings.extend(tracelint.check_module(path, tree))
        findings.extend(jitgeo.check_module(path, tree))
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                summary = jitgeo.router_geometry_summary(node)
                if summary is not None:
                    summary["path"] = path
                    geometry_summaries.append(summary)
                summary = jitgeo.session_geometry_summary(node)
                if summary is not None:
                    summary["path"] = path
                    session_summaries.append(summary)

    kernel_summary: dict | None = None
    autotune_summary: dict | None = None
    if kernel_checks and any(p.endswith(_KERNEL_SOURCE) for p in files):
        from repro.analysis.kernels import (
            check_autotune_cache,
            check_kernel_contracts,
        )

        kernel_findings, kernel_summary = check_kernel_contracts()
        findings.extend(kernel_findings)
        # the persisted autotune cache ($DPP_AUTOTUNE_CACHE or the
        # per-user default) is part of the kernel dispatch surface: a
        # stale or hand-edited entry must not ship an over-budget or
        # gap-revisiting launch
        cache_findings, autotune_summary = check_autotune_cache()
        findings.extend(cache_findings)

    findings = apply_suppressions(findings, suppressions)
    summary = {
        "files": len(files),
        "skipped_syntax": skipped,
        "router_geometry": geometry_summaries,
        "session_geometry": session_summaries,
        "kernel_contracts": kernel_summary,
        "autotune_cache": autotune_summary,
        "findings": len(findings),
    }
    return sorted(set(findings)), summary


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static checks: Pallas kernel contracts, jit "
                    "geometry, trace safety.",
    )
    parser.add_argument(
        "paths", nargs="*",
        help=f"files/directories to check (default: "
             f"{' '.join(DEFAULT_PATHS)})",
    )
    parser.add_argument(
        "--error-on-findings", action="store_true",
        help="exit 1 when findings survive suppression (this is the "
             "default behaviour; the flag exists so CI lanes state "
             "their gate explicitly)",
    )
    parser.add_argument(
        "--no-kernel-checks", action="store_true",
        help="skip the dynamic Pallas contract checker (AST rules only)",
    )
    parser.add_argument("--json", action="store_true",
                        help="machine-readable output")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("--verbose", action="store_true",
                        help="print the geometry/coverage summaries")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in sorted(RULES):
            print(f"{rule}: {RULES[rule]}")
        return 0

    paths = args.paths or [p for p in DEFAULT_PATHS if os.path.exists(p)]
    if not paths:
        print("no paths to check", file=sys.stderr)
        return 2

    findings, summary = run_analysis(
        paths, kernel_checks=not args.no_kernel_checks
    )

    if args.json:
        print(json.dumps({
            "findings": [dataclass_dict(f) for f in findings],
            "summary": summary,
        }, indent=2, default=str))
    else:
        for f in findings:
            print(f.format())
        tail = (f"{summary['files']} files checked, "
                f"{len(findings)} finding(s)")
        if summary["kernel_contracts"]:
            kc = summary["kernel_contracts"]
            tail += (f"; kernel contracts: {kc['geometries']} geometries "
                     f"across {len(kc['families'])} families")
        if summary.get("autotune_cache") and summary["autotune_cache"].get(
                "present"):
            ac = summary["autotune_cache"]
            tail += (f"; autotune cache: {ac['checked']}/{ac['entries']} "
                     f"entries validated ({ac['path']})")
        for geo in summary["router_geometry"]:
            if geo.get("reachable_geometries") == 1:
                tail += (f"; {geo['class']}: 1 reachable compiled "
                         f"geometry ({geo['launch_sites']} launch site)")
        for geo in summary["session_geometry"]:
            if geo.get("reachable_geometries") == 1:
                fams = sum(1 for n in geo["launch_sites"].values() if n)
                tail += (f"; {geo['class']}: 1 reachable compiled "
                         f"geometry per (shape, chunk) "
                         f"({fams} launch families)")
        print(tail)
        if args.verbose:
            print(json.dumps(summary, indent=2, default=str))

    return 1 if findings else 0


def dataclass_dict(f: Finding) -> dict:
    return {"path": f.path, "line": f.line, "rule": f.rule,
            "message": f.message}


if __name__ == "__main__":  # pragma: no cover
    sys.exit(main())
