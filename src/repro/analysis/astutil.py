"""Shared AST plumbing for the static checkers (stdlib ``ast`` only).

The load-bearing pieces:

* :func:`jit_statics` — recognises the repo's jit idioms
  (``@jax.jit``, ``@functools.partial(jax.jit, static_argnames=...)``,
  ``name = jax.jit(fn, static_argnames=...)``) and extracts the static
  argument names.
* :func:`is_kernel_fn` — Pallas kernel bodies are identified by their
  ``*_ref`` Ref parameters (the repo-wide kernel convention).
* :class:`TracedNames` — an "is this expression trace-safe to branch
  on" evaluator.  Taint starts at the *traced* function parameters
  (everything not named static; the ``*_ref`` Refs in kernels) and
  propagates through assignments; ``.shape``-family attributes,
  ``is None`` checks and ``len``/``isinstance`` calls launder taint
  back to host values.  Names with no taint — closure captures,
  globals, statics — are host-valued at trace time, so branching on
  them is fine.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, Iterator, Optional

# attribute reads that yield host (Python) values even on tracers
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "sharding"}
# calls that yield host values from traced arguments
STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "getattr",
                "range", "min", "max", "abs", "round", "tuple", "list",
                "sorted", "zip", "enumerate", "round_up"}


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    """Expand files/directories into .py files (sorted, deduped),
    skipping hidden directories and __pycache__."""
    seen = set()
    for path in paths:
        if os.path.isfile(path):
            if path.endswith(".py") and path not in seen:
                seen.add(path)
                yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = sorted(
                d for d in dirs
                if not d.startswith(".") and d != "__pycache__"
            )
            for name in sorted(files):
                full = os.path.join(root, name)
                if name.endswith(".py") and full not in seen:
                    seen.add(full)
                    yield full


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.experimental.pallas`` -> that string; None when the
    expression is not a plain dotted name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _string_elts(node: ast.AST) -> list[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
        return out
    return []


def _is_jit_name(name: Optional[str]) -> bool:
    return name in ("jax.jit", "jit", "pjit", "jax.pjit")


def jit_statics(fn: ast.FunctionDef) -> Optional[set[str]]:
    """If ``fn`` is jit-decorated, the set of static argument names
    (empty for a bare ``@jax.jit``); None when not jitted."""
    for dec in fn.decorator_list:
        if _is_jit_name(dotted_name(dec)):
            return set()
        if isinstance(dec, ast.Call):
            callee = dotted_name(dec.func)
            if _is_jit_name(callee):
                return set(_jit_call_statics(dec))
            if callee in ("functools.partial", "partial") and dec.args:
                if _is_jit_name(dotted_name(dec.args[0])):
                    return set(_jit_call_statics(dec))
    return None


def _jit_call_statics(call: ast.Call) -> list[str]:
    names: list[str] = []
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            names.extend(_string_elts(kw.value))
    return names


def jit_call_assignments(
    tree: ast.Module,
) -> list[tuple[str, set[str], ast.Call]]:
    """Module-level ``name = jax.jit(fn, static_argnames=...)`` bindings
    -> ``(wrapped function name, static names, the jit call)``."""
    out = []
    for node in tree.body:
        if not (isinstance(node, ast.Assign) and
                isinstance(node.value, ast.Call)):
            continue
        call = node.value
        if not _is_jit_name(dotted_name(call.func)) or not call.args:
            continue
        target = dotted_name(call.args[0])
        if target is not None:
            out.append((target, set(_jit_call_statics(call)), call))
    return out


def is_kernel_fn(fn: ast.FunctionDef) -> bool:
    """Pallas kernel body: positional parameters follow the repo's
    ``*_ref`` Ref naming convention."""
    refs = [a for a in fn.args.args if a.arg.endswith("_ref")]
    return len(refs) >= 2


def param_names(fn: ast.FunctionDef) -> set[str]:
    args = fn.args
    names = {a.arg for a in args.args + args.kwonlyargs + args.posonlyargs}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    return names


class TracedNames:
    """Tracks which local names carry traced (device) values inside a
    traced scope, and classifies expressions.

    Taint semantics, deliberately precise-over-complete: a finding
    requires provable taint from a traced parameter, so closure
    captures, globals and helper calls on host values never fire.
    ``.shape``/``is None``/``len()`` are host reads even on tracers."""

    def __init__(self, traced: Iterable[str] = ()):  # noqa: D107
        self.names = set(traced)

    def observe_assign(self, node: ast.stmt) -> None:
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            value, targets = node.value, [node.target]
        elif isinstance(node, ast.AugAssign):
            value, targets = node.value, [node.target]
        else:
            return
        traced = self.is_traced(value)
        for target in targets:
            for name in _target_names(target):
                if traced:
                    self.names.add(name)
                else:
                    self.names.discard(name)

    def is_traced(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in STATIC_ATTRS:
                return False
            return self.is_traced(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_traced(node.value) or self.is_traced(node.slice)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.is_traced(e) for e in node.elts)
        if isinstance(node, ast.Slice):
            return any(
                self.is_traced(p)
                for p in (node.lower, node.upper, node.step)
                if p is not None
            )
        if isinstance(node, ast.UnaryOp):
            return self.is_traced(node.operand)
        if isinstance(node, ast.BinOp):
            return self.is_traced(node.left) or self.is_traced(node.right)
        if isinstance(node, ast.BoolOp):
            return any(self.is_traced(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return any(
                self.is_traced(p) for p in (node.test, node.body, node.orelse)
            )
        if isinstance(node, ast.Compare):
            # `x is None` is a host identity check even on tracers
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return self.is_traced(node.left) or any(
                self.is_traced(c) for c in node.comparators
            )
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func)
            if callee in STATIC_CALLS:
                return False
            # method calls on traced values stay traced (x.sum());
            # taint also flows in through arguments
            return (
                self.is_traced(node.func)
                or any(self.is_traced(a) for a in node.args)
                or any(self.is_traced(kw.value) for kw in node.keywords)
            )
        return False


def _target_names(target: ast.AST) -> Iterator[str]:
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for e in target.elts:
            yield from _target_names(e)
    elif isinstance(target, ast.Starred):
        yield from _target_names(target.value)
