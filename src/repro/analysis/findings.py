"""Findings and suppression framework for ``repro.analysis``.

Every checker reports :class:`Finding` records — a rule id from
:data:`RULES`, a ``path:line`` anchor, and a message.  A finding is
suppressed by putting ``# repro: ignore[rule-id]`` on the anchored
line; a suppression naming an unknown rule id is itself a finding
(``bad-suppression``), so typos cannot silently disable a check.

The rule catalog (ids, what fires them, how to fix) is documented in
DESIGN.md §9.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize

# rule id -> one-line description (the catalog the CLI prints with
# --list-rules; DESIGN.md §9 carries the long-form entries)
RULES: dict[str, str] = {
    # -- trace-safety lint (tracelint.py) --------------------------------
    "trace-cast": (
        "float()/int()/bool()/.item() on a traced value inside a "
        "jitted or Pallas-kernel body (concretization error at trace "
        "time, or a silent host sync)"
    ),
    "trace-pyif": (
        "Python `if`/`while` on a traced value inside a jitted or "
        "Pallas-kernel body (TracerBoolConversionError; use lax.cond/"
        "jnp.where)"
    ),
    "host-sync-hot": (
        "host sync (np.asarray / device_get / block_until_ready) in a "
        "router pump hot phase outside the designated sync/materialize "
        "spans"
    ),
    "obs-nonstatic": (
        "device work (jnp/np call, .item, .block_until_ready) inside "
        "an obs.span(...) call site — hook arguments must be static/"
        "host-cheap"
    ),
    "dead-shim": (
        "import or attribute use of a removed serving shim "
        "(rerank/rerank_batch/rerank_stream/sharded_rerank/"
        "sharded_rerank_stream/_deprecated)"
    ),
    # -- jit geometry (jitgeo.py) ----------------------------------------
    "jit-static-missing": (
        "static_argnames entry that is not a parameter of the jitted "
        "function (the intended argument stays traced and re-jits are "
        "hidden)"
    ),
    "jit-static-unhashable": (
        "a static_argnames parameter that takes an unhashable or "
        "array value (jit raises at call time, or recompiles per "
        "request)"
    ),
    "router-geometry": (
        "router compiled-geometry attribute written outside __init__ "
        "(or outside its lazy `is None` guard), or more than one "
        "slot-chunk launch site — the single-compiled-geometry proof "
        "fails"
    ),
    "session-geometry": (
        "session compiled-geometry attribute written outside __init__, "
        "or more than one launch site for a resume/extend/rescore "
        "family — the session resume path could compile geometries "
        "beyond (shape, chunk)"
    ),
    # -- Pallas kernel contracts (kernels.py) ----------------------------
    "pallas-coverage-gap": (
        "a BlockSpec index_map never visits some block of its operand "
        "over the full grid (part of the array is never read/written)"
    ),
    "pallas-block-divisibility": (
        "a block shape that does not divide its (padded) operand "
        "dimension"
    ),
    "pallas-revisit-gap": (
        "an output block revisited at non-consecutive grid steps "
        "without an interpret-mode guard (compiled Mosaic does not "
        "guarantee its contents between visits)"
    ),
    "pallas-vmem-budget": (
        "a TilePolicy-selectable geometry whose per-tile working set "
        "exceeds the VMEM budget"
    ),
    "pallas-vmem-model": (
        "tiling.tile_vmem_bytes undercounts the streams the kernel's "
        "BlockSpecs actually declare (the policy would pick an "
        "overflowing tile)"
    ),
    "autotune-cache-invalid": (
        "a persisted autotune cache entry that could ship a bad launch "
        "— over the VMEM budget (model or declared BlockSpecs), a "
        "non-LANE tile, key/fields divergence (hand-edited), a "
        "compiled multi-tile chunk geometry (Mosaic revisit gaps), or "
        "an unreadable/foreign-schema cache file"
    ),
    # -- framework -------------------------------------------------------
    "bad-suppression": (
        "`# repro: ignore[...]` naming an unknown rule id (typo would "
        "silently disable nothing — and hide that it does)"
    ),
}

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*ignore\[([^\]]*)\]")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One checker hit, anchored to ``path:line``."""

    path: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def scan_suppressions(
    path: str, text: str
) -> tuple[dict[int, set[str]], list[Finding]]:
    """Parse ``# repro: ignore[rule-id]`` comments.

    Returns ``(suppressions, findings)`` where ``suppressions`` maps a
    1-indexed line number to the rule ids suppressed on that line, and
    ``findings`` carries a ``bad-suppression`` per unknown rule id.
    """
    supp: dict[int, set[str]] = {}
    findings: list[Finding] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenizeError, SyntaxError, IndentationError):
        return supp, findings
    # real comment tokens only — the pattern appearing in a docstring
    # or string literal is documentation, not a suppression
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        lineno = tok.start[0]
        for m in _SUPPRESS_RE.finditer(tok.string):
            for rule in (r.strip() for r in m.group(1).split(",")):
                if not rule:
                    continue
                if rule not in RULES:
                    findings.append(Finding(
                        path, lineno, "bad-suppression",
                        f"unknown rule id {rule!r} in suppression "
                        f"(known: {', '.join(sorted(RULES))})",
                    ))
                    continue
                supp.setdefault(lineno, set()).add(rule)
    return supp, findings


def apply_suppressions(
    findings: list[Finding], suppressions: dict[str, dict[int, set[str]]]
) -> list[Finding]:
    """Drop findings whose anchored line carries a matching
    suppression.  ``suppressions`` maps path -> line -> rule ids (as
    produced per-file by :func:`scan_suppressions`).  ``bad-suppression``
    itself cannot be suppressed."""
    kept = []
    for f in findings:
        if f.rule != "bad-suppression":
            by_line = suppressions.get(f.path, {})
            if f.rule in by_line.get(f.line, ()):  # noqa: SIM108
                continue
        kept.append(f)
    return kept
