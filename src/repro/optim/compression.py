"""int8 gradient compression with error feedback (1-bit-Adam-style EF).

For bandwidth-bound data-parallel all-reduce: grads are quantized to int8
with a per-tensor scale before the reduce; the quantization residual is
carried in an error-feedback accumulator so the compression bias
telescopes away over steps (Seide et al. '14; Karimireddy et al. '19).

Wired into the training step behind ``--grad-compression int8_ef``; the
roofline collective term for DP all-reduce drops 4x (f32->int8).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: dict  # same tree as grads, f32


def compress_int8(x: jnp.ndarray):
    """f32 tensor -> (int8 tensor, scale). Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_init(params) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    )


def ef_compress_grads(grads, state: ErrorFeedbackState):
    """Quantize (grad + residual); return (decompressed grads to feed the
    optimizer, new residual).  In a multi-host deployment the int8 payload
    is what crosses the wire; numerically this function is identical on
    one host, which is what the tests verify (telescoping residual)."""

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, scale = compress_int8(target)
        deq = decompress_int8(q, scale)
        return deq.astype(g.dtype), target - deq

    out = jax.tree.map(one, grads, state.residual)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, ErrorFeedbackState(residual=new_res)
