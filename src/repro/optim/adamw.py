"""AdamW with decoupled weight decay, f32 moments over (possibly bf16)
params — the memory layout sized for 16 GB/chip at 480 B params / 512
chips: params bf16 (2B) + m,v f32 (8B) = 10 B/param.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(a.astype(jnp.float32))) for a in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale: Any = 1.0):
    """Returns (new_params, new_state, metrics)."""
    if cfg.grad_clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1 - cfg.b1) * gf
        v2 = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * update
        return p2.astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t3: t3[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t3: t3[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t3: t3[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
