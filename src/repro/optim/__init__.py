from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_warmup, constant_lr
from repro.optim.compression import (
    compress_int8,
    decompress_int8,
    ErrorFeedbackState,
    ef_init,
    ef_compress_grads,
)
