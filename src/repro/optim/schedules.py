"""LR schedules as pure step -> scale functions (multiplied onto cfg.lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant_lr(step):
    return jnp.ones_like(step, jnp.float32)


def cosine_warmup(step, warmup: int = 100, total: int = 10000, floor: float = 0.1):
    t = step.astype(jnp.float32)
    warm = jnp.minimum(t / max(warmup, 1), 1.0)
    prog = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
