"""graphcast [gnn]: n_layers=16 d_hidden=512 mesh_refinement=6
aggregator=sum n_vars=227 — encoder-processor-decoder mesh GNN.
[arXiv:2212.12794; unverified]

The paper's technique (DPP re-ranking) is inapplicable to the weather
regression objective itself; node embeddings from the decoder are
DPP-diversifiable downstream (see examples/).  d_feat varies per assigned
graph shape and is taken from the ShapeSpec at step-build time."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import GNN_SHAPES
from repro.models.gnn import GNNConfig

CONFIG = GNNConfig(
    name="graphcast", n_layers=16, d_hidden=512, d_feat=227, n_vars=227,
    d_edge=64, aggregator="sum", mesh_refinement=6, dtype=jnp.bfloat16,
)


def reduced():
    return GNNConfig(
        name="graphcast-reduced", n_layers=2, d_hidden=32, d_feat=16,
        n_vars=8, d_edge=8, dtype=jnp.float32,
    )


ARCH = ArchSpec(
    id="graphcast", family="gnn", config=CONFIG, shapes=GNN_SHAPES,
    skips={}, reduced=reduced,
)
