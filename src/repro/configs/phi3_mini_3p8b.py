"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — RoPE SwiGLU GQA.  [arXiv:2404.14219; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, dtype=jnp.bfloat16,
)


def reduced():
    return TransformerConfig(
        name="phi3-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, dtype=jnp.float32, chunk_q=16,
    )


ARCH = ArchSpec(
    id="phi3-mini-3.8b", family="lm", config=CONFIG, shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch: 500k-context decode "
           "requires sub-quadratic attention state (assignment spec)."},
    reduced=reduced,
)
