"""qwen1.5-4b [dense]: 40L d_model=2560 20H (GQA kv=20) d_ff=6912
vocab=151936 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen1.5-4b", n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab=151936, qkv_bias=True, dtype=jnp.bfloat16,
)


def reduced():
    return TransformerConfig(
        name="qwen-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=256, qkv_bias=True, dtype=jnp.float32, chunk_q=16,
    )


ARCH = ArchSpec(
    id="qwen1.5-4b", family="lm", config=CONFIG, shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch: 500k-context decode "
           "requires sub-quadratic attention state (assignment spec)."},
    reduced=reduced,
)
