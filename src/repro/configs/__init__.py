"""Architecture registry: one module per assigned arch (+ the paper's own
serving scenario is exercised through the recsys archs' retrieval shape).

``get_arch(id)`` / ``list_archs()`` are the ``--arch`` surface.
"""
from repro.configs.base import ArchSpec

_MODULES = {
    "arctic-480b": "repro.configs.arctic_480b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3p8b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "graphcast": "repro.configs.graphcast",
    "autoint": "repro.configs.autoint",
    "xdeepfm": "repro.configs.xdeepfm",
    "wide-deep": "repro.configs.wide_deep",
    "deepfm": "repro.configs.deepfm",
}


def list_archs():
    return sorted(_MODULES)


def get_arch(arch_id: str) -> ArchSpec:
    import importlib

    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[arch_id]).ARCH
