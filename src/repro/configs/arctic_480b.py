"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_ff=4864, capacity_factor=1.25),
    moe_dense_residual=True, dtype=jnp.bfloat16,
)


def reduced():
    return TransformerConfig(
        name="arctic-reduced", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=96, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=96),
        moe_dense_residual=True, dtype=jnp.float32, chunk_q=16,
    )


ARCH = ArchSpec(
    id="arctic-480b", family="lm", config=CONFIG, shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch: 500k-context decode "
           "requires sub-quadratic attention state (assignment spec); "
           "no sliding-window/SSM layers to bound the KV cache."},
    reduced=reduced,
)
