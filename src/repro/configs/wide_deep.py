"""wide-deep [recsys]: n_sparse=40 embed_dim=32 mlp=1024-512-256
interaction=concat.  [arXiv:1606.07792; paper]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, make_recsys_vocabs
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="wide-deep", vocab_sizes=make_recsys_vocabs(40, seed=103),
    embed_dim=32, interaction="concat", mlp_dims=(1024, 512, 256),
    dtype=jnp.float32,
)


def reduced():
    return RecsysConfig(
        name="wide-deep-reduced", vocab_sizes=(50, 30, 80, 20), embed_dim=8,
        interaction="concat", mlp_dims=(32, 16), dtype=jnp.float32,
    )


ARCH = ArchSpec(
    id="wide-deep", family="recsys", config=CONFIG, shapes=RECSYS_SHAPES,
    skips={}, reduced=reduced,
)
