"""Assigned input-shape sets, one per architecture family (verbatim from
the assignment; every (arch x shape) pair is a dry-run cell)."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve | retrieval | graph_train
    # LM
    seq_len: int = 0
    global_batch: int = 0
    # recsys
    batch: int = 0
    n_candidates: int = 0
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple = ()
    n_graphs: int = 0
    nodes_per_graph: int = 0
    edges_per_graph: int = 0


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "graph_train", n_nodes=2708, n_edges=10556, d_feat=1433
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "graph_train", n_nodes=232965, n_edges=114615892,
        d_feat=602, batch_nodes=1024, fanout=(15, 10),
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "graph_train", n_nodes=2449029, n_edges=61859140, d_feat=100
    ),
    "molecule": ShapeSpec(
        "molecule", "graph_train", n_graphs=128, nodes_per_graph=30,
        edges_per_graph=64, d_feat=64,
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "serve", batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", batch=262144),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000
    ),
}
