"""autoint [recsys]: n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2
d_attn=32 interaction=self-attn.  [arXiv:1810.11921; paper]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, make_recsys_vocabs
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="autoint", vocab_sizes=make_recsys_vocabs(39, seed=101),
    embed_dim=16, interaction="self-attn", attn_layers=3, attn_heads=2,
    d_attn=32, dtype=jnp.float32,
)


def reduced():
    return RecsysConfig(
        name="autoint-reduced", vocab_sizes=(50, 30, 80, 20), embed_dim=8,
        interaction="self-attn", attn_layers=2, attn_heads=2, d_attn=4,
        dtype=jnp.float32,
    )


ARCH = ArchSpec(
    id="autoint", family="recsys", config=CONFIG, shapes=RECSYS_SHAPES,
    skips={}, reduced=reduced,
)
