"""xdeepfm [recsys]: n_sparse=39 embed_dim=10 cin_layers=200-200-200
mlp=400-400 interaction=cin.  [arXiv:1803.05170; paper]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, make_recsys_vocabs
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="xdeepfm", vocab_sizes=make_recsys_vocabs(39, seed=102),
    embed_dim=10, interaction="cin", cin_layers=(200, 200, 200),
    mlp_dims=(400, 400), dtype=jnp.float32,
)


def reduced():
    return RecsysConfig(
        name="xdeepfm-reduced", vocab_sizes=(50, 30, 80, 20), embed_dim=8,
        interaction="cin", cin_layers=(12, 12), mlp_dims=(32, 16),
        dtype=jnp.float32,
    )


ARCH = ArchSpec(
    id="xdeepfm", family="recsys", config=CONFIG, shapes=RECSYS_SHAPES,
    skips={}, reduced=reduced,
)
