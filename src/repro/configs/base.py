"""ArchSpec: one selectable ``--arch`` entry = model config + its shape
set + per-shape skips (with reasons) + a reduced config for CPU smoke
tests."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

from repro.configs.shapes import ShapeSpec


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str  # lm | gnn | recsys
    config: Any
    shapes: Dict[str, ShapeSpec]
    skips: Dict[str, str]  # shape name -> reason (recorded in EXPERIMENTS.md)
    reduced: Callable[[], Any]  # small same-family config for smoke tests

    def active_shapes(self):
        return {k: v for k, v in self.shapes.items() if k not in self.skips}


def make_recsys_vocabs(n_fields: int, seed: int, lo: int = 100, hi: int = 10_000_000):
    """Deterministic log-uniform vocab sizes (Criteo-like long tail).

    Real CTR tables mix a few 1e6-1e7-row id fields with many small
    categorical fields; total lands in the tens of millions of rows."""
    import numpy as np

    rng = np.random.default_rng(seed)
    sizes = np.exp(rng.uniform(np.log(lo), np.log(hi), size=n_fields)).astype(np.int64)
    return tuple(int(s) for s in sizes)
