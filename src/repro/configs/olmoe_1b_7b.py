"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8.  [arXiv:2409.02060; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="olmoe-1b-7b", n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024, capacity_factor=1.25),
    dtype=jnp.bfloat16,
)


def reduced():
    return TransformerConfig(
        name="olmoe-reduced", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=48, vocab=256, moe=MoEConfig(n_experts=8, top_k=4, d_ff=48),
        dtype=jnp.float32, chunk_q=16,
    )


ARCH = ArchSpec(
    id="olmoe-1b-7b", family="lm", config=CONFIG, shapes=LM_SHAPES,
    skips={"long_500k": "pure full-attention arch: 500k-context decode "
           "requires sub-quadratic attention state (assignment spec)."},
    reduced=reduced,
)
