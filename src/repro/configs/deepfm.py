"""deepfm [recsys]: n_sparse=39 embed_dim=10 mlp=400-400-400
interaction=fm.  [arXiv:1703.04247; paper]"""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, make_recsys_vocabs
from repro.configs.shapes import RECSYS_SHAPES
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="deepfm", vocab_sizes=make_recsys_vocabs(39, seed=104),
    embed_dim=10, interaction="fm", mlp_dims=(400, 400, 400),
    dtype=jnp.float32,
)


def reduced():
    return RecsysConfig(
        name="deepfm-reduced", vocab_sizes=(50, 30, 80, 20), embed_dim=8,
        interaction="fm", mlp_dims=(32, 16), dtype=jnp.float32,
    )


ARCH = ArchSpec(
    id="deepfm", family="recsys", config=CONFIG, shapes=RECSYS_SHAPES,
    skips={}, reduced=reduced,
)
