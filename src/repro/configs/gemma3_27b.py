"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding-window hybrid, 128k-class context.
[hf:google/gemma-3-1b-pt; unverified]

Adaptation notes: head_dim derived as d_model//n_heads=168
(the HF release uses 128 with a separate head width; the assignment
config pins d_model/heads, so we derive).  Local window = 1024 tokens,
every 6th layer global — the published 5:1 pattern.  long_500k runs for
this arch: 52/62 layers hold only a 1024-slot ring cache."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec
from repro.configs.shapes import LM_SHAPES
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma3-27b", n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144, window=1024, global_every=6, dtype=jnp.bfloat16,
)


def reduced():
    return TransformerConfig(
        name="gemma3-reduced", n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, window=8, global_every=3,
        dtype=jnp.float32, chunk_q=16,
    )


ARCH = ArchSpec(
    id="gemma3-27b", family="lm", config=CONFIG, shapes=LM_SHAPES,
    skips={},  # hybrid local:global -> long_500k runs (ring caches)
    reduced=reduced,
)
