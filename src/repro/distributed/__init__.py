from repro.distributed.context import (
    axis_rules,
    constrain,
    current_mesh,
    current_rules,
    logical_to_spec,
    multi_pod_rules,
    single_pod_rules,
)
