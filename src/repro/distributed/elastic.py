"""Elastic re-meshing: when hosts are lost (or added), rebuild the mesh
from the surviving device count and reshard training state from the last
checkpoint.

The policy keeps the model axis fixed when possible (param shardings
remain valid) and shrinks the data axis — DP degree is the elastic
dimension, which is how production fleets handle node loss without
invalidating the TP layout.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np


def choose_mesh_shape(n_devices: int, model_pref: int) -> Tuple[int, int]:
    """Largest (data, model) grid with model | model_pref, maximizing used
    devices; prefers keeping the full model axis."""
    for model in sorted(
        {m for m in range(1, model_pref + 1) if model_pref % m == 0}, reverse=True
    ):
        data = n_devices // model
        if data >= 1:
            return data, model
    return n_devices, 1


def make_elastic_mesh(devices, model_pref: int):
    """Mesh over an explicit device list (survivors)."""
    n = len(devices)
    data, model = choose_mesh_shape(n, model_pref)
    used = np.asarray(devices[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(used, ("data", "model"))


def reshard(tree, shardings):
    """Move/reshard a pytree onto new shardings (device_put handles the
    cross-mesh transfer; after a failure this is a restore-from-checkpoint
    placement in practice)."""
    return jax.device_put(tree, shardings)
