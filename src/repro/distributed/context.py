"""Logical-axis sharding context.

Model code never names mesh axes directly; it constrains activations by
*logical* names ("batch", "seq", "model", "experts", "vocab", ...) via
``constrain``.  The launch layer installs a rule table mapping logical
names to mesh axes; outside any mesh (unit tests, single-device smoke
runs) everything is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Mapping, Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

AxisVal = Union[None, str, Sequence[str]]

_RULES: contextvars.ContextVar[Optional[Mapping[str, AxisVal]]] = contextvars.ContextVar(
    "axis_rules", default=None
)

# Canonical rule tables (sharding rules; DESIGN.md §4).  "dp" is the pure-data axis name
# set; on the multi-pod mesh the pod axis composes with data.
def single_pod_rules() -> Mapping[str, AxisVal]:
    return {
        "batch": ("data",),
        "fsdp": ("data",),
        "model": "model",
        "experts": "model",
        "vocab": "model",
        "heads": "model",
        "kv_seq": "model",
        "ff": "model",
        "rows": "model",  # embedding-table rows
        "nodes": ("data", "model"),  # GNN full-graph node sharding
        "edges": ("data", "model"),
    }


def multi_pod_rules() -> Mapping[str, AxisVal]:
    return {
        "batch": ("pod", "data"),
        "fsdp": ("pod", "data"),
        "model": "model",
        "experts": "model",
        "vocab": "model",
        "heads": "model",
        "kv_seq": "model",
        "ff": "model",
        "rows": "model",
        "nodes": ("pod", "data", "model"),
        "edges": ("pod", "data", "model"),
    }


def fsdp_ep_rules(multi_pod: bool) -> Mapping[str, AxisVal]:
    """Beyond-paper LM profile (§Perf): no tensor parallelism — dense
    params ZeRO-3-sharded over ALL axes (gathered per layer), activations
    sharded batch x sequence (the "model" axis carries SEQUENCE, not
    heads), experts stay expert-parallel on "model".  Kills the
    per-layer Megatron activation all-reduces."""
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "seq": "model",
        "fsdp": dp + ("model",),
        "fsdp_expert": dp,  # experts already consume "model"
        "model": "model",
        "experts": "model",
        "vocab": "model",
        "heads": None,
        "kv_seq": "model",
        "ff": None,
        "rows": "model",
        "nodes": dp + ("model",),
        "edges": dp + ("model",),
    }


def recsys_a2a_rules(multi_pod: bool) -> Mapping[str, AxisVal]:
    """Beyond-paper recsys profile (§Perf): batch sharded over ALL axes,
    embedding rows exchanged via all_to_all instead of dense psum."""
    base = dict(multi_pod_rules() if multi_pod else single_pod_rules())
    base["batch"] = (("pod", "data", "model") if multi_pod
                     else ("data", "model"))
    base["rows"] = base["batch"]  # table rows over the full device grid
    return base


def make_mesh_compat(axis_shapes, axis_names, devices=None):
    """``jax.make_mesh`` with Auto axis types where supported.

    ``jax.sharding.AxisType`` landed after jax 0.4.x; on older jax the
    plain mesh (implicitly auto) is equivalent for our profiles, so fall
    back rather than pinning a floor we can't install everywhere.
    """
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kwargs["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def shard_map_compat(f, *, mesh, in_specs, out_specs, check=False):
    """``jax.shard_map`` across jax versions.

    jax >= 0.5 exposes ``jax.shard_map(..., check_vma=)``; 0.4.x only has
    ``jax.experimental.shard_map.shard_map(..., check_rep=)``.  Same
    semantics for our SPMD bodies either way.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=check
        )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check
    )


_MESH: contextvars.ContextVar = contextvars.ContextVar("mesh", default=None)


@contextlib.contextmanager
def axis_rules(rules: Optional[Mapping[str, AxisVal]], mesh=None):
    tok = _RULES.set(rules)
    tok_m = _MESH.set(mesh)
    try:
        yield
    finally:
        _RULES.reset(tok)
        _MESH.reset(tok_m)


def current_mesh():
    return _MESH.get()


def data_axis_names() -> tuple:
    """Concrete mesh axes behind the logical batch/data axis."""
    rules = _RULES.get()
    if rules is None:
        return ()
    v = rules.get("batch")
    if v is None:
        return ()
    return (v,) if isinstance(v, str) else tuple(v)


def current_rules() -> Optional[Mapping[str, AxisVal]]:
    return _RULES.get()


def logical_to_spec(*names: Optional[str]) -> P:
    rules = _RULES.get()
    if rules is None:
        return P()
    resolved = []
    for n in names:
        if n is None:
            resolved.append(None)
        else:
            r = rules.get(n)
            resolved.append(tuple(r) if isinstance(r, (list, tuple)) else r)
    return P(*resolved)


def constrain(x, *names: Optional[str]):
    """with_sharding_constraint by logical axis names; no-op without rules."""
    if _RULES.get() is None:
        return x
    mesh = _MESH.get()
    spec = logical_to_spec(*names)
    if mesh is not None:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec)
        )
    return jax.lax.with_sharding_constraint(x, spec)


def axis_size(logical: str) -> int:
    """Product of mesh-axis sizes a logical name maps to (1 outside mesh)."""
    rules = _RULES.get()
    if rules is None:
        return 1
    val = rules.get(logical)
    if val is None:
        return 1
    names = (val,) if isinstance(val, str) else tuple(val)
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    size = 1
    for n in names:
        size *= mesh.shape.get(n, 1)
    return size


def model_axis_name() -> Optional[str]:
    """Concrete mesh-axis name for the logical 'model' axis (or None)."""
    rules = _RULES.get()
    if rules is None:
        return None
    v = rules.get("model")
    if isinstance(v, (list, tuple)):
        return v[0] if v else None
    return v
