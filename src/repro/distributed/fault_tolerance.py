"""Fault-tolerance policies: heartbeat tracking, straggler detection,
restart bookkeeping.

The policies are pure logic over reported timings/heartbeats so they are
unit-testable on one host and drop into a real multi-host launcher
unchanged: the launcher feeds real heartbeats instead of simulated ones.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional


@dataclasses.dataclass
class HostState:
    host_id: int
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    """Declares a host dead when its heartbeat is older than ``timeout``."""

    def __init__(self, n_hosts: int, timeout: float = 60.0, clock=time.monotonic):
        self.clock = clock
        self.timeout = timeout
        self.hosts: Dict[int, HostState] = {
            h: HostState(h, clock()) for h in range(n_hosts)
        }

    def beat(self, host_id: int):
        st = self.hosts[host_id]
        st.last_heartbeat = self.clock()
        st.alive = True

    def dead_hosts(self) -> List[int]:
        now = self.clock()
        out = []
        for st in self.hosts.values():
            if st.alive and now - st.last_heartbeat > self.timeout:
                st.alive = False
            if not st.alive:
                out.append(st.host_id)
        return out

    def alive_hosts(self) -> List[int]:
        self.dead_hosts()
        return [h for h, st in self.hosts.items() if st.alive]


class StragglerPolicy:
    """Flags hosts whose recent step time exceeds ``factor`` x the fleet
    median over a sliding window.  Mitigation at the driver: exclude the
    straggler from the next re-mesh (it rejoins when healthy) — the
    standard "deadline + respawn" pattern."""

    def __init__(self, factor: float = 2.0, window: int = 8, min_samples: int = 3):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.times: Dict[int, List[float]] = {}

    def report(self, host_id: int, step_time: float):
        buf = self.times.setdefault(host_id, [])
        buf.append(step_time)
        del buf[: -self.window]

    def stragglers(self) -> List[int]:
        if len(self.times) < 2:
            return []
        medians = {}
        for h, buf in self.times.items():
            if len(buf) >= self.min_samples:
                s = sorted(buf)
                medians[h] = s[len(s) // 2]
        if len(medians) < 2:
            return []
        fleet = sorted(medians.values())[len(medians) // 2]
        return [h for h, m in medians.items() if m > self.factor * fleet]


@dataclasses.dataclass
class RestartBudget:
    """Crash-loop guard: at most ``max_restarts`` within ``horizon_s``."""

    max_restarts: int = 10
    horizon_s: float = 3600.0
    events: List[float] = dataclasses.field(default_factory=list)

    def record(self, now: Optional[float] = None) -> bool:
        now = time.monotonic() if now is None else now
        self.events.append(now)
        self.events = [t for t in self.events if now - t <= self.horizon_s]
        return len(self.events) <= self.max_restarts
