"""Fault-tolerant checkpointing.

* **atomic commit** — arrays are written into ``<dir>/.tmp.step_N`` and
  the directory is ``os.rename``d to ``step_N`` only after every file is
  flushed; a crash mid-save can never produce a half-readable step;
* **async** — saves run on a background thread (double-buffered: the next
  save joins the previous one), so the train loop never blocks on disk;
* **auto-resume** — ``latest_step`` scans for the newest committed step;
  restore validates the tree structure against a skeleton and returns
  arrays with their recorded dtypes (bf16 round-trips via a uint16 view);
* **multi-host layout** — each host writes only its ``process_index``
  shard file; on this single-process container that is one file, but the
  layout and naming mirror the production contract.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten_with_names(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = _SEP.join(_key_str(k) for k in path)
        flat[name] = leaf
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = os.path.join(directory, f".tmp.step_{step:08d}.{os.getpid()}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten_with_names(tree)
    arrays, meta = {}, {"step": step, "dtypes": {}, "names": sorted(flat)}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        meta["dtypes"][name] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[name] = arr
    shard = f"arrays.p{jax.process_index()}.npz"
    with open(os.path.join(tmp, shard), "wb") as f:
        np.savez(f, **{n.replace("/", "|"): a for n, a in arrays.items()})
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.isfile(os.path.join(d if os.path.isabs(d) else os.path.join(directory, d), "meta.json"))
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, skeleton: Any, step: Optional[int] = None) -> Tuple[int, Any]:
    """Restore into the structure of ``skeleton`` (shapes/dtypes validated)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    shard = os.path.join(path, f"arrays.p{jax.process_index()}.npz")
    with np.load(shard) as z:
        arrays = {n.replace("|", "/"): z[n] for n in z.files}

    flat_skel = _flatten_with_names(skeleton)
    if sorted(flat_skel) != sorted(meta["names"]):
        missing = set(meta["names"]) ^ set(flat_skel)
        raise ValueError(f"checkpoint tree mismatch: {sorted(missing)[:5]} ...")

    def rebuild(name, skel_leaf):
        arr = arrays[name]
        want = meta["dtypes"][name]
        if want == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(skel_leaf.shape):
            raise ValueError(f"{name}: shape {arr.shape} != {skel_leaf.shape}")
        return jnp.asarray(arr)

    leaves_named = _flatten_with_names(skeleton)
    restored_flat = {n: rebuild(n, l) for n, l in leaves_named.items()}
    treedef = jax.tree_util.tree_structure(skeleton)
    ordered = [
        restored_flat[_SEP.join(_key_str(k) for k in path)]
        for path, _ in jax.tree_util.tree_flatten_with_path(skeleton)[0]
    ]
    return step, jax.tree_util.tree_unflatten(treedef, ordered)


class Checkpointer:
    """Async double-buffered checkpointer with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save_async(self, step: int, tree: Any):
        self.wait()
        tree = jax.device_get(tree)  # snapshot before the train loop mutates

        def work():
            save_checkpoint(self.directory, step, tree)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
