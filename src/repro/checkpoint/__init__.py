from repro.checkpoint.checkpointer import (
    Checkpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
