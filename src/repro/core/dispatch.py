"""One front door for greedy DPP MAP inference.

Every greedy variant in the repo — exact Algorithm 1 (dense or low-rank,
single or batched), the sliding-window incremental variant, the Pallas
whole-slate-in-VMEM kernel, and the candidate-sharded multi-device path
— is reachable through ``greedy_map`` with a ``GreedySpec``.  The
serving reranker and the benchmark harness both dispatch through here,
so a config change (say, turning on a window for long feeds, or
spreading the candidate axis over a mesh) never requires touching call
sites.

Dispatch rules:

* kernel representation — pass exactly one of ``L`` (dense, (M, M) or
  (B, M, M)) or ``V`` (low-rank ``L = V^T V``, (D, M) or (B, D, M));
* ``spec.window`` — ``None`` (or ``>= k``) runs the exact Algorithm 1;
  smaller windows run the O(w M)-per-step incremental sliding-window
  greedy (unbounded slate length);
* ``spec.backend`` — "jnp" lowers through XLA; "pallas" routes low-rank
  inputs through the TPU kernels (interpret-mode on CPU; dense inputs
  are rejected — the kernels never materialize L); "sharded" shards the
  candidate axis M over ``spec.mesh``'s ``spec.axis_name`` (low-rank;
  batched V runs all B users on the mesh at once); "auto" picks
  "sharded" when a mesh is set, else "jnp";
* ``spec.tile_m`` — candidate-axis tile for the Pallas kernels.  On the
  pallas backend it forces the tiled streaming kernels (by default
  ``TilePolicy`` keeps the whole-working-set resident kernels while
  they fit VMEM and tiles past that); on the sharded backend each
  device's local per-step update reuses the same tiled kernel on its
  (D, M/P) shard.
* ``spec.chunk_size`` — greedy steps per resumable chunk.  On the
  pallas backend ``greedy_map`` then runs the slate as fused multi-step
  chunk kernels (one pallas_call — one HBM C/d2 round-trip — per
  chunk, the ROADMAP's sweep-fusion headroom); on the sharded backend
  the slate advances chunk-by-chunk with the loop state staying
  device-resident between chunks.  Both produce the identical slate to
  unchunked execution.  The pure-jnp whole-slate path has no chunked
  execution, so ``chunk_size`` with ``backend='jnp'`` (or ``'auto'``
  without a mesh) is rejected at construction — mirroring the
  ``tile_m`` rule; jnp *streaming* passes ``chunk_size=`` to
  ``greedy_map_chunks`` directly instead.

``greedy_map_chunks`` is the streaming front door: a generator yielding
per-chunk ``GreedyResult``s whose concatenation is exactly the
whole-slate ``greedy_map`` result (see ``repro.core.streaming``).

``GreedySpec`` validates itself at construction — a bad config raises
``GreedySpecError`` (a ``ValueError``) at spec-build time instead of
surfacing as a shape or trace error deep inside a jitted computation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp

from repro.core.greedy_chol import (
    GreedyResult,
    dpp_greedy_dense,
    dpp_greedy_dense_batch,
    dpp_greedy_lowrank,
    dpp_greedy_lowrank_batch,
)
from repro.core.windowed import (
    dpp_greedy_windowed,
    dpp_greedy_windowed_batch,
    dpp_greedy_windowed_lowrank,
    dpp_greedy_windowed_lowrank_batch,
)
from repro.obs.dispatch import record_greedy_map

_BACKENDS = ("auto", "jnp", "pallas", "sharded")


class GreedySpecError(ValueError):
    """Invalid ``GreedySpec`` — raised at spec construction time."""


@dataclasses.dataclass(frozen=True)
class GreedySpec:
    """How to run greedy MAP: slate size, window, backend, mesh, tolerance."""

    k: int
    window: Optional[int] = None  # None = exact Algorithm 1
    backend: str = "auto"  # "auto" | "jnp" | "pallas" | "sharded"
    eps: float = 1e-6
    interpret: bool = True  # Pallas interpret mode (CPU dev/test)
    mesh: Optional[object] = None  # jax Mesh for the sharded backend
    axis_name: str = "data"  # mesh axis the candidate axis shards over
    # Pallas candidate-axis tile: an explicit LANE multiple, "auto"
    # (measured autotune cache, model fallback), or None (VMEM model)
    tile_m: Union[int, str, None] = None
    chunk_size: Optional[int] = None  # greedy steps per resumable chunk

    def __post_init__(self):
        if self.k <= 0:
            raise GreedySpecError(f"k must be >= 1, got {self.k}")
        if self.window is not None and self.window < 1:
            raise GreedySpecError(f"window must be >= 1, got {self.window}")
        if self.chunk_size is not None:
            if self.chunk_size < 1:
                raise GreedySpecError(
                    f"chunk_size must be >= 1, got {self.chunk_size}"
                )
            if self.backend == "jnp" or (
                self.backend == "auto" and self.mesh is None
            ):
                raise GreedySpecError(
                    "chunk_size= selects chunked execution, which only the "
                    "pallas (fused multi-step chunk kernels) and sharded "
                    "(device-resident chunk state) backends implement — on "
                    "the jnp whole-slate path it would be silently ignored; "
                    "stream through greedy_map_chunks(..., chunk_size=) "
                    "instead"
                )
        if self.tile_m is not None:
            from repro.kernels.dpp_greedy.tiling import validate_tile_m

            try:
                validate_tile_m(self.tile_m, allow_auto=True)
            except ValueError as e:
                raise GreedySpecError(str(e)) from None
            if self.tile_m == "auto" and self.backend != "pallas":
                raise GreedySpecError(
                    'tile_m="auto" consults the measured autotune cache, '
                    "which only the single-device Pallas dispatch does "
                    "(backend='pallas') — the jnp backend ignores tile_m "
                    "entirely and the sharded per-device update needs an "
                    "explicit LANE multiple"
                )
            if self.backend == "jnp" or (
                self.backend == "auto" and self.mesh is None
            ):
                raise GreedySpecError(
                    "tile_m= (an int or \"auto\") only applies to the "
                    "Pallas kernels (backend='pallas', or 'sharded'/'auto' "
                    "with a mesh) — on the jnp backend it would be "
                    "silently ignored"
                )
        if self.backend not in _BACKENDS:
            raise GreedySpecError(
                f"unknown backend {self.backend!r}; expected one of {_BACKENDS}"
            )
        if self.backend == "sharded" and self.mesh is None:
            raise GreedySpecError("backend='sharded' needs mesh= (and axis_name=)")
        if self.mesh is not None and self.backend not in ("auto", "sharded"):
            raise GreedySpecError(
                f"mesh= only applies to the sharded backend (backend='sharded' "
                f"or 'auto'), not {self.backend!r} — a mesh with a "
                f"single-device backend would be silently ignored"
            )

    def windowed(self) -> bool:
        return self.window is not None and self.window < self.k

    def sharded(self) -> bool:
        return self.backend == "sharded" or (
            self.backend == "auto" and self.mesh is not None
        )


def greedy_map(
    spec: GreedySpec,
    *,
    L: Optional[jnp.ndarray] = None,
    V: Optional[jnp.ndarray] = None,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Run greedy DPP MAP per ``spec`` on a dense (L) or low-rank (V) kernel.

    Accepts single problems (L (M, M) / V (D, M)) and user batches
    (L (B, M, M) / V (B, D, M)); returns a ``GreedyResult`` whose leaves
    gain a leading batch dimension in the batched case.  The sharded
    backend is low-rank only; batched inputs keep the candidate axis
    sharded and run all B users on the mesh at once.

    ``mask`` may be per-problem ((M,) single / (B, M) batched) or — a
    shared candidate filter applied to every user of a batch — a single
    (M,) vector alongside a batched L/V; it is broadcast to (B, M)
    before dispatch so every backend sees the same per-user shape.
    """
    if (L is None) == (V is None):
        raise ValueError("pass exactly one of L= (dense) or V= (low-rank)")
    if spec.backend == "pallas" and L is not None:
        raise ValueError(
            "backend='pallas' needs the low-rank V — the kernel never "
            "materializes the dense L"
        )

    kern = L if L is not None else V
    if mask is not None and kern.ndim == 3 and mask.ndim == 1:
        # shared (M,) mask with a batched kernel: every backend's batch
        # path consumes a (B, M) mask (the jnp paths vmap over it, the
        # pallas kernel reshapes to (B, 1, M)), so broadcast here once
        mask = jnp.broadcast_to(mask, (kern.shape[0], mask.shape[0]))

    # static shapes only — trace-safe; chunked runs count their launched
    # steps per chunk (greedy_chunk), unchunked ones here
    record_greedy_map(
        "sharded" if spec.sharded()
        else "pallas" if spec.backend == "pallas" else "jnp",
        B=kern.shape[0] if kern.ndim == 3 else 1,
        k=spec.k,
        M=kern.shape[-1],
        chunked=spec.chunk_size is not None,
    )

    if spec.chunk_size is not None:
        # chunked whole-slate execution (pallas: fused multi-step chunk
        # kernels; sharded: device-resident chunk state) — identical
        # slate to the unchunked paths, validated by tests/test_streaming
        chunks = list(greedy_map_chunks(spec, L=L, V=V, mask=mask))
        sel = jnp.concatenate([c.indices for c in chunks], axis=-1)
        dh = jnp.concatenate([c.d_hist for c in chunks], axis=-1)
        n = jnp.sum(sel >= 0, axis=-1).astype(jnp.int32)
        return GreedyResult(sel, n, dh)

    if spec.sharded():
        if L is not None:
            raise ValueError(
                "backend='sharded' needs the low-rank V — a dense L cannot "
                "be candidate-sharded"
            )
        from repro.core.sharded import dpp_greedy_sharded

        return dpp_greedy_sharded(
            V,
            spec.k,
            mesh=spec.mesh,
            axis_name=spec.axis_name,
            window=spec.window,
            eps=spec.eps,
            mask=mask,
            tile_m=spec.tile_m,
            interpret=spec.interpret,
        )

    if spec.backend == "pallas":
        from repro.kernels.dpp_greedy import dpp_greedy as dpp_greedy_pallas

        batched = V.ndim == 3
        Vb = V if batched else V[None]
        mb = mask if (mask is None or batched) else mask[None]
        sel, dh = dpp_greedy_pallas(
            Vb,
            spec.k,
            mask=mb,
            eps=spec.eps,
            interpret=spec.interpret,
            window=spec.window,
            tile_m=spec.tile_m,
        )
        n = jnp.sum(sel >= 0, axis=-1).astype(jnp.int32)
        res = GreedyResult(sel, n, dh)
        if batched:
            return res
        return GreedyResult(sel[0], n[0], dh[0])

    if L is not None:
        batched = L.ndim == 3
        if spec.windowed():
            fn = dpp_greedy_windowed_batch if batched else dpp_greedy_windowed
            return fn(L, spec.k, spec.window, spec.eps, mask)
        fn = dpp_greedy_dense_batch if batched else dpp_greedy_dense
        return fn(L, spec.k, spec.eps, mask)

    batched = V.ndim == 3
    if spec.windowed():
        fn = (
            dpp_greedy_windowed_lowrank_batch
            if batched
            else dpp_greedy_windowed_lowrank
        )
        return fn(V, spec.k, spec.window, spec.eps, mask)
    fn = dpp_greedy_lowrank_batch if batched else dpp_greedy_lowrank
    return fn(V, spec.k, spec.eps, mask)


def greedy_map_chunks(
    spec: GreedySpec,
    *,
    L: Optional[jnp.ndarray] = None,
    V: Optional[jnp.ndarray] = None,
    mask: Optional[jnp.ndarray] = None,
    chunk_size: Optional[int] = None,
):
    """Generator running greedy MAP per ``spec`` in resumable chunks.

    Yields ``ceil(k / chunk)`` :class:`GreedyResult`s whose ``indices``
    / ``d_hist`` cover ``chunk`` selections each (the last chunk is
    short when ``chunk`` does not divide ``k``); their concatenation is
    exactly the whole-slate ``greedy_map`` result — indices
    index-for-index, ``d_hist`` bitwise on jnp and to ~1 ulp across
    kernels.  After an eps-stop the remaining slots hold -1 / 0, as the
    whole-slate tail does.

    ``chunk_size`` overrides ``spec.chunk_size`` — that is how the jnp
    backend (whose spec cannot carry a chunk size, see ``GreedySpec``)
    streams.  Backends: jnp takes single problems (dense L or low-rank
    V); pallas and sharded take single or batched low-rank V.
    """
    from repro.core.streaming import greedy_chunk, greedy_init, resolve_chunk

    chunk = resolve_chunk(spec, chunk_size)
    kern = L if L is not None else V
    if mask is not None and kern is not None and kern.ndim == 3 \
            and mask.ndim == 1:
        mask = jnp.broadcast_to(mask, (kern.shape[0], mask.shape[0]))
    state = greedy_init(spec, L=L, V=V, mask=mask)
    # pad/cast the kernel operand to the state's padded geometry ONCE —
    # the chunk executors skip their copy when the shape already
    # matches, so the loop below moves no O(D M) data per chunk
    if spec.sharded():
        from repro.core.sharded import _stream_pad

        V = _stream_pad(V, state.d2.shape[-1])
    elif spec.backend == "pallas":
        from repro.kernels.dpp_greedy import dpp_greedy_stream_pad

        V = dpp_greedy_stream_pad(V, state)
    done = 0
    while done < spec.k:
        c = min(chunk, spec.k - done)
        state, sel, dh = greedy_chunk(spec, state, L=L, V=V, chunk_size=c)
        n = jnp.sum(sel >= 0, axis=-1).astype(jnp.int32)
        yield GreedyResult(sel, n, dh)
        done += c
