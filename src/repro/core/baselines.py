"""Reference diversification algorithms the paper compares against (§5).

* MMR   (Carbonell & Goldstein '98, paper eq. (25)):
      j = argmax  theta*r_i + (1-theta) * min_{k in R} (1 - S_ki)
* Greedy (Bradley & Smyth '01, paper eq. (26)):
      j = argmax  theta*r_i + (1-theta) * mean_{k in R} (1 - S_ki)
  (The paper's displayed eq. (26) shows ``max`` but the surrounding text
  — "(26) uses the average dissimilarity" — and the cited [3] both say
  *average*; we implement average and note the typo.)
* Random/Top (paper §5): sample N uniformly from the N+b most relevant
  (b=0 degenerates to pure Top-N).

All selectors share the fixed-shape conventions of ``greedy_chol``:
(M,) relevance, (M, M) similarity, optional (M,) selectable mask, output
(N,) int32 indices (no early stop — these methods always fill N slots,
as in the paper).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -jnp.inf


def _select_loop(r, S, k, theta, mask, use_min: bool):
    M = r.shape[0]
    dtype = r.dtype
    avail = jnp.where(mask, 0.0, NEG_INF).astype(dtype)

    # State trackers for the dissimilarity aggregate over selected items.
    min_dis = jnp.ones((M,), dtype)  # min over empty set := 1 (constant
    sum_dis = jnp.zeros((M,), dtype)  # -> first pick is argmax relevance)
    sel = jnp.full((k,), -1, jnp.int32)

    def body(t, state):
        min_dis, sum_dis, avail, sel = state
        agg = min_dis if use_min else jnp.where(t == 0, 1.0, sum_dis / jnp.maximum(t, 1))
        score = theta * r + (1.0 - theta) * agg + avail
        j = jnp.argmax(score)
        dis_j = 1.0 - S[j]  # dissimilarity of every item to the new pick
        min_dis2 = jnp.minimum(min_dis, dis_j)
        sum_dis2 = sum_dis + dis_j
        avail = avail.at[j].set(NEG_INF)
        sel = sel.at[t].set(j)
        return min_dis2, sum_dis2, avail, sel

    _, _, _, sel = jax.lax.fori_loop(0, k, body, (min_dis, sum_dis, avail, sel))
    return sel


@partial(jax.jit, static_argnames=("k",))
def mmr_select(
    r: jnp.ndarray,
    S: jnp.ndarray,
    k: int,
    theta: float = 0.5,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """MMR (paper eq. (25)) — min-dissimilarity aggregate."""
    if mask is None:
        mask = jnp.ones(r.shape, bool)
    return _select_loop(r, S, k, jnp.asarray(theta, r.dtype), mask, use_min=True)


@partial(jax.jit, static_argnames=("k",))
def greedy_avg_select(
    r: jnp.ndarray,
    S: jnp.ndarray,
    k: int,
    theta: float = 0.5,
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Greedy [3] (paper eq. (26)) — average-dissimilarity aggregate."""
    if mask is None:
        mask = jnp.ones(r.shape, bool)
    return _select_loop(r, S, k, jnp.asarray(theta, r.dtype), mask, use_min=False)


def top_n_select(r: np.ndarray, k: int, mask: Optional[np.ndarray] = None) -> np.ndarray:
    """Pure relevance Top-N."""
    r = np.asarray(r)
    if mask is not None:
        r = np.where(mask, r, -np.inf)
    return np.argsort(-r, kind="stable")[:k]


def random_top_select(
    r: np.ndarray,
    k: int,
    b: int,
    rng: np.random.Generator,
    mask: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Random baseline (paper §5): N uniform picks from the N+b most relevant."""
    pool = top_n_select(r, k + b, mask)
    if b == 0:
        return pool
    return rng.choice(pool, size=min(k, pool.size), replace=False)
