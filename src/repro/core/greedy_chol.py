"""Fast greedy DPP MAP inference — the paper's Algorithm 1 ("Div-DPP").

Incremental-Cholesky greedy MAP approximation (paper §4.2):

* each remaining candidate ``i`` carries a row vector ``c_i`` and a scalar
  ``d_i^2 = L_ii - ||c_i||^2`` with ``det(L_{Y u {i}}) = det(L_Y) d_i^2``;
* selection (eq. 13):  ``j = argmax_i d_i``                    — O(M);
* update (eqs. 16-18): ``e_i = (L_ji - <c_j, c_i>) / d_j``,
  ``c_i <- [c_i e_i]``, ``d_i^2 <- d_i^2 - e_i^2``             — O(Mk);
* stop when ``#Y = N`` or ``d_j <= eps`` (eq. 20, justified by Thm 4.1).

TPU adaptation: ``c`` is pre-allocated ``(M, N)`` zeros and
column ``k`` is written at step ``k``; zero-padding makes the full-width
matvec ``c @ c_j`` exact, so each step is one MXU-friendly ``(M,N)x(N,)``
matvec.  Total work O(M N^2), memory O(M N) — the paper's complexity.

Two kernel representations:

* ``dpp_greedy_dense(L, ...)``   — explicit (M, M) kernel;
* ``dpp_greedy_lowrank(V, ...)`` — implicit ``L = V^T V`` with
  ``V (D, M)``; row ``L_j`` is recomputed as ``V[:, j] @ V`` on the fly
  (never materializes M^2 memory; the M=1e6 retrieval path).

Both run a fixed-trip-count ``lax.fori_loop`` with masked/predicated
updates so they jit, vmap and shard_map cleanly.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -jnp.inf


class GreedyResult(NamedTuple):
    """Result of greedy MAP inference.

    indices:     (N,) int32 — selected item ids in selection order; slots
                 after an eps-stop hold -1.
    n_selected:  ()  int32 — number of valid entries in ``indices``.
    d_hist:      (N,) float — the marginal-gain sequence d^k (paper
                 Thm 4.1: positive, non-increasing while selection runs).
                 Slots after the stop hold 0.
    """

    indices: jnp.ndarray
    n_selected: jnp.ndarray
    d_hist: jnp.ndarray


def greedy_step_exact(row_fn, t, c, d2, stopped, eps2):
    """One step of Algorithm 1 on the column-layout state ``c (M, k)``.

    Factored out of the ``_greedy_loop`` fori body so the whole-slate
    loop and the chunked/resumable executors in ``repro.core.streaming``
    run the *identical* op sequence — streamed chunks concatenate
    bitwise to the whole-slate result.  ``t`` is the absolute step
    index (the column of ``c`` the new Cholesky row lands in).

    Returns ``(c, d2, stopped, j, dj)``.
    """
    j = jnp.argmax(d2)
    dj2 = d2[j]
    # Stop rule (eq. 20): d_j <= eps  <=>  d_j^2 <= eps^2 (d_j >= 0).
    stopped = stopped | (dj2 <= eps2)
    dj = jnp.sqrt(jnp.maximum(dj2, eps2))  # guarded; unused when stopped
    # Update (eqs. 16-18): e = (L_j - c c_j) / d_j.
    e = (row_fn(j) - c @ c[j]) / dj
    e = jnp.where(stopped, jnp.zeros_like(e), e)
    c = c.at[:, t].set(e)
    d2_next = d2 - e * e
    d2_next = d2_next.at[j].set(NEG_INF)  # remove j from candidates
    d2 = jnp.where(stopped, d2, d2_next)
    return c, d2, stopped, j, dj


def _greedy_loop(diag, row_fn, k: int, eps: float, mask):
    """Shared greedy loop.

    diag:   (M,) float — L_ii for every candidate.
    row_fn: j -> (M,) float — returns row L_j of the kernel.
    mask:   (M,) bool — True where the candidate is selectable (profile
            items / padding are excluded with False).
    """
    M = diag.shape[0]
    dtype = diag.dtype
    eps2 = jnp.asarray(eps, dtype) ** 2

    d2 = jnp.where(mask, diag, NEG_INF)
    c = jnp.zeros((M, k), dtype)
    sel = jnp.full((k,), -1, jnp.int32)
    d_hist = jnp.zeros((k,), dtype)

    def body(t, state):
        c, d2, sel, d_hist, stopped = state
        c, d2, stopped, j, dj = greedy_step_exact(
            row_fn, t, c, d2, stopped, eps2
        )
        sel = sel.at[t].set(jnp.where(stopped, -1, j))
        d_hist = d_hist.at[t].set(jnp.where(stopped, 0.0, dj))
        return c, d2, sel, d_hist, stopped

    state = (c, d2, sel, d_hist, jnp.asarray(False))
    c, d2, sel, d_hist, _ = jax.lax.fori_loop(0, k, body, state)
    n_selected = jnp.sum(sel >= 0).astype(jnp.int32)
    return GreedyResult(sel, n_selected, d_hist)


def _dense_impl(L, k, eps, mask):
    return _greedy_loop(jnp.diagonal(L), lambda j: L[j], k, eps, mask)


def _lowrank_impl(V, k, eps, mask):
    diag = jnp.sum(V * V, axis=0)
    return _greedy_loop(diag, lambda j: V[:, j] @ V, k, eps, mask)


@partial(jax.jit, static_argnames=("k", "eps"))
def dpp_greedy_dense(
    L: jnp.ndarray,
    k: int,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Algorithm 1 on an explicit (M, M) kernel ``L``."""
    if mask is None:
        mask = jnp.ones((L.shape[0],), bool)
    return _dense_impl(L, k, eps, mask)


@partial(jax.jit, static_argnames=("k", "eps"))
def dpp_greedy_lowrank(
    V: jnp.ndarray,
    k: int,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Algorithm 1 on the implicit kernel ``L = V^T V``, ``V (D, M)``.

    Row ``L_j = V[:, j] @ V`` is recomputed per step — O(DM) extra FLOPs
    per step traded for O(M^2) memory never allocated.  For candidate
    sets larger than one device holds, ``repro.core.sharded`` runs this
    same recurrence with the M axis sharded over a mesh.
    """
    if mask is None:
        mask = jnp.ones((V.shape[1],), bool)
    return _lowrank_impl(V, k, eps, mask)


# ---------------------------------------------------------------------------
# Batched serving entry points (beyond-paper: the paper is one-user-at-a-time)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("k", "eps"))
def dpp_greedy_dense_batch(
    L: jnp.ndarray,
    k: int,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """vmap over users: L (B, M, M), mask (B, M)."""
    if mask is None:
        mask = jnp.ones(L.shape[:2], bool)
    return jax.vmap(lambda Li, mi: _dense_impl(Li, k, eps, mi))(L, mask)


@partial(jax.jit, static_argnames=("k", "eps"))
def dpp_greedy_lowrank_batch(
    V: jnp.ndarray,
    k: int,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """vmap over users: V (B, D, M), mask (B, M)."""
    if mask is None:
        mask = jnp.ones((V.shape[0], V.shape[2]), bool)
    return jax.vmap(lambda Vi, mi: _lowrank_impl(Vi, k, eps, mi))(V, mask)


def dpp_greedy(
    relevance: jnp.ndarray,
    k: int,
    *,
    similarity: Optional[jnp.ndarray] = None,
    feats: Optional[jnp.ndarray] = None,
    alpha=1.0,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Convenience front-end: builds the (implicit) kernel and runs Div-DPP.

    Exactly one of ``similarity`` (dense (M, M)) or ``feats`` (column-
    normalized (D, M)) must be given.
    """
    from repro.core import kernel_matrix as km

    if (similarity is None) == (feats is None):
        raise ValueError("pass exactly one of similarity= or feats=")
    if similarity is not None:
        L = km.build_kernel_dense(relevance, similarity, alpha)
        return dpp_greedy_dense(L, k, eps, mask)
    V = km.scaled_features(feats, relevance, alpha)
    return dpp_greedy_lowrank(V, k, eps, mask)
