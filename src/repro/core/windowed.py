"""Windowed Div-DPP (beyond-paper; the NeurIPS'18 version of this work
adds a sliding-window variant for long result sequences).

Diversity is enforced only against the last ``w`` selected items: the
DPP kernel is restricted to the window, so slate length is unbounded
with O(w * M) state.

Two implementations live here:

* ``dpp_greedy_windowed`` / ``dpp_greedy_windowed_lowrank`` — the
  paper's **incremental** update, O(w M) per step.  State is the window
  Cholesky factor's action on every candidate, ``C (w, M)`` with
  ``C[:, i] = V_W^{-1} L_{W, i}`` kept in window order (row 0 =
  oldest pick).  Appending a pick is the paper's eq. 16-18 row append
  (one (w,)x(w, M) matvec); evicting the oldest pick is a first-row
  Cholesky *downdate*: ``w - 1`` Givens rotations applied to the rows
  of ``C``.  Because ``C[:, win]`` *is* ``V_W^T``, the rotations are
  computed from ``C`` itself — no separate factor is stored, and
  ``d_i^2`` is repaired in O(M) from the rotation residue
  (``d2 += u_fin^2``) instead of recomputed.

* ``dpp_greedy_windowed_rebuild`` — the original O(w^2 M)-per-step
  reference: per step the window's Cholesky factor is rebuilt (O(w^3))
  and every candidate is re-solved against it (a batched triangular
  solve).  Slower by a factor w but independently derived — kept as
  the correctness oracle for the incremental path and the Pallas
  windowed kernel.

Why the downdate is just rotations on rows of ``C``:  drop the oldest
window item and split the factor ``V = [[v00, 0], [v, V22]]``.  The
shrunken Gram is ``V22 V22^T + v v^T``, so the new factor is the
rank-1 Cholesky *update* of ``V22`` by ``v`` — a product of Givens
rotations ``Q`` with ``[V22 | v] Q = [V' | 0]``.  The same ``Q^T``
applied to the stacked rows ``[C_1; c_0]`` (surviving rows over the
evicted row) yields the new ``C`` rows exactly, and the evicted
residue row ``u_fin`` carries the norm lost per column
(``||C'||^2 = ||C||^2 - u_fin^2``), which is the ``d2`` repair.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.greedy_chol import NEG_INF, GreedyResult


def greedy_step_windowed(row_fn, t, C, d2, win, stopped, *, w, eps2, tiny):
    """One sliding-window greedy step on the ring state ``C (w, M)``.

    Factored out of the ``_windowed_loop`` fori body so the whole-slate
    loop and the chunked/resumable executors in ``repro.core.streaming``
    run the *identical* op sequence — streamed chunks concatenate
    bitwise to the whole-slate result.  ``t`` is the absolute step
    index (it decides eviction, ``t >= w``, and the ring row ``pos``).

    Returns ``(C, d2, win, stopped, j, dj)``.
    """
    M = d2.shape[0]
    dtype = d2.dtype
    C0, d20, win0 = C, d2, win

    # ---- select against the current window of min(t, w) picks
    # (paper eq. 13; d2 is maintained incrementally across steps)
    j = jnp.argmax(d2)
    dj2 = d2[j]
    stopped = stopped | (dj2 <= eps2)
    dj = jnp.sqrt(jnp.maximum(dj2, eps2))

    # ---- evict the oldest window item to make room (window full only)
    full = jnp.logical_and(t >= w, jnp.logical_not(stopped))
    u = jnp.where(full, C[0], jnp.zeros((M,), dtype))
    win_shift = jnp.roll(win, -1)  # win_shift[r] = old win[r+1]

    def rot(r, Cu):
        C, u = Cu
        # when not evicting, read row r and rotate by identity (no-op)
        read = jnp.where(full, r + 1, r)
        row = jax.lax.dynamic_slice(C, (read, 0), (1, M))[0]
        idx = jnp.clip(win_shift[r], 0)
        a = row[idx]  # current window-factor diagonal V22[r, r]
        b = u[idx]  # current downdate vector entry v[r]
        rho = jnp.maximum(jnp.sqrt(a * a + b * b), tiny)
        cos = jnp.where(full, a / rho, 1.0)
        sin = jnp.where(full, b / rho, 0.0)
        new_row = cos * row + sin * u
        u = cos * u - sin * row
        C = jax.lax.dynamic_update_slice(C, new_row[None], (r, 0))
        return C, u

    C, u = jax.lax.fori_loop(0, w - 1, rot, (C, u))
    # the evicted slot: stale last row is cleared, d2 regains the
    # norm carried away by the rotation residue row
    C = jnp.where(full, C.at[w - 1].set(0.0), C)
    d2 = jnp.where(full, d2 + u * u, d2)
    win = jnp.where(full, win_shift.at[w - 1].set(-1), win)

    # ---- append j against the *post-eviction* window (eqs. 16-18);
    # its marginal there is d2[j] repaired by the eviction (>= dj2)
    djp = jnp.sqrt(jnp.maximum(d2[j], eps2))
    e = (row_fn(j) - C[:, j] @ C) / djp
    pos = jnp.minimum(t, w - 1)
    C_next = jax.lax.dynamic_update_slice(C, e[None], (pos, 0))
    d2_next = (d2 - e * e).at[j].set(NEG_INF)
    win_next = win.at[pos].set(j)

    C = jnp.where(stopped, C0, C_next)
    d2 = jnp.where(stopped, d20, d2_next)
    win = jnp.where(stopped, win0, win_next)
    return C, d2, win, stopped, j, dj


def _windowed_loop(
    diag: jnp.ndarray,
    row_fn: Callable[[jnp.ndarray], jnp.ndarray],
    k: int,
    window: int,
    eps: float,
    mask: jnp.ndarray,
) -> GreedyResult:
    """Incremental sliding-window greedy, O(w M) per step.

    diag:   (M,) float — L_ii for every candidate.
    row_fn: j -> (M,) float — returns row L_j of the kernel.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    M = diag.shape[0]
    w = min(window, k)
    dtype = diag.dtype
    eps2 = jnp.asarray(eps, dtype) ** 2
    tiny = jnp.asarray(1e-30, dtype)

    d2 = jnp.where(mask, diag, NEG_INF)
    C = jnp.zeros((w, M), dtype)
    win = jnp.full((w,), -1, jnp.int32)  # window order: 0 = oldest
    sel = jnp.full((k,), -1, jnp.int32)
    d_hist = jnp.zeros((k,), dtype)

    def body(t, state):
        C, d2, win, sel, d_hist, stopped = state
        C, d2, win, stopped, j, dj = greedy_step_windowed(
            row_fn, t, C, d2, win, stopped, w=w, eps2=eps2, tiny=tiny
        )
        sel = sel.at[t].set(jnp.where(stopped, -1, j))
        d_hist = d_hist.at[t].set(jnp.where(stopped, 0.0, dj))
        return C, d2, win, sel, d_hist, stopped

    state = (C, d2, win, sel, d_hist, jnp.asarray(False))
    _, _, _, sel, d_hist, _ = jax.lax.fori_loop(0, k, body, state)
    return GreedyResult(sel, jnp.sum(sel >= 0).astype(jnp.int32), d_hist)


@partial(jax.jit, static_argnames=("k", "window", "eps"))
def dpp_greedy_windowed(
    L: jnp.ndarray,
    k: int,
    window: int = 10,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Greedy MAP with a sliding diversity window of the last ``w`` picks.

    L (M, M) dense kernel.  With ``window >= k`` this equals the exact
    Algorithm 1 (tested); smaller windows trade global diversity for
    unbounded slate length at O(w M) per step.
    """
    if mask is None:
        mask = jnp.ones((L.shape[0],), bool)
    return _windowed_loop(jnp.diagonal(L), lambda j: L[j], k, window, eps, mask)


@partial(jax.jit, static_argnames=("k", "window", "eps"))
def dpp_greedy_windowed_lowrank(
    V: jnp.ndarray,
    k: int,
    window: int = 10,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Sliding-window greedy on the implicit kernel ``L = V^T V``, V (D, M).

    Never materializes M^2 memory; row ``L_j = V[:, j] @ V`` is
    recomputed per step exactly as in ``dpp_greedy_lowrank``.
    """
    if mask is None:
        mask = jnp.ones((V.shape[1],), bool)
    diag = jnp.sum(V * V, axis=0)
    return _windowed_loop(diag, lambda j: V[:, j] @ V, k, window, eps, mask)


@partial(jax.jit, static_argnames=("k", "window", "eps"))
def dpp_greedy_windowed_batch(
    L: jnp.ndarray,
    k: int,
    window: int = 10,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """vmap over users: L (B, M, M), mask (B, M)."""
    if mask is None:
        mask = jnp.ones(L.shape[:2], bool)
    fn = lambda Li, mi: _windowed_loop(
        jnp.diagonal(Li), lambda j: Li[j], k, window, eps, mi
    )
    return jax.vmap(fn)(L, mask)


@partial(jax.jit, static_argnames=("k", "window", "eps"))
def dpp_greedy_windowed_lowrank_batch(
    V: jnp.ndarray,
    k: int,
    window: int = 10,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """vmap over users: V (B, D, M), mask (B, M)."""
    if mask is None:
        mask = jnp.ones((V.shape[0], V.shape[2]), bool)
    fn = lambda Vi, mi: _windowed_loop(
        jnp.sum(Vi * Vi, axis=0), lambda j: Vi[:, j] @ Vi, k, window, eps, mi
    )
    return jax.vmap(fn)(V, mask)


@jax.jit
def windowed_state_rebuild(V, shown, dead):
    """Rebuild the incremental ring state ``(C, d2)`` from history alone.

    A windowed state is a pure function of the pool ``V (D, M)``, the
    last ``w`` shown pool columns (``shown (w,)`` int32, oldest first,
    -1-padded at the tail) and the dead set (``dead (M,)`` bool — every
    ever-shown or masked-out column, padding included).  The window's
    Gram is PD without jitter (every pick cleared the eps gate, so the
    incremental factor's diagonal is >= eps), and the Cholesky factor
    is unique — so this rebuild lands on the same ``C (w, M)`` rows the
    incremental path reached, up to rounding (~1 ulp).

    This is the session layer's eviction-repair: a session dropped from
    the LRU byte budget is rebuilt bit-compatibly from its host-side
    history the next time it is touched (``repro.serving.session``).
    """
    dtype = V.dtype
    w = shown.shape[0]
    ids = jnp.clip(shown, 0)
    valid = shown >= 0
    Vwin = jnp.where(valid[:, None], V[:, ids].T, 0.0)  # (w, D) rows
    eye = jnp.eye(w, dtype=dtype)
    vm = valid[:, None] & valid[None, :]
    Lw = jnp.where(vm, Vwin @ Vwin.T, eye)
    F = jnp.linalg.cholesky(Lw)
    Lwi = Vwin @ V  # (w, M); zero rows at empty ring slots
    C = jax.scipy.linalg.solve_triangular(F, Lwi, lower=True)
    C = jnp.where(valid[:, None], C, 0.0)
    d2 = jnp.sum(V * V, axis=0) - jnp.sum(C * C, axis=0)
    d2 = jnp.where(dead, NEG_INF, d2)
    return C, d2


@partial(jax.jit, static_argnames=("k", "window", "eps"))
def dpp_greedy_windowed_rebuild(
    L: jnp.ndarray,
    k: int,
    window: int = 10,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Reference sliding-window greedy: rebuild + re-solve every step.

    O(w^2 M) per step (vs the incremental path's O(w M)); independently
    derived, kept as the oracle the fast paths are tested against.
    """
    if window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    M = L.shape[0]
    w = min(window, k)
    dtype = L.dtype
    eps2 = jnp.asarray(eps, dtype) ** 2
    if mask is None:
        mask = jnp.ones((M,), bool)

    diag = jnp.diagonal(L)
    sel = jnp.full((k,), -1, jnp.int32)
    d_hist = jnp.zeros((k,), dtype)
    # ring buffer of the last w selected ids (-1 = empty)
    win = jnp.full((w,), -1, jnp.int32)
    avail = jnp.where(mask, 0.0, NEG_INF).astype(dtype)

    def body(t, state):
        sel, d_hist, win, avail, stopped = state
        # Build the window's kernel and Cholesky factor.  Empty slots use
        # an identity row/col so the factor stays well-defined.
        ids = jnp.clip(win, 0)
        valid = win >= 0
        Lw = L[jnp.ix_(ids, ids)]
        eye = jnp.eye(w, dtype=dtype)
        vm = valid[:, None] & valid[None, :]
        Lw = jnp.where(vm, Lw, eye)
        V = jnp.linalg.cholesky(Lw + 1e-6 * eye)

        # c_i = V^{-1} L_{W,i} for all candidates (batched triangular solve)
        Lwi = jnp.where(valid[:, None], L[ids], 0.0)  # (w, M)
        C = jax.scipy.linalg.solve_triangular(V, Lwi, lower=True)  # (w, M)
        d2 = diag - jnp.sum(C * C, axis=0)
        d2 = d2 + avail  # -inf for taken/masked

        j = jnp.argmax(d2)
        dj2 = d2[j]
        stopped = stopped | (dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))

        sel = sel.at[t].set(jnp.where(stopped, -1, j))
        d_hist = d_hist.at[t].set(jnp.where(stopped, 0.0, dj))
        win = jnp.where(stopped, win, win.at[t % w].set(j))
        avail = jnp.where(stopped, avail, avail.at[j].set(NEG_INF))
        return sel, d_hist, win, avail, stopped

    sel, d_hist, _, _, _ = jax.lax.fori_loop(
        0, k, body, (sel, d_hist, win, avail, jnp.asarray(False))
    )
    return GreedyResult(sel, jnp.sum(sel >= 0).astype(jnp.int32), d_hist)
