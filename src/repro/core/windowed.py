"""Windowed Div-DPP (beyond-paper; the NeurIPS'18 version of this work
adds a sliding-window variant for long result sequences).

Diversity is enforced only against the last ``w`` selected items: the
DPP kernel is restricted to the window, so slate length is unbounded
with O(w * M) state.  Implementation: per step, the window's Cholesky
factor is rebuilt (O(w^3), w is small) and every candidate's marginal
``d_i^2 = L_ii - ||solve(V, L_{W,i})||^2`` is computed by a batched
triangular solve (O(w^2 M)) — a factor-w more work per step than the
incremental NeurIPS'18 update, but simple, numerically robust, and still
independent of the total slate length N (total O(N w^2 M) vs the exact
algorithm's O(N^2 M) with N >> w).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.greedy_chol import NEG_INF, GreedyResult


@partial(jax.jit, static_argnames=("k", "window", "eps"))
def dpp_greedy_windowed(
    L: jnp.ndarray,
    k: int,
    window: int = 10,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
) -> GreedyResult:
    """Greedy MAP with a sliding diversity window of the last ``w`` picks.

    L (M, M) dense kernel.  With ``window >= k`` this equals the exact
    Algorithm 1 (tested); smaller windows trade global diversity for
    unbounded slate length.
    """
    M = L.shape[0]
    w = min(window, k)
    dtype = L.dtype
    eps2 = jnp.asarray(eps, dtype) ** 2
    if mask is None:
        mask = jnp.ones((M,), bool)

    diag = jnp.diagonal(L)
    sel = jnp.full((k,), -1, jnp.int32)
    d_hist = jnp.zeros((k,), dtype)
    # ring buffer of the last w selected ids (-1 = empty)
    win = jnp.full((w,), -1, jnp.int32)
    avail = jnp.where(mask, 0.0, NEG_INF).astype(dtype)

    def body(t, state):
        sel, d_hist, win, avail, stopped = state
        # Build the window's kernel and Cholesky factor.  Empty slots use
        # an identity row/col so the factor stays well-defined.
        ids = jnp.clip(win, 0)
        valid = win >= 0
        Lw = L[jnp.ix_(ids, ids)] if False else L[ids][:, ids]
        eye = jnp.eye(w, dtype=dtype)
        vm = valid[:, None] & valid[None, :]
        Lw = jnp.where(vm, Lw, eye)
        V = jnp.linalg.cholesky(Lw + 1e-6 * eye)

        # c_i = V^{-1} L_{W,i} for all candidates (batched triangular solve)
        Lwi = jnp.where(valid[:, None], L[ids], 0.0)  # (w, M)
        C = jax.scipy.linalg.solve_triangular(V, Lwi, lower=True)  # (w, M)
        d2 = diag - jnp.sum(C * C, axis=0)
        d2 = d2 + avail  # -inf for taken/masked

        j = jnp.argmax(d2)
        dj2 = d2[j]
        stopped = stopped | (dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))

        sel = sel.at[t].set(jnp.where(stopped, -1, j))
        d_hist = d_hist.at[t].set(jnp.where(stopped, 0.0, dj))
        win = jnp.where(stopped, win, win.at[t % w].set(j))
        avail = jnp.where(stopped, avail, avail.at[j].set(NEG_INF))
        return sel, d_hist, win, avail, stopped

    sel, d_hist, _, _, _ = jax.lax.fori_loop(
        0, k, body, (sel, d_hist, win, avail, jnp.asarray(False))
    )
    return GreedyResult(sel, jnp.sum(sel >= 0).astype(jnp.int32), d_hist)
