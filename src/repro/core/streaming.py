"""Step-resumable greedy MAP — the state/init/step/chunk layer under
streaming slate emission.

The paper's greedy loop is a pure recurrence on a small state (the
incremental Cholesky rows, the marginal gains ``d2`` and, windowed, the
ring order); the whole-slate entry points in ``greedy_chol`` /
``windowed`` just run it ``k`` times inside one ``fori_loop``.  This
module reifies that state as :class:`GreedyState` and exposes the
recurrence in resumable pieces:

* ``greedy_init(spec, L=|V=, mask=)``  -> initial state;
* ``greedy_step(spec, state, ...)``    -> one selection;
* ``greedy_chunk(spec, state, ...)``   -> ``chunk_size`` selections.

Chunks concatenate *exactly* (indices bitwise, d_hist to the last bit on
the jnp backend, ~1 ulp across kernels) to the whole-slate result,
because every backend's chunk executor runs the identical per-step op
sequence as its whole-slate loop:

* jnp       — ``greedy_step_exact`` / ``greedy_step_windowed``, the very
              functions the whole-slate ``fori_loop`` bodies call;
* pallas    — the fused multi-step chunk kernels
              (``repro.kernels.dpp_greedy.ops.dpp_greedy_stream_*``):
              one grid sweep per step, one ``pallas_call`` — one HBM
              C/d2 round-trip — per *chunk*;
* sharded   — per-device chunk bodies built from the same step factories
              as the whole-slate SPMD loop
              (``repro.core.sharded.dpp_greedy_sharded_stream_*``); the
              sharded state stays device-resident between chunks.

``GreedyState`` is **backend-specific and opaque**: the jnp exact state
keeps the paper's column layout ``C (M, k)``, the windowed state the
ring layout ``C (w, M)``, the Pallas state the kernels' padded row
layout, and the sharded state globally-shaped sharded arrays.  Always
thread a state back into the same ``spec`` (and kernel operand) that
created it.

The serving front door is ``repro.serving.Reranker.stream`` (and the
continuous-batching router over the slot substrate); the
dispatch-level generator is ``repro.core.dispatch.greedy_map_chunks``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.greedy_chol import NEG_INF, greedy_step_exact
from repro.core.windowed import greedy_step_windowed
from repro.obs.dispatch import record_chunk


def _backend_label(spec) -> str:
    if spec.sharded():
        return "sharded"
    return "pallas" if spec.backend == "pallas" else "jnp"


class GreedyState(NamedTuple):
    """Resumable greedy MAP state (backend-specific layouts, see module
    docstring).

    t:       () int32 — the next absolute step index.
    stopped: () bool  — eps-stop latch ((B,) for batched Pallas states).
    C:       Cholesky state — jnp exact ``(M, k)`` columns, windowed
             ``(w, M)`` ring rows; Pallas ``(B, R, Mp)``; sharded the
             global view of the per-device slices.
    d2:      marginal gains with the selectability mask folded in
             (masked candidates sit at -inf) — ``(M,)`` / ``(B, Mp)``.
    win:     window ring ids, oldest first (``(0,)``-shaped when exact).
    """

    t: jnp.ndarray
    stopped: jnp.ndarray
    C: jnp.ndarray
    d2: jnp.ndarray
    win: jnp.ndarray


def _check_kernel_args(spec, L, V):
    if (L is None) == (V is None):
        raise ValueError("pass exactly one of L= (dense) or V= (low-rank)")
    if L is not None and (spec.backend == "pallas" or spec.sharded()):
        raise ValueError(
            f"backend {spec.backend!r} streams the low-rank V only — a "
            f"dense L cannot be tiled or candidate-sharded"
        )


def resolve_chunk(spec, chunk_size: Optional[int]) -> int:
    """The effective chunk size: the explicit argument wins, else
    ``spec.chunk_size``; one of them must be set and positive."""
    c = chunk_size if chunk_size is not None else spec.chunk_size
    if c is None:
        raise ValueError(
            "no chunk size: pass chunk_size= or set GreedySpec.chunk_size"
        )
    if c < 1:
        raise ValueError(f"chunk_size must be >= 1, got {c}")
    return c


# ---------------------------------------------------------------------------
# jnp executors (single problem; dense L or low-rank V)
# ---------------------------------------------------------------------------


def _init_jnp(k: int, window: Optional[int], L, V, mask) -> GreedyState:
    kern = L if L is not None else V
    if kern.ndim != 2:
        raise ValueError(
            f"jnp streaming takes a single problem (L (M, M) / V (D, M)), "
            f"got ndim={kern.ndim}"
        )
    M = kern.shape[-1]
    dtype = kern.dtype
    if mask is None:
        mask = jnp.ones((M,), bool)
    diag = jnp.diagonal(L) if L is not None else jnp.sum(V * V, axis=0)
    d2 = jnp.where(mask, diag, NEG_INF)
    if window is not None and window < k:
        w = min(window, k)
        C = jnp.zeros((w, M), dtype)
        win = jnp.full((w,), -1, jnp.int32)
    else:
        C = jnp.zeros((M, k), dtype)
        win = jnp.zeros((0,), jnp.int32)
    return GreedyState(
        jnp.zeros((), jnp.int32), jnp.asarray(False), C, d2, win
    )


def _chunk_body(row_fn, state: GreedyState, chunk: int, eps: float):
    """``chunk`` steps of the shared per-step bodies, absolute step
    ``t = state.t + s`` — the same op sequence as the whole-slate loops."""
    dtype = state.d2.dtype
    eps2 = jnp.asarray(eps, dtype) ** 2
    tiny = jnp.asarray(1e-30, dtype)
    windowed = state.win.shape[0] > 0
    sel = jnp.full((chunk,), -1, jnp.int32)
    dh = jnp.zeros((chunk,), dtype)

    if windowed:
        w = state.C.shape[0]

        def body(s, carry):
            C, d2, win, stopped, sel, dh = carry
            C, d2, win, stopped, j, dj = greedy_step_windowed(
                row_fn, state.t + s, C, d2, win, stopped,
                w=w, eps2=eps2, tiny=tiny,
            )
            sel = sel.at[s].set(jnp.where(stopped, -1, j))
            dh = dh.at[s].set(jnp.where(stopped, 0.0, dj))
            return C, d2, win, stopped, sel, dh

        C, d2, win, stopped, sel, dh = jax.lax.fori_loop(
            0, chunk, body,
            (state.C, state.d2, state.win, state.stopped, sel, dh),
        )
    else:

        def body(s, carry):
            C, d2, stopped, sel, dh = carry
            C, d2, stopped, j, dj = greedy_step_exact(
                row_fn, state.t + s, C, d2, stopped, eps2
            )
            sel = sel.at[s].set(jnp.where(stopped, -1, j))
            dh = dh.at[s].set(jnp.where(stopped, 0.0, dj))
            return C, d2, stopped, sel, dh

        C, d2, stopped, sel, dh = jax.lax.fori_loop(
            0, chunk, body, (state.C, state.d2, state.stopped, sel, dh)
        )
        win = state.win
    next_state = GreedyState(state.t + chunk, stopped, C, d2, win)
    return next_state, sel, dh


@partial(jax.jit, static_argnames=("chunk", "eps"))
def _chunk_dense(L, state, chunk: int, eps: float):
    return _chunk_body(lambda j: L[j], state, chunk, eps)


@partial(jax.jit, static_argnames=("chunk", "eps"))
def _chunk_lowrank(V, state, chunk: int, eps: float):
    return _chunk_body(lambda j: V[:, j] @ V, state, chunk, eps)


# ---------------------------------------------------------------------------
# Dispatch-aware front doors
# ---------------------------------------------------------------------------


def greedy_init(spec, *, L=None, V=None, mask=None) -> GreedyState:
    """Initial resumable state for ``spec`` on a dense (L) or low-rank
    (V) kernel.  ``mask`` marks selectable candidates; it is folded into
    the state (masked entries can never be selected in any later chunk).
    """
    _check_kernel_args(spec, L, V)
    if spec.sharded():
        from repro.core.sharded import dpp_greedy_sharded_stream_init

        return dpp_greedy_sharded_stream_init(
            V, spec.k, mesh=spec.mesh, axis_name=spec.axis_name,
            window=spec.window, mask=mask, tile_m=spec.tile_m,
        )
    if spec.backend == "pallas":
        from repro.kernels.dpp_greedy import dpp_greedy_stream_init

        return dpp_greedy_stream_init(
            V, spec.k, mask=mask, window=spec.window, tile_m=spec.tile_m
        )
    return _init_jnp(spec.k, spec.window, L, V, mask)


def greedy_chunk(
    spec, state: GreedyState, *, L=None, V=None,
    chunk_size: Optional[int] = None,
):
    """Advance ``chunk_size`` greedy steps (default ``spec.chunk_size``).

    Returns ``(next_state, sel (chunk,), d_hist (chunk,))`` — with a
    leading batch axis on ``sel``/``d_hist`` for batched Pallas/sharded
    states.  Slots after an eps-stop hold -1 / 0, exactly as the
    whole-slate result's tail does.  The caller sizes chunks so the
    total never exceeds ``spec.k`` on the exact path (the windowed ring
    is unbounded); ``repro.core.dispatch.greedy_map_chunks`` does this.
    """
    _check_kernel_args(spec, L, V)
    chunk = resolve_chunk(spec, chunk_size)
    kern = L if L is not None else V
    record_chunk(
        _backend_label(spec),
        B=kern.shape[0] if kern.ndim == 3 else 1,
        chunk=chunk,
        M=kern.shape[-1],
    )
    if spec.sharded():
        from repro.core.sharded import dpp_greedy_sharded_stream_chunk

        return dpp_greedy_sharded_stream_chunk(
            V, state, chunk, mesh=spec.mesh, axis_name=spec.axis_name,
            eps=spec.eps, tile_m=spec.tile_m, interpret=spec.interpret,
        )
    if spec.backend == "pallas":
        from repro.kernels.dpp_greedy import dpp_greedy_stream_chunk

        return dpp_greedy_stream_chunk(
            V, state, chunk, eps=spec.eps, tile_m=spec.tile_m,
            interpret=spec.interpret,
        )
    fn = _chunk_dense if L is not None else _chunk_lowrank
    return fn(L if L is not None else V, state, chunk, float(spec.eps))


def greedy_step(spec, state: GreedyState, *, L=None, V=None):
    """One greedy step: ``(next_state, idx, d)`` with scalar ``idx``/``d``
    (-1 / 0 once eps-stopped).  Sugar for a chunk of one."""
    state, sel, dh = greedy_chunk(spec, state, L=L, V=V, chunk_size=1)
    return state, sel[..., 0], dh[..., 0]


# ---------------------------------------------------------------------------
# Session delta updates — recondition a windowed state on a pool delta
# ---------------------------------------------------------------------------
#
# A windowed state is fully determined by the pool ``V``, the last-w
# shown ids and the dead set (shown + masked): ``d2_i = L_ii -
# ||C[:, i]||^2`` for live i, and ``C[:, i] = V_W^{-1} L_{W, i}``
# depends only on the *window* columns of V.  So when a block of
# candidate columns is appended or overwritten, only that block's C
# columns and d2 entries change — everything else (the ring rows for
# shown items, every untouched column) is already correct.  The block
# is re-solved against the window factor directly: ``C[:, win]`` IS
# ``V_W`` (lower-triangular — column ``win[r]`` of C carries zeros
# above row r, see ``repro.core.windowed``), so one (w, w) gather plus
# one triangular solve reconditions dM columns in O(w^2 + w*dM*D) —
# never O(k * M) like a from-scratch rerun.


def _delta_cols(V, C, d2, win, start, V_blk, mask_blk, keep_dead: bool):
    """Recompute C/d2 for pool columns ``[start, start + dM)`` after
    writing ``V_blk`` there.  Unbatched leaves: V (D*, M*), C (w, M*),
    d2 (M*,), win (w,).  ``keep_dead`` preserves dead columns (d2 at
    -inf: shown, masked, padding) bit-for-bit — the rescore contract."""
    D, _ = V.shape
    w = C.shape[0]
    dtype = C.dtype
    dm = V_blk.shape[1]
    ids = jnp.clip(win, 0)
    valid = win >= 0

    # The window's lower-triangular Cholesky factor, read off C itself;
    # empty ring slots become identity rows so the solve is a no-op there.
    Vw = jnp.where(valid[:, None], C[:, ids].T, jnp.eye(w, dtype=dtype))
    # b[r] = L_{win[r], blk} from the (unchanged) window columns of V
    b = jnp.where(valid[:, None], V[:, ids].T @ V_blk, 0.0)
    c = jax.scipy.linalg.solve_triangular(Vw, b, lower=True)  # (w, dm)
    diag_blk = jnp.sum(V_blk * V_blk, axis=0)
    d2_blk = jnp.where(mask_blk, diag_blk - jnp.sum(c * c, axis=0), NEG_INF)

    if keep_dead:
        oldV = jax.lax.dynamic_slice(V, (0, start), (D, dm))
        oldC = jax.lax.dynamic_slice(C, (0, start), (w, dm))
        oldd = jax.lax.dynamic_slice(d2, (start,), (dm,))
        dead = jnp.isneginf(oldd)
        V_blk = jnp.where(dead[None, :], oldV, V_blk)
        c = jnp.where(dead[None, :], oldC, c)
        d2_blk = jnp.where(dead, oldd, d2_blk)

    V = jax.lax.dynamic_update_slice(V, V_blk.astype(V.dtype), (0, start))
    C = jax.lax.dynamic_update_slice(C, c.astype(dtype), (0, start))
    d2 = jax.lax.dynamic_update_slice(d2, d2_blk.astype(d2.dtype), (start,))
    return V, C, d2


@partial(jax.jit, static_argnames=("keep_dead",))
def _delta_update(V, C, d2, win, start, V_blk, mask_blk, *, keep_dead: bool):
    return _delta_cols(V, C, d2, win, start, V_blk, mask_blk, keep_dead)


@partial(jax.jit, static_argnames=("keep_dead",))
def _delta_update_b1(V, C, d2, win, start, V_blk, mask_blk, *, keep_dead: bool):
    # batched single-lane leaves (the Pallas stream layout, B == 1)
    V, C1, d21 = _delta_cols(
        V, C[0], d2[0], win[0], start, V_blk, mask_blk, keep_dead
    )
    return V, C1[None], d21[None]


def _state_delta(spec, state, V, start, V_new, mask_new, keep_dead, op):
    if spec.sharded():
        raise NotImplementedError(
            f"{op} is not implemented for sharded states: the window ring "
            f"lives sharded behind shard_map and a column delta crosses "
            f"device boundaries.  Lands with the ROADMAP 'Router scale-up' "
            f"item (sharded slot batches + window heterogeneity); until "
            f"then re-rank sharded pools from scratch."
        )
    if state.win.shape[-1] == 0:
        raise ValueError(
            f"{op} needs a windowed state (cfg.window < slate_size): the "
            f"exact C (M, k) layout does not expose the conditioning "
            f"window, so a column delta cannot be re-solved in O(w*dM)"
        )
    if V_new.ndim != 2:
        raise ValueError(f"{op}: V_new must be (D, dM), got ndim={V_new.ndim}")
    dm = V_new.shape[1]
    M = V.shape[-1]
    if V_new.shape[0] > V.shape[0]:
        raise ValueError(
            f"{op}: V_new has D={V_new.shape[0]} rows but the pool operand "
            f"carries D={V.shape[0]}"
        )
    if isinstance(start, int):
        if start < 0 or start + dm > M:
            raise ValueError(
                f"{op}: block [{start}, {start + dm}) exceeds the pool's "
                f"{M} columns — size the session capacity up front"
            )
    if mask_new is None:
        mask_new = jnp.ones((dm,), bool)
    V_blk = V_new.astype(V.dtype)
    if V_blk.shape[0] < V.shape[0]:  # Pallas row padding (Dp >= D)
        V_blk = jnp.pad(V_blk, ((0, V.shape[0] - V_blk.shape[0]), (0, 0)))
    start = jnp.asarray(start, jnp.int32)
    if spec.backend == "pallas":
        if state.C.ndim != 3 or state.C.shape[0] != 1:
            raise ValueError(
                f"{op} takes a single-request Pallas stream state "
                f"(leading batch axis 1); slot-batched delta updates land "
                f"with the ROADMAP 'Router scale-up' item"
            )
        V2, C2, d22 = _delta_update_b1(
            V, state.C, state.d2, state.win, start, V_blk, mask_new,
            keep_dead=keep_dead,
        )
    else:
        V2, C2, d22 = _delta_update(
            V, state.C, state.d2, state.win, start, V_blk, mask_new,
            keep_dead=keep_dead,
        )
    # a delta can revive a stopped session: new/raised columns may now
    # clear the eps gate, so the latch re-arms and re-evaluates.  The
    # revived resume must condition on the *live* ring: a stopped chunk
    # advances t past the last real pick (its aborted steps revert
    # C/win but not the step counter), and a stale t >= w would evict a
    # window item that was never followed by a pick.  Ring occupancy is
    # the true pick count below w, and any t >= w is behaviorally
    # equivalent once the ring is full — so re-derive t from the ring.
    t2 = jnp.sum(state.win >= 0).astype(jnp.int32)
    new_state = GreedyState(
        t2, jnp.zeros_like(state.stopped), C2, d22, state.win
    )
    return new_state, V2


def greedy_state_extend(spec, state: GreedyState, V, start, V_new, mask_new=None):
    """Append ``dM`` candidate columns at ``start`` of the pool operand.

    Writes ``V_new (D, dM)`` into columns ``[start, start + dM)`` of
    ``V``, re-solves exactly those columns' Cholesky state against the
    session's current window and returns ``(state', V')`` — O(w * dM),
    independent of how many steps the state has already taken.  The
    target region is overwritten blind (it is the caller's padding /
    retired region); ``mask_new`` marks which of the new columns are
    selectable.  ``start`` may be a host int (bounds-checked) or traced;
    the block width ``dM`` is static — one compile per distinct width.
    Windowed states only; sharded raises ``NotImplementedError``.
    """
    return _state_delta(
        spec, state, V, start, V_new, mask_new, False, "greedy_state_extend"
    )


def greedy_state_rescore(spec, state: GreedyState, V, start, V_new, mask_new=None):
    """Overwrite ``dM`` *existing* columns with refreshed vectors.

    Same geometry and cost as :func:`greedy_state_extend`, with one
    contract change: dead columns (d2 at -inf — already shown, masked
    out, or padding) keep their exact old V/C/d2 bits, so the shown
    history and the window factor are never rewritten by a score
    refresh.  ``mask_new`` False additionally retires a live column.
    """
    return _state_delta(
        spec, state, V, start, V_new, mask_new, True, "greedy_state_rescore"
    )


# ---------------------------------------------------------------------------
# Slot-batched execution — the continuous-batching substrate
# ---------------------------------------------------------------------------
#
# The serving router (``repro.serving.router``) coalesces heterogeneous
# live requests into one padded micro-batch of S *slots* and advances
# all of them with a single chunk call per cycle.  Unlike the batched
# whole-slate paths — where every lane starts together — slots join and
# leave mid-flight (a freed slot is respliced with a brand-new request
# while its neighbours are deep into their slates), so the slot state
# carries a **per-slot step counter** ``t (S,)`` instead of the scalar
# the uniform batch paths share.  The per-step bodies already consume
# ``t`` per lane (it only feeds the Cholesky row index and the ring
# position), so the same op sequence runs; a slot's selections are
# bitwise those of a single-request state at the same ``t``.
#
# Layout: every leaf gains a leading slot axis — jnp exact
# ``C (S, M, k)``, windowed ``C (S, w, M)``, Pallas ``(S, R, Mp)``,
# sharded the global per-device views — and parked (empty) slots hold
# ``stopped=True`` with ``d2`` at -inf, so they select -1 at zero
# numerical risk while occupied neighbours compute.


def greedy_slot_state(spec, V, mask=None, dtype=None) -> GreedyState:
    """Single-request state in ``spec``'s slot layout.

    ``spec.k`` is the slot *capacity* (the router's ``max_slate``), not
    the request's own slate length — every slot shares one Cholesky
    geometry so states splice into any slot; a request simply stops
    consuming after its own ``k`` selections.  ``V (D, M)`` must already
    be padded to the router's bucket width (mask False over padding).
    ``dtype`` casts ``V`` first so the state's C/d2 leaves match the
    slot batch it will be spliced into (``state_splice`` casts leaf-wise
    — building the state in the wrong precision and upcasting later is
    NOT the same bits); the Pallas kernels compute in f32 regardless.
    """
    if dtype is not None:
        V = V.astype(dtype)
    if spec.sharded():
        from repro.core.sharded import dpp_greedy_sharded_stream_init

        return dpp_greedy_sharded_stream_init(
            V, spec.k, mesh=spec.mesh, axis_name=spec.axis_name,
            window=spec.window, mask=mask, tile_m=spec.tile_m,
        )
    if spec.backend == "pallas":
        from repro.kernels.dpp_greedy import dpp_greedy_stream_init

        st = dpp_greedy_stream_init(
            V, spec.k, mask=mask, window=spec.window, tile_m=spec.tile_m
        )
        # squeeze the kernels' (1, ...) batch leaves to the slot layout
        return GreedyState(st.t, st.stopped[0], st.C[0], st.d2[0], st.win[0])
    return _init_jnp(spec.k, spec.window, None, V, mask)


def slot_pad_v(spec, V, state):
    """Pad ``V`` to the slot executor's device geometry (Pallas (Dp, Mp)
    padding, sharded mesh/tile quantum; identity on jnp) so the per-cycle
    chunk calls move no O(D M) data."""
    if spec.sharded():
        from repro.core.sharded import _stream_pad

        return _stream_pad(V, state.d2.shape[-1])
    if spec.backend == "pallas":
        from repro.kernels.dpp_greedy import dpp_greedy_stream_pad

        return dpp_greedy_stream_pad(V, state)
    return V


def greedy_slots_init(spec, slots: int, D: int, M: int, dtype=jnp.float32):
    """Parked S-slot batch state + its zeroed V operand.

    Returns ``(state, V_slots)``: every slot is parked (``stopped``,
    ``d2`` -inf, ``t`` 0) and ``V_slots`` is zeros in the executor
    geometry — admit requests with :func:`state_splice`, free slots with
    :func:`state_evict`.  ``M`` is the router's padded bucket width and
    ``spec.k`` the per-slot capacity (see :func:`greedy_slot_state`).
    ``dtype`` is the resident V/C/d2 element type — it must match the
    lanes that will be spliced in, or ``state_splice``'s leaf-wise
    ``astype`` silently rounds every bf16/f64 request through it.
    """
    if slots < 1:
        raise ValueError(f"slots must be >= 1, got {slots}")
    Vz = jnp.zeros((D, M), dtype)
    single = greedy_slot_state(spec, Vz, mask=jnp.zeros((M,), bool))
    single = single._replace(stopped=jnp.asarray(True))
    state = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (slots,) + x.shape).copy(), single
    )
    Vp = slot_pad_v(spec, Vz, state)
    V_slots = jnp.zeros((slots,) + Vp.shape, Vp.dtype)
    return state, V_slots


def state_splice(state: GreedyState, single: GreedyState, slot) -> GreedyState:
    """Write a single-request state (``greedy_slot_state``, same spec and
    geometry) into ``slot`` of a slot-batched state.  ``slot`` may be a
    traced/int index — splicing never retriggers compilation."""
    i = jnp.asarray(slot, jnp.int32)
    return jax.tree_util.tree_map(
        lambda b, s: b.at[i].set(s.astype(b.dtype)), state, single
    )


def state_evict(state: GreedyState, slot) -> GreedyState:
    """Park ``slot``: eps-stopped with every candidate at -inf, step
    counter rewound — the slot selects -1 until a new request is
    spliced in.  The freed Cholesky rows are zeroed so a later splice
    starts from the same bits as a fresh single-request state."""
    i = jnp.asarray(slot, jnp.int32)
    win = state.win.at[i].set(-1) if state.win.shape[-1] else state.win
    return GreedyState(
        state.t.at[i].set(0),
        state.stopped.at[i].set(True),
        state.C.at[i].set(0.0),
        state.d2.at[i].set(NEG_INF),
        win,
    )


@partial(jax.jit, static_argnames=("chunk", "eps"))
def _chunk_lowrank_slots(V, state, chunk: int, eps: float):
    # one lane per slot; _chunk_body consumes the per-slot t scalar it
    # sees inside its lane, so heterogeneous progress just works
    return jax.vmap(
        lambda v, s: _chunk_body(lambda j: v[:, j] @ v, s, chunk, eps)
    )(V, state)


def greedy_chunk_slots(spec, state: GreedyState, V_slots, chunk: int):
    """Advance every slot ``chunk`` greedy steps in one batched call.

    ``V_slots (S, D*, M*)`` is the stacked per-slot kernel operand in
    executor geometry (``greedy_slots_init`` / ``slot_pad_v``).  Returns
    ``(state, sel (S, chunk), d_hist (S, chunk))`` — parked and stopped
    slots yield -1 / 0.  One jit cache entry per (geometry, chunk): the
    per-request k / mask / progress all live in data, never in statics.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    record_chunk(
        _backend_label(spec),
        B=V_slots.shape[0],
        chunk=chunk,
        M=V_slots.shape[-1],
    )
    if spec.sharded():
        from repro.core.sharded import dpp_greedy_sharded_stream_chunk

        return dpp_greedy_sharded_stream_chunk(
            V_slots, state, chunk, mesh=spec.mesh, axis_name=spec.axis_name,
            eps=spec.eps, tile_m=spec.tile_m, interpret=spec.interpret,
        )
    if spec.backend == "pallas":
        from repro.kernels.dpp_greedy import dpp_greedy_stream_chunk

        return dpp_greedy_stream_chunk(
            V_slots, state, chunk, eps=spec.eps, tile_m=spec.tile_m,
            interpret=spec.interpret,
        )
    return _chunk_lowrank_slots(V_slots, state, chunk, float(spec.eps))
