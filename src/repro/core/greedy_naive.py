"""Naive greedy MAP approximation — the paper's *baseline* (eq. (8)).

At step k it evaluates ``det(L_{Y u {i}})`` for every remaining candidate
``i`` with an explicit determinant — O(k^3) per candidate, O(N^3 M) per
slate.  This is the algorithm Figure 1 of the paper compares against and
the exactness oracle for Algorithm 1 (both must select identical items).

Implemented in float64 numpy for oracle quality; a vmapped-slogdet jnp
variant is provided for the Figure-1 benchmark (it is the "vectorized as
well as possible" version of the naive method, so the measured speedup is
not an artifact of poor baseline engineering).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

try:  # jnp variant is optional at import time
    import jax
    import jax.numpy as jnp

    _HAS_JAX = True
except Exception:  # pragma: no cover
    _HAS_JAX = False


def greedy_map_naive(
    L: np.ndarray,
    k: int,
    eps: float = 1e-6,
    mask: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Paper eq. (8): j = argmax_i det(L_{Y u {i}}), numpy float64.

    Returns (indices, gains) where ``gains[t]`` is the determinant ratio
    ``det(L_{Y_t}) / det(L_{Y_{t-1}})`` (= d_t^2 of Algorithm 1) so tests
    can check the determinant identity det(L_Y) = prod d^2.

    Stops early when the best marginal gain ``sqrt(ratio) <= eps``
    (mirrors Algorithm 1's eq.-(20) stop so both methods stay comparable).
    """
    L = np.asarray(L, np.float64)
    M = L.shape[0]
    selectable = np.ones(M, bool) if mask is None else np.asarray(mask, bool).copy()
    sel: list[int] = []
    gains: list[float] = []
    det_prev = 1.0
    for _ in range(k):
        cand = np.flatnonzero(selectable)
        if cand.size == 0:
            break
        best_j, best_det = -1, -np.inf
        for i in cand:
            idx = sel + [int(i)]
            det_i = np.linalg.det(L[np.ix_(idx, idx)])
            if det_i > best_det:
                best_det, best_j = det_i, int(i)
        ratio = best_det / det_prev
        if ratio <= eps * eps:
            break
        sel.append(best_j)
        gains.append(ratio)
        selectable[best_j] = False
        det_prev = best_det
    out = np.full(k, -1, np.int64)
    out[: len(sel)] = sel
    g = np.zeros(k, np.float64)
    g[: len(gains)] = gains
    return out, g


if _HAS_JAX:

    def greedy_map_naive_vmapped(
        L: "jnp.ndarray", k: int, eps: float = 1e-6
    ) -> np.ndarray:
        """Vectorized naive greedy: per step, a vmapped ``slogdet`` over all
        candidates on (t+1)x(t+1) gathered submatrices.  Used as the
        strongest-possible "original greedy" baseline in Figure 1.
        """
        L = jnp.asarray(L)
        M = L.shape[0]
        sel = []
        selectable = jnp.ones(M, bool)
        for t in range(k):
            base = jnp.array(sel, dtype=jnp.int32) if sel else jnp.zeros((0,), jnp.int32)
            # re-trace per t (shape changes); fine for a benchmark baseline
            def one(i, base=base):
                idx = jnp.concatenate([base, i[None].astype(jnp.int32)])
                sub = L[jnp.ix_(idx, idx)]
                sign, logdet = jnp.linalg.slogdet(sub)
                return jnp.where(sign > 0, logdet, -jnp.inf)

            lds = jax.jit(jax.vmap(one))(jnp.arange(M, dtype=jnp.int32))
            lds = jnp.where(selectable, lds, -jnp.inf)
            j = int(jnp.argmax(lds))
            sel.append(j)
            selectable = selectable.at[j].set(False)
        out = np.full(k, -1, np.int64)
        out[: len(sel)] = sel
        return out
