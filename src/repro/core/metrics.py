"""Accuracy and diversity metrics (paper §5.2.2).

* recall              — fraction of users whose held-out test item appears
                        in the recommended slate;
* average / minimum / median pairwise dissimilarity ``1 - S_ij`` within
  the slate (the min and median are the paper's two *new* metrics).

All slate metrics accept -1-padded index vectors (the eps-stop of
Algorithm 1) and ignore padded slots.
"""
from __future__ import annotations

from typing import Dict

import numpy as np


def recall_at_n(selections: np.ndarray, test_items: np.ndarray) -> float:
    """selections (U, N) int, test_items (U,) int -> recall in [0, 1]."""
    selections = np.asarray(selections)
    test_items = np.asarray(test_items)
    hits = (selections == test_items[:, None]).any(axis=1)
    return float(hits.mean())


def _pairwise_dissim(sel: np.ndarray, S: np.ndarray) -> np.ndarray:
    """Upper-triangle pairwise dissimilarities of the valid slate items."""
    sel = sel[sel >= 0]
    if sel.size < 2:
        return np.zeros((0,))
    sub = S[np.ix_(sel, sel)]
    iu = np.triu_indices(sel.size, k=1)
    return 1.0 - sub[iu]


def slate_diversity(sel: np.ndarray, S: np.ndarray) -> Dict[str, float]:
    """average / minimum / median dissimilarity of one slate (paper §5.2.2)."""
    d = _pairwise_dissim(np.asarray(sel), np.asarray(S))
    if d.size == 0:
        return {"avg": 0.0, "min": 0.0, "median": 0.0}
    return {
        "avg": float(d.mean()),
        "min": float(d.min()),
        "median": float(np.median(d)),
    }


def mean_slate_diversity(selections: np.ndarray, S: np.ndarray) -> Dict[str, float]:
    """Per-user diversity averaged over users (the paper's Figure-3 y-axes)."""
    accs = {"avg": [], "min": [], "median": []}
    for sel in np.asarray(selections):
        m = slate_diversity(sel, S)
        for key in accs:
            accs[key].append(m[key])
    return {key: float(np.mean(v)) for key, v in accs.items()}


def log_det_objective(L: np.ndarray, sel: np.ndarray) -> float:
    """log det(L_Y) of a slate — the MAP objective being greedily maximized.

    Used by tests/benchmarks to compare solution quality across methods.
    """
    sel = np.asarray(sel)
    sel = sel[sel >= 0]
    if sel.size == 0:
        return 0.0
    sign, logdet = np.linalg.slogdet(np.asarray(L, np.float64)[np.ix_(sel, sel)])
    return float(logdet) if sign > 0 else -np.inf
