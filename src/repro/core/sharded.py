"""Sharded candidate-axis greedy MAP — one slate over millions of candidates.

The paper's Algorithm 1 costs O(D M) per step on the low-rank kernel
``L = V^T V``; the per-step work (a candidate matvec plus an argmax) is
embarrassingly parallel over the candidate axis M, exactly the structure
Han et al. (arXiv:1703.03389) exploit for parallel greedy DPP inference.
Because each candidate only needs its own column of ``V`` (Gartrell et
al., arXiv:1602.05436 low-rank factorization), device ``p`` of a
P-device mesh computes on just the ``(D, M/P)`` column shard plus its
slice of the ``c``/``d2`` Cholesky state — the dense ``(M, M)`` kernel
``L`` never exists anywhere.  (The eager front end below still builds
the full ``(D, M)`` ``V`` on the host before resharding; feeding the
shards straight from a sharded feature store is a ROADMAP item.)

A request batch of B users shares the mesh: ``V (B, D, M)`` keeps the
candidate axis sharded (every device holds a ``(B, D, M/P)`` block) and
the per-slate SPMD body is ``vmap``-ed *inside* the ``shard_map``, so
the loop state becomes ``(B, Mloc)`` per device and each step's argmax
allreduce and winner broadcast move ``B`` values in one batched
collective instead of ``B`` sequential ones.

Per greedy step, inside one ``shard_map``:

1. **local update** — each device updates its candidate shard
   (O(D M / P) exact, O(w M / P) windowed); with ``tile_m=`` set it
   runs through the same tiled, double-buffered Pallas pass as the
   single-device streaming kernel (``repro.kernels.dpp_greedy.tiled``),
   so M/P shards past the VMEM budget stream in tiles instead of
   lowering through unfused jnp;
2. **global argmax** — an all-gather allreduce of per-device
   ``(d2_max, global_index)`` pairs (P tiny pairs), first-occurrence
   tie-breaking identical to a single-device ``argmax``;
3. **winner broadcast** — one psum replicates the winning column's data
   (``V[:, j]``, its Cholesky column ``c_j`` and, windowed, the repaired
   ``d2[j]``) from the owner shard to everyone.

The sliding-window variant additionally psum-gathers the tiny ``(w, w)``
window factor ``C[:, win]`` each step so every device computes the same
Givens eviction rotations from the same bits.  The selected slate
matches the single-device ``dpp_greedy_lowrank`` /
``dpp_greedy_windowed_lowrank`` paths on the gathered ``V`` index for
index (same argmax sequence, same tie-breaking); the marginal-gain
history agrees to ~1 ulp — XLA may compile the per-shard ``(D, M/P)``
reductions with a different op order than the ``(D, M)`` shapes.

Front doors: ``greedy_map(GreedySpec(backend="sharded", mesh=...))``
dispatches here; serving goes through ``repro.serving.Reranker`` with
``cfg.mesh`` set (which also replaces the single-device
``jax.lax.top_k`` shortlist with ``sharded_topk``); the
``repro.launch.serve_sharded`` driver and ``benchmarks/fig5_sharded.py``
demonstrate the path end to end on a host-device mesh.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.greedy_chol import NEG_INF, GreedyResult
from repro.distributed.context import shard_map_compat


def _mesh_axis_size(mesh, axis_name: str) -> int:
    if axis_name not in mesh.shape:
        raise ValueError(
            f"mesh has no axis {axis_name!r}; mesh axes: {tuple(mesh.shape)}"
        )
    return mesh.shape[axis_name]


def _global_argmax(d2, ax, off, axis_name):
    """(d2_max, global index, owner?) via a P-pair all-gather allreduce.

    Gathered in axis-index order, so ``argmax`` over the per-device maxima
    breaks ties toward the lowest shard — combined with the local
    ``argmax``'s first-occurrence rule this reproduces a single-device
    ``argmax`` over the concatenated candidate axis exactly.

    ``ax``/``off`` (axis index, shard offset) are computed once outside
    the greedy loop and passed in: a ``jax.lax.axis_index`` *inside* a
    ``fori_loop`` body can survive XLA simplification as a raw
    PartitionId op the SPMD partitioner rejects (observed on jax 0.4.x
    when the w=1 eviction loop folds away).
    """
    jl = jnp.argmax(d2).astype(jnp.int32)
    dv = jax.lax.all_gather(d2[jl], axis_name)  # (P,)
    gv = jax.lax.all_gather(jl + off, axis_name)
    p = jnp.argmax(dv)
    return jl, dv[p], gv[p], p == ax


def _bcast_from_owner(parts, owner, axis_name):
    """Replicate the owner shard's small vectors to every device (one psum)."""
    z = jnp.concatenate([jnp.atleast_1d(x) for x in parts])
    return jax.lax.psum(jnp.where(owner, z, jnp.zeros_like(z)), axis_name)


def _exact_step_fn(
    eps: float, axis_name: str,
    tile_m: Optional[int] = None, interpret: bool = True,
):
    """Per-step body of sharded Algorithm 1, factored out so the
    whole-slate loop and the chunked streaming executor run the
    identical op sequence (streamed chunks concatenate exactly to the
    whole-slate slate).

    Returns ``step(t, Vl, ax, off, C, d2, stopped) ->
    (C, d2, stopped, j, dj)``; the jnp flavor keeps the column layout
    ``C (Mloc, k)``, the tiled flavor the row layout ``(k, Mloc)`` the
    Pallas pass streams."""

    def step_tiled(t, Vl, ax, off, C, d2, stopped):
        from repro.kernels.dpp_greedy.tiled import tiled_update_exact

        D = Vl.shape[0]
        eps2 = jnp.asarray(eps, Vl.dtype) ** 2
        jl, dj2, j, owner = _global_argmax(d2, ax, off, axis_name)
        stopped = stopped | (dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))
        # winner broadcast: V[:, j] and its Cholesky column c_j
        z = _bcast_from_owner((Vl[:, jl], C[:, jl]), owner, axis_name)
        vj, cj = z[:D], z[D:]
        e, d2 = tiled_update_exact(
            Vl, C, d2, vj, cj, dj, stopped, j, off,
            tile_m=tile_m, interpret=interpret,
        )
        C = C.at[t].set(e)
        return C, d2, stopped, j, dj

    def step(t, Vl, ax, off, C, d2, stopped):
        D = Vl.shape[0]
        eps2 = jnp.asarray(eps, Vl.dtype) ** 2
        jl, dj2, j, owner = _global_argmax(d2, ax, off, axis_name)
        stopped = stopped | (dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))
        # winner broadcast: V[:, j] and its Cholesky column c_j
        z = _bcast_from_owner((Vl[:, jl], C[jl, :]), owner, axis_name)
        vj, cj = z[:D], z[D:]
        # local shard of the update (eqs. 16-18): e = (L_j - c c_j) / d_j
        e = (vj @ Vl - C @ cj) / dj
        e = jnp.where(stopped, jnp.zeros_like(e), e)
        C = C.at[:, t].set(e)
        d2_next = d2 - e * e
        d2_next = d2_next.at[jl].set(jnp.where(owner, NEG_INF, d2_next[jl]))
        d2 = jnp.where(stopped, d2, d2_next)
        return C, d2, stopped, j, dj

    return step_tiled if tile_m is not None else step


def _exact_body(
    k: int, eps: float, axis_name: str,
    tile_m: Optional[int] = None, interpret: bool = True,
):
    """Algorithm 1 with the candidate axis sharded; mirrors
    ``greedy_chol._greedy_loop`` operation-for-operation on each shard.

    With ``tile_m`` set, the local per-step update (the O(D M/P) matvec
    + Cholesky append + d2 downdate) runs through the same tiled Pallas
    pass as the single-device streaming kernel
    (``kernels.dpp_greedy.tiled.tiled_update_exact``) — the shard's
    global column offset makes the winner masking land on the owner —
    so an M/P shard past the VMEM budget streams in double-buffered
    tiles instead of lowering through unfused jnp."""
    step = _exact_step_fn(eps, axis_name, tile_m, interpret)
    # row layout (k, Mloc) for the tiled pass, column layout (Mloc, k)
    # for jnp — the latter kept so the reduction order (and therefore
    # d_hist) stays bitwise identical to the single-device path
    row_layout = tile_m is not None

    def body_fn(Vl, maskl):
        Mloc = Vl.shape[1]
        dtype = Vl.dtype
        ax = jax.lax.axis_index(axis_name)
        off = ax.astype(jnp.int32) * Mloc

        diag = jnp.sum(Vl * Vl, axis=0)
        d2 = jnp.where(maskl, diag, NEG_INF)
        C = jnp.zeros((k, Mloc) if row_layout else (Mloc, k), dtype)
        sel = jnp.full((k,), -1, jnp.int32)
        d_hist = jnp.zeros((k,), dtype)

        def body(t, state):
            C, d2, sel, d_hist, stopped = state
            C, d2, stopped, j, dj = step(t, Vl, ax, off, C, d2, stopped)
            sel = sel.at[t].set(jnp.where(stopped, -1, j))
            d_hist = d_hist.at[t].set(jnp.where(stopped, 0.0, dj))
            return C, d2, sel, d_hist, stopped

        state = (C, d2, sel, d_hist, jnp.asarray(False))
        _, _, sel, d_hist, _ = jax.lax.fori_loop(0, k, body, state)
        return sel, jnp.sum(sel >= 0).astype(jnp.int32), d_hist

    return body_fn


def _windowed_body(
    k: int, window: int, eps: float, axis_name: str,
    tile_m: Optional[int] = None, interpret: bool = True,
):
    """Sliding-window greedy with the candidate axis sharded; mirrors
    ``windowed._windowed_loop``.

    The eviction Givens rotations read the window factor ``C[:, win]``
    — w columns scattered across shards — so each step psum-gathers that
    tiny ``(w, w)`` block first and every device then applies identical
    rotations to its local rows (and to the gathered block, which tracks
    the window columns through the loop).

    With ``tile_m`` set, the rotation coefficients are instead
    precomputed from the replicated ``(w, w)`` factor
    (``kernels.dpp_greedy.tiled.eviction_coeffs`` — the identical
    recurrence, factored out of the row sweep), the winner's
    post-eviction column and repaired ``d2[j]`` are derived from its
    broadcast *pre*-eviction column the same way, and the whole local
    evict + append lands in one ``tiled_update_windowed`` Pallas sweep
    over the shard.
    """
    w = min(window, k)
    step = _windowed_step_fn(w, eps, axis_name, tile_m, interpret)

    def body_fn(Vl, maskl):
        Mloc = Vl.shape[1]
        dtype = Vl.dtype
        ax = jax.lax.axis_index(axis_name)
        off = ax.astype(jnp.int32) * Mloc

        diag = jnp.sum(Vl * Vl, axis=0)
        d2 = jnp.where(maskl, diag, NEG_INF)
        C = jnp.zeros((w, Mloc), dtype)
        win = jnp.full((w,), -1, jnp.int32)  # window order: 0 = oldest
        sel = jnp.full((k,), -1, jnp.int32)
        d_hist = jnp.zeros((k,), dtype)

        def body(t, state):
            C, d2, win, sel, d_hist, stopped = state
            C, d2, win, stopped, j, dj = step(
                t, Vl, ax, off, C, d2, win, stopped
            )
            sel = sel.at[t].set(jnp.where(stopped, -1, j))
            d_hist = d_hist.at[t].set(jnp.where(stopped, 0.0, dj))
            return C, d2, win, sel, d_hist, stopped

        state = (C, d2, win, sel, d_hist, jnp.asarray(False))
        _, _, _, sel, d_hist, _ = jax.lax.fori_loop(0, k, body, state)
        return sel, jnp.sum(sel >= 0).astype(jnp.int32), d_hist

    return body_fn


def _windowed_step_fn(
    w: int, eps: float, axis_name: str,
    tile_m: Optional[int] = None, interpret: bool = True,
):
    """Per-step body of the sharded sliding-window greedy, factored out
    so the whole-slate loop and the chunked streaming executor run the
    identical op sequence.  Returns
    ``step(t, Vl, ax, off, C, d2, win, stopped) ->
    (C, d2, win, stopped, j, dj)`` on the ring layout ``C (w, Mloc)``.
    """

    def step_tiled(t, Vl, ax, off, C, d2, win, stopped):
        from repro.kernels.dpp_greedy.tiled import (
            eviction_coeffs,
            tiled_update_windowed,
        )

        D, Mloc = Vl.shape
        eps2 = jnp.asarray(eps, Vl.dtype) ** 2
        win0 = win
        jl, dj2, j, owner = _global_argmax(d2, ax, off, axis_name)
        stopped = stopped | (dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))

        # replicate the (w, w) window factor and the winner's
        # PRE-eviction column; everything data-dependent but small
        # is resolved here, between sweeps
        li = win - off
        owned = (win >= 0) & (li >= 0) & (li < Mloc)
        cols = jnp.take(C, jnp.clip(li, 0, Mloc - 1), axis=1)
        Cw = jax.lax.psum(
            jnp.where(owned[None, :], cols, jnp.zeros_like(cols)),
            axis_name,
        )
        z = _bcast_from_owner((Vl[:, jl], C[:, jl]), owner, axis_name)
        vj, cj_pre = z[:D], z[D:]
        full = jnp.logical_and(t >= w, jnp.logical_not(stopped))
        cos, sin, cj_post, d2j = eviction_coeffs(Cw, cj_pre, dj2, full, w)
        djp = jnp.sqrt(jnp.maximum(d2j, eps2))
        pos = jnp.minimum(t, w - 1)
        C, d2 = tiled_update_windowed(
            Vl, C, d2, vj, cj_post, djp, stopped, full, cos, sin,
            j, off, pos, w=w, tile_m=tile_m, interpret=interpret,
        )
        win_shift = jnp.roll(win, -1)
        win1 = jnp.where(full, win_shift.at[w - 1].set(-1), win)
        win = jnp.where(stopped, win0, win1.at[pos].set(j))
        return C, d2, win, stopped, j, dj

    def step(t, Vl, ax, off, C, d2, win, stopped):
        D, Mloc = Vl.shape
        dtype = Vl.dtype
        eps2 = jnp.asarray(eps, dtype) ** 2
        tiny = jnp.asarray(1e-30, dtype)
        C0, d20, win0 = C, d2, win

        jl, dj2, j, owner = _global_argmax(d2, ax, off, axis_name)
        stopped = stopped | (dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))

        # ---- gather the (w, w) window factor C[:, win] from the
        # owner shard of each window member (one psum)
        li = win - off
        owned = (win >= 0) & (li >= 0) & (li < Mloc)
        cols = jnp.take(C, jnp.clip(li, 0, Mloc - 1), axis=1)  # (w, w)
        Cw = jax.lax.psum(
            jnp.where(owned[None, :], cols, jnp.zeros_like(cols)), axis_name
        )

        # ---- evict the oldest window item (window full only): the
        # same first-row Cholesky downdate as the single-device path,
        # with rotation coefficients read from the replicated Cw
        full = jnp.logical_and(t >= w, jnp.logical_not(stopped))
        u = jnp.where(full, C[0], jnp.zeros((Mloc,), dtype))
        u_w = jnp.where(full, Cw[0], jnp.zeros((w,), dtype))
        win_shift = jnp.roll(win, -1)

        def rot(r, carry):
            C, u, Cw, u_w = carry
            read = jnp.where(full, r + 1, r)
            row = jax.lax.dynamic_slice(C, (read, 0), (1, Mloc))[0]
            row_w = jax.lax.dynamic_slice(Cw, (read, 0), (1, w))[0]
            a = row_w[r + 1]  # = C[read, win_shift[r]] when full
            b = u_w[r + 1]
            rho = jnp.maximum(jnp.sqrt(a * a + b * b), tiny)
            cos = jnp.where(full, a / rho, 1.0)
            sin = jnp.where(full, b / rho, 0.0)
            new_row = cos * row + sin * u
            new_row_w = cos * row_w + sin * u_w
            u = cos * u - sin * row
            u_w = cos * u_w - sin * row_w
            C = jax.lax.dynamic_update_slice(C, new_row[None], (r, 0))
            Cw = jax.lax.dynamic_update_slice(Cw, new_row_w[None], (r, 0))
            return C, u, Cw, u_w

        C, u, _, _ = jax.lax.fori_loop(0, w - 1, rot, (C, u, Cw, u_w))
        C = jnp.where(full, C.at[w - 1].set(0.0), C)
        d2 = jnp.where(full, d2 + u * u, d2)
        win = jnp.where(full, win_shift.at[w - 1].set(-1), win)

        # ---- append j against the post-eviction window: broadcast
        # V[:, j], the post-eviction c_j and the repaired d2[j]
        z = _bcast_from_owner(
            (Vl[:, jl], C[:, jl], d2[jl]), owner, axis_name
        )
        vj, cj, d2j = z[:D], z[D : D + w], z[D + w]
        djp = jnp.sqrt(jnp.maximum(d2j, eps2))
        e = (vj @ Vl - cj @ C) / djp
        pos = jnp.minimum(t, w - 1)
        C_next = jax.lax.dynamic_update_slice(C, e[None], (pos, 0))
        d2_next = d2 - e * e
        d2_next = d2_next.at[jl].set(jnp.where(owner, NEG_INF, d2_next[jl]))
        win_next = win.at[pos].set(j)

        C = jnp.where(stopped, C0, C_next)
        d2 = jnp.where(stopped, d20, d2_next)
        win = jnp.where(stopped, win0, win_next)
        return C, d2, win, stopped, j, dj

    return step_tiled if tile_m is not None else step


# Compiled shard_map callables, keyed by (mesh, axis_name, static args).
# jax meshes hash by device assignment, so reuse across calls is exact
# and jit handles per-shape retracing underneath; the cache is bounded
# so long-lived servers sweeping k/window/eps don't grow it forever.
@functools.lru_cache(maxsize=64)
def _greedy_fn(
    mesh, axis_name: str, k: int, window: Optional[int], eps: float,
    batched: bool = False, tile_m: Optional[int] = None,
    interpret: bool = True,
):
    if window is None:
        body = _exact_body(k, eps, axis_name, tile_m, interpret)
    else:
        body = _windowed_body(k, window, eps, axis_name, tile_m, interpret)
    if batched:
        # vmap inside shard_map: every device runs all B users on its
        # (B, D, Mloc) block and the per-step collectives batch over B
        body = jax.vmap(body)
        in_specs = (P(None, None, axis_name), P(None, axis_name))
    else:
        in_specs = (P(None, axis_name), P(axis_name))
    return jax.jit(
        shard_map_compat(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P(), P()),
        )
    )


# ---------------------------------------------------------------------------
# Resumable streaming execution (chunk-emitting; repro.core.streaming)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _stream_init_fn(mesh, axis_name: str, batched: bool = False):
    """d2 initialization as a shard_map so the per-shard reduction order
    matches the whole-slate body bit for bit."""

    def body(Vl, maskl):
        diag = jnp.sum(Vl * Vl, axis=0)
        return jnp.where(maskl, diag, NEG_INF)

    if batched:
        body = jax.vmap(body)
        in_specs = (P(None, None, axis_name), P(None, axis_name))
        out_specs = P(None, axis_name)
    else:
        in_specs = (P(None, axis_name), P(axis_name))
        out_specs = P(axis_name)
    return jax.jit(
        shard_map_compat(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )
    )


@functools.lru_cache(maxsize=64)
def _stream_chunk_fn(
    mesh, axis_name: str, chunk: int, w: Optional[int], eps: float,
    batched: bool = False, tile_m: Optional[int] = None,
    interpret: bool = True, t_batched: bool = False,
):
    """Compiled shard_map advancing ``chunk`` greedy steps on resumable
    sharded state.  The per-device loop body is built from the same step
    factories as the whole-slate ``_greedy_fn``, so a sequence of chunks
    reproduces the whole-slate selection exactly; between chunks the
    C/d2 shards stay device-resident and only the (chunk,)-sized
    sel/d_hist (plus the replicated ring/stop scalars) reach the host —
    one collective round per chunk, not per slate."""
    windowed = w is not None

    if windowed:
        step = _windowed_step_fn(w, eps, axis_name, tile_m, interpret)

        def body(Vl, C, d2, win, stopped, t0):
            Mloc = Vl.shape[1]
            ax = jax.lax.axis_index(axis_name)
            off = ax.astype(jnp.int32) * Mloc
            sel = jnp.full((chunk,), -1, jnp.int32)
            dh = jnp.zeros((chunk,), d2.dtype)

            def sbody(s, carry):
                C, d2, win, stopped, sel, dh = carry
                C, d2, win, stopped, j, dj = step(
                    t0 + s, Vl, ax, off, C, d2, win, stopped
                )
                sel = sel.at[s].set(jnp.where(stopped, -1, j))
                dh = dh.at[s].set(jnp.where(stopped, 0.0, dj))
                return C, d2, win, stopped, sel, dh

            return jax.lax.fori_loop(
                0, chunk, sbody, (C, d2, win, stopped, sel, dh)
            )

        c_spec = P(None, axis_name)
        state_in = (c_spec, P(axis_name), P(), P())
        state_out = (c_spec, P(axis_name), P(), P())
    else:
        step = _exact_step_fn(eps, axis_name, tile_m, interpret)

        def body(Vl, C, d2, stopped, t0):
            Mloc = Vl.shape[1]
            ax = jax.lax.axis_index(axis_name)
            off = ax.astype(jnp.int32) * Mloc
            sel = jnp.full((chunk,), -1, jnp.int32)
            dh = jnp.zeros((chunk,), d2.dtype)

            def sbody(s, carry):
                C, d2, stopped, sel, dh = carry
                C, d2, stopped, j, dj = step(
                    t0 + s, Vl, ax, off, C, d2, stopped
                )
                sel = sel.at[s].set(jnp.where(stopped, -1, j))
                dh = dh.at[s].set(jnp.where(stopped, 0.0, dj))
                return C, d2, stopped, sel, dh

            return jax.lax.fori_loop(
                0, chunk, sbody, (C, d2, stopped, sel, dh)
            )

        # row layout (k, Mloc) for the tiled pass, column layout
        # (Mloc, k) for jnp — as in the whole-slate bodies
        c_spec = P(None, axis_name) if tile_m is not None else P(axis_name, None)
        state_in = (c_spec, P(axis_name), P())
        state_out = (c_spec, P(axis_name), P())

    if batched:
        nstate = len(state_in)
        # t_batched: the continuous-batching slot layout carries a
        # per-slot step counter t (B,) (slots join mid-flight at
        # heterogeneous progress — repro.core.streaming slot executors);
        # the uniform batch paths keep the shared scalar
        body = jax.vmap(
            body, in_axes=(0,) * (1 + nstate) + (0 if t_batched else None,)
        )
        bat = lambda spec: P(None, *spec)
        in_specs = (
            (P(None, None, axis_name),)
            + tuple(bat(s) for s in state_in)
            + (P(None) if t_batched else P(),)
        )
        out_specs = tuple(bat(s) for s in state_out) + (
            P(None, None), P(None, None),
        )
    else:
        in_specs = (P(None, axis_name),) + state_in + (P(),)
        out_specs = state_out + (P(), P())
    return jax.jit(
        shard_map_compat(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs
        )
    )


def _stream_pad(V, Mp):
    M = V.shape[-1]
    if Mp == M:
        return V
    pad = [(0, 0)] * (V.ndim - 1) + [(0, Mp - M)]
    return jnp.pad(V, pad)


def dpp_greedy_sharded_stream_init(
    V: jnp.ndarray,
    k: int,
    *,
    mesh,
    axis_name: str = "data",
    window: Optional[int] = None,
    mask: Optional[jnp.ndarray] = None,
    tile_m: Optional[int] = None,
):
    """Initial resumable state for the sharded streaming path.

    Same contract as ``dpp_greedy_sharded`` (V (D, M) / (B, D, M),
    mask broadcastable, M padded to the mesh/tile quantum); returns a
    ``repro.core.streaming.GreedyState`` whose C/d2 leaves are the
    *global* views of the per-device slices (layouts as the whole-slate
    bodies use: exact jnp ``(M, k)`` columns, exact tiled ``(k, M)``
    rows, windowed ``(w, M)`` ring).
    """
    from repro.core.streaming import GreedyState
    from repro.kernels.dpp_greedy.tiling import validate_tile_m

    if V.ndim not in (2, 3):
        raise ValueError(
            f"sharded streaming takes V (D, M) or a user batch (B, D, M), "
            f"got ndim={V.ndim}"
        )
    if k <= 0:
        raise ValueError(f"k must be >= 1, got {k}")
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    validate_tile_m(tile_m)
    batched = V.ndim == 3
    B = V.shape[0] if batched else None
    nshards = _mesh_axis_size(mesh, axis_name)
    M = V.shape[-1]
    mask_shape = (B, M) if batched else (M,)
    if mask is None:
        mask = jnp.ones(mask_shape, bool)
    elif mask.shape != mask_shape:
        mask = jnp.broadcast_to(mask, mask_shape)
    quantum = nshards * (tile_m or 1)
    Mp = -(-M // quantum) * quantum
    V = _stream_pad(V, Mp)
    if Mp != M:
        mask = jnp.pad(
            mask, [(0, 0)] * (mask.ndim - 1) + [(0, Mp - M)],
            constant_values=False,
        )
    d2 = _stream_init_fn(mesh, axis_name, batched)(V, mask)
    dtype = V.dtype
    windowed = window is not None and window < k
    lead = (B,) if batched else ()
    if windowed:
        w = min(window, k)
        C = jnp.zeros(lead + (w, Mp), dtype)
        win = jnp.full(lead + (w,), -1, jnp.int32)
    else:
        shape = (k, Mp) if tile_m is not None else (Mp, k)
        C = jnp.zeros(lead + shape, dtype)
        win = jnp.zeros(lead + (0,), jnp.int32)
    stopped = jnp.zeros(lead, bool) if batched else jnp.asarray(False)
    return GreedyState(jnp.zeros((), jnp.int32), stopped, C, d2, win)


def dpp_greedy_sharded_stream_chunk(
    V: jnp.ndarray,
    state,
    chunk: int,
    *,
    mesh,
    axis_name: str = "data",
    eps: float = 1e-6,
    tile_m: Optional[int] = None,
    interpret: bool = True,
):
    """Advance ``chunk`` sharded greedy steps on a resumable state.

    The state is authoritative for the mode (its ``win`` leaf decides
    windowed vs exact).  Returns ``(state, sel, dh)`` — ``sel``/``dh``
    shaped ``(chunk,)`` single / ``(B, chunk)`` batched, global
    candidate ids.  Chunks concatenate exactly to
    ``dpp_greedy_sharded``'s whole-slate result.

    A batched state may carry either the shared scalar step counter
    ``t ()`` (uniform batch — every lane started together) or a
    per-slot ``t (B,)`` (the continuous-batching slot layout, where
    requests join and leave mid-flight; see the slot executors in
    ``repro.core.streaming``) — the per-device step bodies consume
    ``t`` per lane either way.
    """
    batched = V.ndim == 3
    V = _stream_pad(V, state.d2.shape[-1])
    windowed = state.win.shape[-1] > 0
    w = state.win.shape[-1] if windowed else None
    t_batched = batched and jnp.ndim(state.t) == 1
    fn = _stream_chunk_fn(
        mesh, axis_name, chunk, w, float(eps), batched, tile_m, interpret,
        t_batched,
    )
    if windowed:
        C, d2, win, stopped, sel, dh = fn(
            V, state.C, state.d2, state.win, state.stopped, state.t
        )
    else:
        C, d2, stopped, sel, dh = fn(
            V, state.C, state.d2, state.stopped, state.t
        )
        win = state.win
    new_state = type(state)(state.t + chunk, stopped, C, d2, win)
    return new_state, sel, dh


def dpp_greedy_sharded(
    V: jnp.ndarray,
    k: int,
    *,
    mesh,
    axis_name: str = "data",
    window: Optional[int] = None,
    eps: float = 1e-6,
    mask: Optional[jnp.ndarray] = None,
    tile_m: Optional[int] = None,
    interpret: bool = True,
) -> GreedyResult:
    """Greedy DPP MAP with the candidate axis of ``V`` sharded.

    ``V`` is a single problem ``(D, M)`` or a user batch ``(B, D, M)``;
    ``mask`` is ``(M,)``, ``(B, M)``, or — batched with a shared
    candidate filter — ``(M,)`` broadcast over B.  Selects the same
    slate(s) — identical indices, d_hist equal to ~1 ulp — as
    ``dpp_greedy_lowrank`` (``window=None`` / ``>= k``) or
    ``dpp_greedy_windowed_lowrank`` (smaller windows), respectively
    their ``_batch`` vmap variants, on the gathered ``V``; but each
    device's compute only touches its ``(D, M/P)`` (or ``(B, D, M/P)``)
    shard where ``P = mesh.shape[axis_name]``.  ``M`` is zero-padded
    (mask False) up to a multiple of ``P``; padding can never be
    selected.

    The index-for-index match holds while marginal gains sit above the
    float32 cancellation-noise floor; past the kernel's numerical rank
    (``k`` beyond ~``D`` selections) the argmax runs on rounding noise
    on any backend — set ``eps`` to stop there (paper eq. 20), as the
    single-device paths also should.

    ``tile_m`` routes each device's local per-step update through the
    tiled streaming Pallas pass (``repro.kernels.dpp_greedy.tiled``) in
    ``tile_m``-column blocks — the same kernel the single-device tiled
    path runs — so shards whose (D, M/P) working set exceeds the VMEM
    budget stream through it instead of lowering through unfused jnp.
    ``M`` is padded up to a multiple of ``P * tile_m``.  ``interpret``
    applies to those Pallas calls (interpret mode on CPU meshes).
    """
    if V.ndim not in (2, 3):
        raise ValueError(
            f"dpp_greedy_sharded takes V (D, M) or a user batch (B, D, M), "
            f"got ndim={V.ndim}"
        )
    if k <= 0:
        raise ValueError(f"k must be >= 1, got {k}")
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    from repro.kernels.dpp_greedy.tiling import validate_tile_m

    validate_tile_m(tile_m)
    batched = V.ndim == 3
    nshards = _mesh_axis_size(mesh, axis_name)
    M = V.shape[-1]
    mask_shape = (V.shape[0], M) if batched else (M,)
    if mask is None:
        mask = jnp.ones(mask_shape, bool)
    elif mask.shape != mask_shape:
        mask = jnp.broadcast_to(mask, mask_shape)
    quantum = nshards * (tile_m or 1)
    Mp = -(-M // quantum) * quantum
    if Mp != M:
        pad = [(0, 0)] * (V.ndim - 1) + [(0, Mp - M)]
        V = jnp.pad(V, pad)
        mask = jnp.pad(mask, pad[1:], constant_values=False)
    window_eff = window if (window is not None and window < k) else None
    fn = _greedy_fn(
        mesh, axis_name, k, window_eff, float(eps), batched, tile_m,
        interpret,
    )
    sel, n, d_hist = fn(V, mask)
    return GreedyResult(sel, n, d_hist)


@functools.lru_cache(maxsize=64)
def _topk_fn(mesh, axis_name: str, c: int, batched: bool = False):
    nsh = _mesh_axis_size(mesh, axis_name)
    # log(P) tree merge: recursive doubling over the hypercube — at
    # round r every device exchanges its current top-c with its
    # (axis ^ 2^r) partner and keeps the top-c of the union, so after
    # log2(P) rounds every device holds the exact global top-c having
    # moved P*log(P)*c values total instead of the all-gather's P^2*c
    # replicated payload.  Requires power-of-two P; other axis sizes
    # keep the all-gather merge.
    tree = nsh > 1 and (nsh & (nsh - 1)) == 0

    def body(s):
        Mloc = s.shape[0]
        off = jax.lax.axis_index(axis_name).astype(jnp.int32) * Mloc
        cl = min(c, Mloc)
        v, i = jax.lax.top_k(s, cl)
        gi = i.astype(jnp.int32) + off
        if not tree:
            av = jax.lax.all_gather(v, axis_name).reshape(-1)
            ai = jax.lax.all_gather(gi, axis_name).reshape(-1)
            vv, pp = jax.lax.top_k(av, c)
            return vv, ai[pp]
        if cl < c:  # pad local lists to a common length c
            v = jnp.concatenate([v, jnp.full((c - cl,), NEG_INF, v.dtype)])
            gi = jnp.concatenate(
                [gi, jnp.full((c - cl,), jnp.iinfo(jnp.int32).max, jnp.int32)]
            )
        # sort keys (-value, index): value-descending with lowest-global-
        # index tie-breaking — exactly the order (and tie winners)
        # jax.lax.top_k produces on the gathered vector, because each
        # local top_k already lists equal values by ascending index
        nv = -v
        for step in range(nsh.bit_length() - 1):
            d = 1 << step
            perm = [(p, p ^ d) for p in range(nsh)]
            pnv = jax.lax.ppermute(nv, axis_name, perm)
            pgi = jax.lax.ppermute(gi, axis_name, perm)
            snv, sgi = jax.lax.sort(
                (jnp.concatenate([nv, pnv]), jnp.concatenate([gi, pgi])),
                num_keys=2,
            )
            nv, gi = snv[:c], sgi[:c]
        return -nv, gi

    if batched:
        body = jax.vmap(body)
        in_specs = (P(None, axis_name),)
    else:
        in_specs = (P(axis_name),)
    return jax.jit(
        shard_map_compat(
            body,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(), P()),
        )
    )


def sharded_topk(scores: jnp.ndarray, c: int, *, mesh, axis_name: str = "data"):
    """Global top-c of a candidate-sharded score vector ``scores (M,)``
    or score batch ``(B, M)``.

    Each shard takes a local top-``min(c, M/P)``; the survivors then
    merge in ``log2(P)`` recursive-doubling rounds (pairwise
    ``lax.ppermute`` exchange + top-c reduce — exact, since every
    global top-c element survives its own shard's local top-c and
    top-c-of-unions preserves it), falling back to a single all-gather
    merge when P is not a power of two.  The sharded replacement for a
    single-device ``jax.lax.top_k`` shortlist.  Returns
    ``(values (c,), global indices (c,) int32)`` — leading B axis when
    batched — with the same value order and lowest-index tie-breaking
    as ``jax.lax.top_k`` on the gathered vector(s).
    """
    if scores.ndim not in (1, 2):
        raise ValueError(
            f"sharded_topk takes scores (M,) or a batch (B, M), "
            f"got ndim={scores.ndim}"
        )
    batched = scores.ndim == 2
    nshards = _mesh_axis_size(mesh, axis_name)
    M = scores.shape[-1]
    c = min(c, M)
    if c <= 0:
        raise ValueError(f"c must be >= 1, got {c}")
    Mp = -(-M // nshards) * nshards
    if Mp != M:
        pad = ((0, 0), (0, Mp - M)) if batched else ((0, Mp - M),)
        scores = jnp.pad(scores, pad, constant_values=NEG_INF)
    return _topk_fn(mesh, axis_name, c, batched)(scores)
