"""The paper's primary contribution: fast greedy DPP MAP inference
("Div-DPP", Chen et al. 2017/2018) plus the kernel construction, the
naive-greedy oracle, the sliding-window and candidate-sharded variants,
the reference diversifiers and the evaluation metrics.
"""
from repro.core.kernel_matrix import (
    build_kernel_dense,
    build_kernel_dense_raw,
    map_relevance,
    normalize_columns,
    scaled_features,
    scaled_features_raw,
    similarity_from_features,
)
from repro.core.greedy_chol import (
    GreedyResult,
    dpp_greedy,
    dpp_greedy_dense,
    dpp_greedy_dense_batch,
    dpp_greedy_lowrank,
    dpp_greedy_lowrank_batch,
)
from repro.core.windowed import (
    dpp_greedy_windowed,
    dpp_greedy_windowed_batch,
    dpp_greedy_windowed_lowrank,
    dpp_greedy_windowed_lowrank_batch,
    dpp_greedy_windowed_rebuild,
)
from repro.core.dispatch import (
    GreedySpec,
    GreedySpecError,
    greedy_map,
    greedy_map_chunks,
)
from repro.core.sharded import dpp_greedy_sharded, sharded_topk
from repro.core.streaming import (
    GreedyState,
    greedy_chunk,
    greedy_chunk_slots,
    greedy_init,
    greedy_slot_state,
    greedy_slots_init,
    greedy_step,
    state_evict,
    state_splice,
)
from repro.core.greedy_naive import greedy_map_naive
from repro.core.baselines import (
    greedy_avg_select,
    mmr_select,
    random_top_select,
    top_n_select,
)
from repro.core.metrics import (
    log_det_objective,
    mean_slate_diversity,
    recall_at_n,
    slate_diversity,
)

__all__ = [
    "GreedyResult",
    "GreedySpec",
    "GreedySpecError",
    "GreedyState",
    "greedy_map",
    "greedy_map_chunks",
    "greedy_init",
    "greedy_step",
    "greedy_chunk",
    "greedy_chunk_slots",
    "greedy_slot_state",
    "greedy_slots_init",
    "state_evict",
    "state_splice",
    "dpp_greedy_sharded",
    "sharded_topk",
    "dpp_greedy_windowed",
    "dpp_greedy_windowed_batch",
    "dpp_greedy_windowed_lowrank",
    "dpp_greedy_windowed_lowrank_batch",
    "dpp_greedy_windowed_rebuild",
    "build_kernel_dense",
    "build_kernel_dense_raw",
    "map_relevance",
    "normalize_columns",
    "scaled_features",
    "scaled_features_raw",
    "similarity_from_features",
    "dpp_greedy",
    "dpp_greedy_dense",
    "dpp_greedy_dense_batch",
    "dpp_greedy_lowrank",
    "dpp_greedy_lowrank_batch",
    "greedy_map_naive",
    "greedy_avg_select",
    "mmr_select",
    "random_top_select",
    "top_n_select",
    "log_det_objective",
    "mean_slate_diversity",
    "recall_at_n",
    "slate_diversity",
]
