"""DPP kernel-matrix construction (paper eqs. (5), (21), (22)).

The paper builds the DPP kernel from a relevance vector ``r`` and an item
similarity matrix ``S``::

    L = Diag(m(r)) . S . Diag(m(r)),     m(r_i) = alpha ** r_i   (alpha >= 1)

With ``alpha == 1`` the kernel reduces to pure similarity (maximum
diversity); as ``alpha`` grows the most-relevant set dominates (Thm 4.2).

Two representations are supported:

* **dense** — the explicit ``(M, M)`` kernel ``L`` (the paper's setting,
  ``M`` ~ 1e3 shortlisted candidates);
* **implicit low-rank** — ``S = F^T F`` for column-normalized features
  ``F in (D, M)``; the kernel is represented by the *scaled feature*
  matrix ``V = F * m(r)`` so that ``L = V^T V`` and any row
  ``L_j = V[:, j]^T V`` is recomputed on the fly.  This never
  materializes ``O(M^2)`` memory and is the TPU-native serving path;
  it is also what lets ``repro.core.sharded`` shard the candidate axis
  (each device only needs its column shard of ``V``).
"""
from __future__ import annotations

import jax.numpy as jnp


def map_relevance(r: jnp.ndarray, alpha) -> jnp.ndarray:
    """Paper eq. (21): m(r_i) = alpha ** r_i, computed in log space."""
    alpha = jnp.asarray(alpha, dtype=r.dtype)
    return jnp.exp(r * jnp.log(alpha))


def normalize_columns(F: jnp.ndarray, eps: float = 1e-12) -> jnp.ndarray:
    """Unit-l2-normalize the columns of a (D, M) feature matrix."""
    nrm = jnp.linalg.norm(F, axis=0, keepdims=True)
    return F / jnp.maximum(nrm, eps)


def similarity_from_features(F: jnp.ndarray) -> jnp.ndarray:
    """S = F^T F for column-normalized F (paper §5.1 synthetic setup)."""
    return F.T @ F


def build_kernel_dense(
    relevance: jnp.ndarray, similarity: jnp.ndarray, alpha=1.0
) -> jnp.ndarray:
    """Paper eq. (22): L = Diag(alpha^r) S Diag(alpha^r); eq. (5) at alpha s.t.
    alpha^r == r (i.e. callers wanting the *raw* eq.-(5) kernel pass the
    relevance through ``build_kernel_dense(log_r / log_alpha, ...)`` or use
    ``build_kernel_dense_raw``)."""
    m = map_relevance(relevance, alpha)
    return (m[:, None] * similarity) * m[None, :]


def build_kernel_dense_raw(
    relevance: jnp.ndarray, similarity: jnp.ndarray
) -> jnp.ndarray:
    """Paper eq. (5): L = Diag(r) S Diag(r) (no exponential mapping)."""
    return (relevance[:, None] * similarity) * relevance[None, :]


def scaled_features(
    feats: jnp.ndarray, relevance: jnp.ndarray, alpha=1.0
) -> jnp.ndarray:
    """Implicit kernel: V = F * alpha^r so that L = V^T V.

    ``feats`` is (D, M) column-normalized; ``relevance`` is (M,).
    """
    return feats * map_relevance(relevance, alpha)[None, :]


def scaled_features_raw(feats: jnp.ndarray, relevance: jnp.ndarray) -> jnp.ndarray:
    """Implicit eq.-(5) kernel: V = F * r."""
    return feats * relevance[None, :]
