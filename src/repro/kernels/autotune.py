"""``python -m repro.kernels.autotune`` — measured kernel-geometry
sweep for the dpp_greedy Pallas seams.

Thin runner over :mod:`repro.kernels.dpp_greedy.autotune` (the cache,
keying, and measurement harness live there, next to the kernels they
tune).  Typical use::

    python -m repro.kernels.autotune --smoke          # tiny CI preset
    python -m repro.kernels.autotune --full --trials 5

then serve with ``tile_m="auto"`` (``GreedySpec`` / ``DPPRerankConfig``)
pointed at the same cache (``$DPP_AUTOTUNE_CACHE`` or the per-user
default).
"""
from repro.kernels.dpp_greedy.autotune import main

if __name__ == "__main__":
    raise SystemExit(main())
