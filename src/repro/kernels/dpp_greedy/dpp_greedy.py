"""Pallas TPU kernels: whole-slate greedy DPP MAP inference, VMEM-resident.

TPU-native adaptation of the paper's Algorithm 1 (DESIGN.md §3):

* the kernel never materializes ``L`` — it holds the *scaled feature*
  matrix ``V (D, M)`` (``L = V^T V``) in VMEM and recomputes the needed
  kernel row ``L_j = V[:, j]^T V`` on the MXU each step;
* the Cholesky-state matrix ``C`` is laid out **(N, M)** — step ``t``
  writes *row* ``t`` (a contiguous lane-dim store) instead of the paper's
  per-candidate column append, and the update inner product
  ``<c_j, c_i>`` for all ``i`` is the matvec ``c_j^T C`` on the MXU;
* the entire N-step greedy loop runs inside one kernel invocation with
  zero HBM round-trips between steps; the grid dimension is the *user
  batch* (one program = one user's slate).

``window=w`` switches to the **sliding-window** kernel (the NeurIPS'18
long-sequence variant): ``C`` shrinks to a ``(w, M)`` ring of window
Cholesky rows, so the slate length ``N`` is unbounded while VMEM stays
O(w M).  Each step is select (argmax over the maintained ``d2``), evict
(the first-row Cholesky downdate — ``w - 1`` Givens rotations swept over
the rows of ``C``, with the rotation residue row repairing ``d2``), and
append (the same eq. 16-18 row append as the full kernel, against the
post-eviction window).  See ``repro.core.windowed`` for the math.

VMEM working set (resident mode): ``V`` (D*M*4) + ``C`` (N*M*4, or
w*M*4 windowed) + ``d2/e`` rows — e.g. D=128, M=4096, N=64: 2 MB +
1 MB, comfortably inside 16 MB v5e VMEM
(``tiling.untiled_vmem_bytes``).  These kernels hold that working set
*whole*, which is what buys the zero-HBM-round-trip greedy loop — and
what caps M.  Past the budget the ops.py wrapper dispatches the tiled
streaming kernels in ``tiled.py`` instead (per-step grid sweeps over
``(D, tile_m)`` blocks, double-buffered HBM<->VMEM, VMEM bounded per
*tile* by ``tiling.tile_vmem_bytes``) — there is no silent jnp fallback
at scale any more; the jnp oracle needs an explicit ``force_jnp=True``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(v_ref, mask_ref, sel_ref, dhist_ref, c_ref, *, k: int, eps: float):
    """One user's full greedy slate.

    v_ref:    (D, M) f32 — scaled features, L = V^T V
    mask_ref: (1, M) f32 — 1.0 where selectable
    sel_ref:  (1, N) i32 out
    dhist_ref:(1, N) f32 out
    c_ref:    (N, M) f32 VMEM scratch — incremental Cholesky rows
    """
    V = v_ref[...]
    mask = mask_ref[...]  # (1, M)
    M = V.shape[1]
    eps2 = eps * eps

    diag = jnp.sum(V * V, axis=0, keepdims=True)  # (1, M)
    d2 = jnp.where(mask > 0, diag, NEG_INF)
    c_ref[...] = jnp.zeros_like(c_ref)
    sel_ref[...] = jnp.full(sel_ref.shape, -1, jnp.int32)
    dhist_ref[...] = jnp.zeros(dhist_ref.shape, jnp.float32)

    def body(t, carry):
        d2, stopped = carry
        j = jnp.argmax(d2[0])
        dj2 = d2[0, j]
        stopped = jnp.logical_or(stopped, dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))

        # kernel row L_j = V[:, j]^T V  — (1, D) x (D, M) on the MXU
        vj = jax.lax.dynamic_slice(V, (0, j), (V.shape[0], 1))  # (D, 1)
        lj = jnp.dot(vj.T, V, preferred_element_type=jnp.float32)  # (1, M)

        # <c_j, c_i> for all i — (1, N) x (N, M) on the MXU
        cj = jax.lax.dynamic_slice(c_ref[...], (0, j), (c_ref.shape[0], 1))  # (N,1)
        dots = jnp.dot(cj.T, c_ref[...], preferred_element_type=jnp.float32)

        e = (lj - dots) / dj  # (1, M)
        e = jnp.where(stopped, jnp.zeros_like(e), e)
        pl.store(c_ref, (pl.dslice(t, 1), pl.dslice(0, M)), e)

        iota = jax.lax.broadcasted_iota(jnp.int32, (1, M), 1)
        d2_next = jnp.where(iota == j, NEG_INF, d2 - e * e)
        d2 = jnp.where(stopped, d2, d2_next)

        sel_val = jnp.where(stopped, -1, j).astype(jnp.int32)
        pl.store(sel_ref, (pl.dslice(0, 1), pl.dslice(t, 1)), sel_val[None, None])
        d_val = jnp.where(stopped, 0.0, dj).astype(jnp.float32)
        pl.store(dhist_ref, (pl.dslice(0, 1), pl.dslice(t, 1)), d_val[None, None])
        return d2, stopped

    jax.lax.fori_loop(0, k, body, (d2, jnp.asarray(False)))


def _kernel_windowed(
    v_ref, mask_ref, sel_ref, dhist_ref, c_ref, *, k: int, w: int, eps: float
):
    """One user's full slate with a sliding diversity window of ``w``.

    v_ref:    (D, M) f32 — scaled features, L = V^T V
    mask_ref: (1, M) f32 — 1.0 where selectable
    sel_ref:  (1, N) i32 out (N = k, unbounded)
    dhist_ref:(1, N) f32 out
    c_ref:    (w, M) f32 VMEM scratch — ring of window Cholesky rows in
              window order (row 0 = oldest pick still in the window)
    """
    V = v_ref[...]
    mask = mask_ref[...]  # (1, M)
    M = V.shape[1]
    eps2 = eps * eps
    tiny = 1e-30

    diag = jnp.sum(V * V, axis=0, keepdims=True)  # (1, M)
    d2 = jnp.where(mask > 0, diag, NEG_INF)
    c_ref[...] = jnp.zeros_like(c_ref)
    sel_ref[...] = jnp.full(sel_ref.shape, -1, jnp.int32)
    dhist_ref[...] = jnp.zeros(dhist_ref.shape, jnp.float32)

    def body(t, carry):
        d2, win, stopped = carry
        # ---- select against the current window of min(t, w) picks
        j = jnp.argmax(d2[0])
        dj2 = d2[0, j]
        stopped = jnp.logical_or(stopped, dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))

        # ---- evict the oldest pick: first-row Cholesky downdate as
        # w - 1 Givens rotations swept over the rows of C; identity
        # rotation (cos=1, sin=0, read==write row) when not evicting
        full = jnp.logical_and(t >= w, jnp.logical_not(stopped))
        u0 = jnp.where(full, c_ref[0:1, :], jnp.zeros((1, M), jnp.float32))
        win_shift = jnp.roll(win, -1, axis=1)  # win_shift[0, r] = old win[0, r+1]

        def rot(r, u):
            read = jnp.where(full, r + 1, r)
            row = pl.load(c_ref, (pl.dslice(read, 1), pl.dslice(0, M)))  # (1, M)
            idx = jnp.maximum(win_shift[0, r], 0)
            a = jax.lax.dynamic_slice(row, (0, idx), (1, 1))[0, 0]
            b = jax.lax.dynamic_slice(u, (0, idx), (1, 1))[0, 0]
            rho = jnp.maximum(jnp.sqrt(a * a + b * b), tiny)
            cos = jnp.where(full, a / rho, 1.0)
            sin = jnp.where(full, b / rho, 0.0)
            pl.store(c_ref, (pl.dslice(r, 1), pl.dslice(0, M)), cos * row + sin * u)
            return cos * u - sin * row

        u = jax.lax.fori_loop(0, w - 1, rot, u0)
        last = c_ref[w - 1 : w, :]
        c_ref[w - 1 : w, :] = jnp.where(full, jnp.zeros_like(last), last)
        d2 = jnp.where(full, d2 + u * u, d2)
        win = jnp.where(full, win_shift.at[0, w - 1].set(-1), win)

        # ---- append j against the post-eviction window (eqs. 16-18)
        djp = jnp.sqrt(jnp.maximum(d2[0, j], eps2))
        vj = jax.lax.dynamic_slice(V, (0, j), (V.shape[0], 1))  # (D, 1)
        lj = jnp.dot(vj.T, V, preferred_element_type=jnp.float32)  # (1, M)
        cj = jax.lax.dynamic_slice(c_ref[...], (0, j), (w, 1))  # (w, 1)
        dots = jnp.dot(cj.T, c_ref[...], preferred_element_type=jnp.float32)
        e = (lj - dots) / djp  # (1, M)

        pos = jnp.minimum(t, w - 1)
        old = pl.load(c_ref, (pl.dslice(pos, 1), pl.dslice(0, M)))
        pl.store(
            c_ref,
            (pl.dslice(pos, 1), pl.dslice(0, M)),
            jnp.where(stopped, old, e),
        )
        iota = jax.lax.broadcasted_iota(jnp.int32, (1, M), 1)
        d2_next = jnp.where(iota == j, NEG_INF, d2 - e * e)
        d2 = jnp.where(stopped, d2, d2_next)
        win_next = jax.lax.dynamic_update_slice(
            win, j[None, None].astype(jnp.int32), (0, pos)
        )
        win = jnp.where(stopped, win, win_next)

        sel_val = jnp.where(stopped, -1, j).astype(jnp.int32)
        pl.store(sel_ref, (pl.dslice(0, 1), pl.dslice(t, 1)), sel_val[None, None])
        d_val = jnp.where(stopped, 0.0, dj).astype(jnp.float32)
        pl.store(dhist_ref, (pl.dslice(0, 1), pl.dslice(t, 1)), d_val[None, None])
        return d2, win, stopped

    win0 = jnp.full((1, w), -1, jnp.int32)
    jax.lax.fori_loop(0, k, body, (d2, win0, jnp.asarray(False)))


@functools.partial(jax.jit, static_argnames=("k", "window", "eps", "interpret"))
def dpp_greedy_kernel(
    V: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    window: int | None = None,
    eps: float = 1e-3,
    interpret: bool = True,
):
    """Batched greedy DPP MAP on TPU.

    V:    (B, D, M) f32 scaled features (columns = alpha^r_i * f_i)
    mask: (B, M) bool/float — selectable candidates
    window: sliding diversity window ``w`` (None = full, exact Alg. 1);
        with ``w < k`` the VMEM state is O(w M) so ``k`` is unbounded.
    Returns (sel (B, k) i32, d_hist (B, k) f32).
    """
    B, D, M = V.shape
    mask = mask.astype(jnp.float32).reshape(B, 1, M)

    if window is not None and window < k:
        kernel = functools.partial(_kernel_windowed, k=k, w=window, eps=eps)
        state_rows = window
    else:
        kernel = functools.partial(_kernel, k=k, eps=eps)
        state_rows = k
    sel, dhist = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, D, M), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, 1, M), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, 1, k), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, 1, k), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, k), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((state_rows, M), jnp.float32)],
        interpret=interpret,
    )(V.astype(jnp.float32), mask)
    return sel[:, 0, :], dhist[:, 0, :]
