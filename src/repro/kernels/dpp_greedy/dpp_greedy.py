"""Pallas TPU kernel: whole-slate greedy DPP MAP inference in VMEM.

TPU-native adaptation of the paper's Algorithm 1 (DESIGN.md §3):

* the kernel never materializes ``L`` — it holds the *scaled feature*
  matrix ``V (D, M)`` (``L = V^T V``) in VMEM and recomputes the needed
  kernel row ``L_j = V[:, j]^T V`` on the MXU each step;
* the Cholesky-state matrix ``C`` is laid out **(N, M)** — step ``t``
  writes *row* ``t`` (a contiguous lane-dim store) instead of the paper's
  per-candidate column append, and the update inner product
  ``<c_j, c_i>`` for all ``i`` is the matvec ``c_j^T C`` on the MXU;
* the entire N-step greedy loop runs inside one kernel invocation with
  zero HBM round-trips between steps; the grid dimension is the *user
  batch* (one program = one user's slate).

VMEM working set: ``V`` (D*M*4) + ``C`` (N*M*4) + ``d2/e`` rows —
e.g. D=128, M=4096, N=64: 2 MB + 1 MB, comfortably inside 16 MB v5e VMEM.
The ops.py wrapper falls back to the pure-jnp path when it would not fit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(v_ref, mask_ref, sel_ref, dhist_ref, c_ref, *, k: int, eps: float):
    """One user's full greedy slate.

    v_ref:    (D, M) f32 — scaled features, L = V^T V
    mask_ref: (1, M) f32 — 1.0 where selectable
    sel_ref:  (1, N) i32 out
    dhist_ref:(1, N) f32 out
    c_ref:    (N, M) f32 VMEM scratch — incremental Cholesky rows
    """
    V = v_ref[...]
    mask = mask_ref[...]  # (1, M)
    M = V.shape[1]
    eps2 = eps * eps

    diag = jnp.sum(V * V, axis=0, keepdims=True)  # (1, M)
    d2 = jnp.where(mask > 0, diag, NEG_INF)
    c_ref[...] = jnp.zeros_like(c_ref)
    sel_ref[...] = jnp.full(sel_ref.shape, -1, jnp.int32)
    dhist_ref[...] = jnp.zeros(dhist_ref.shape, jnp.float32)

    def body(t, carry):
        d2, stopped = carry
        j = jnp.argmax(d2[0])
        dj2 = d2[0, j]
        stopped = jnp.logical_or(stopped, dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))

        # kernel row L_j = V[:, j]^T V  — (1, D) x (D, M) on the MXU
        vj = jax.lax.dynamic_slice(V, (0, j), (V.shape[0], 1))  # (D, 1)
        lj = jnp.dot(vj.T, V, preferred_element_type=jnp.float32)  # (1, M)

        # <c_j, c_i> for all i — (1, N) x (N, M) on the MXU
        cj = jax.lax.dynamic_slice(c_ref[...], (0, j), (c_ref.shape[0], 1))  # (N,1)
        dots = jnp.dot(cj.T, c_ref[...], preferred_element_type=jnp.float32)

        e = (lj - dots) / dj  # (1, M)
        e = jnp.where(stopped, jnp.zeros_like(e), e)
        pl.store(c_ref, (pl.dslice(t, 1), pl.dslice(0, M)), e)

        iota = jax.lax.broadcasted_iota(jnp.int32, (1, M), 1)
        d2_next = jnp.where(iota == j, NEG_INF, d2 - e * e)
        d2 = jnp.where(stopped, d2, d2_next)

        sel_val = jnp.where(stopped, -1, j).astype(jnp.int32)
        pl.store(sel_ref, (pl.dslice(0, 1), pl.dslice(t, 1)), sel_val[None, None])
        d_val = jnp.where(stopped, 0.0, dj).astype(jnp.float32)
        pl.store(dhist_ref, (pl.dslice(0, 1), pl.dslice(t, 1)), d_val[None, None])
        return d2, stopped

    jax.lax.fori_loop(0, k, body, (d2, jnp.asarray(False)))


@functools.partial(jax.jit, static_argnames=("k", "eps", "interpret"))
def dpp_greedy_kernel(
    V: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    eps: float = 1e-3,
    interpret: bool = True,
):
    """Batched greedy DPP MAP on TPU.

    V:    (B, D, M) f32 scaled features (columns = alpha^r_i * f_i)
    mask: (B, M) bool/float — selectable candidates
    Returns (sel (B, k) i32, d_hist (B, k) f32).
    """
    B, D, M = V.shape
    mask = mask.astype(jnp.float32).reshape(B, 1, M)

    kernel = functools.partial(_kernel, k=k, eps=eps)
    sel, dhist = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((None, D, M), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, 1, M), lambda b: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, 1, k), lambda b: (b, 0, 0)),
            pl.BlockSpec((None, 1, k), lambda b: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 1, k), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, k), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((k, M), jnp.float32)],
        interpret=interpret,
    )(V.astype(jnp.float32), mask)
    return sel[:, 0, :], dhist[:, 0, :]
