from repro.kernels.dpp_greedy.autotune import (
    AutotuneCache,
    active_cache_path,
    bucket_m,
    cache_key,
    lookup_tile,
    run_sweep,
)
from repro.kernels.dpp_greedy.ops import (
    dpp_greedy,
    dpp_greedy_stream_chunk,
    dpp_greedy_stream_init,
    dpp_greedy_stream_pad,
)
from repro.kernels.dpp_greedy.ref import dpp_greedy_ref
from repro.kernels.dpp_greedy.tiled import dpp_greedy_tiled
from repro.kernels.dpp_greedy.tiling import (
    TilePolicy,
    VMEM_BUDGET_BYTES,
    tile_vmem_bytes,
    untiled_vmem_bytes,
)

__all__ = [
    "dpp_greedy",
    "dpp_greedy_ref",
    "dpp_greedy_stream_chunk",
    "dpp_greedy_stream_init",
    "dpp_greedy_stream_pad",
    "dpp_greedy_tiled",
    "AutotuneCache",
    "active_cache_path",
    "bucket_m",
    "cache_key",
    "lookup_tile",
    "run_sweep",
    "TilePolicy",
    "VMEM_BUDGET_BYTES",
    "tile_vmem_bytes",
    "untiled_vmem_bytes",
]
