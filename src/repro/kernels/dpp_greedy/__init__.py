from repro.kernels.dpp_greedy.ops import dpp_greedy, vmem_bytes
from repro.kernels.dpp_greedy.ref import dpp_greedy_ref

__all__ = ["dpp_greedy", "dpp_greedy_ref", "vmem_bytes"]
