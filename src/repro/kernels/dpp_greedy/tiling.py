"""Tile policy for the dpp_greedy Pallas kernels — the VMEM *model*, not
a gate.

Earlier revisions guarded the kernel with a single whole-array check
(``vmem_bytes(D, M, state_rows) > VMEM_BUDGET_BYTES`` -> silently fall
back to pure jnp), which surrendered exactly the large-M regime the
paper's O(M)-per-step update is about.  ``TilePolicy`` replaces that
gate with a decision between two *kernel* execution modes:

* **resident** — the whole working set (``V (D, M)``, the Cholesky
  state ``C (state_rows, M)`` and a few ``(1, M)`` rows) fits in VMEM:
  run the classic whole-slate kernels in ``dpp_greedy.py`` (the entire
  greedy loop inside one ``pallas_call``, zero HBM round-trips between
  steps).
* **tiled** — the working set exceeds the budget: run the streaming
  kernels in ``tiled.py``.  Each greedy step is one grid sweep over
  ``M``-tiles; per grid step only ``(D, tile_m)`` of ``V`` and
  ``(state_rows, tile_m)`` of ``C`` are VMEM-resident, and the Pallas
  BlockSpec pipeline double-buffers the HBM->VMEM (and VMEM->HBM)
  copies of consecutive tiles.  The VMEM bound is per *tile*, so M is
  unbounded.

The pure-jnp path survives only as an explicit escape hatch
(``force_jnp=True``) and as a last resort when even a single
lane-width tile would not fit (pathological ``D``/``state_rows``).

(The pre-tiling ``vmem_bytes`` name lived here as a DeprecationWarning
shim for one release after PR 4 and is now removed; the resident-mode
working set is :func:`untiled_vmem_bytes`, the per-tile model
:func:`tile_vmem_bytes`.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

# What the tile_m knob accepts across the stack: an explicit LANE
# multiple, the measured-autotuner mode, or None (VMEM model decides).
TileM = Union[int, str, None]

LANE = 128
SUBLANE = 8
# Budget for f32 working sets inside ~16 MB/core VMEM, leaving headroom
# for the compiler's own temporaries.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024
# Upper bound for auto-chosen tiles: past this, wider tiles stop paying
# (DMA is already fully amortized) and only lengthen the pipeline warmup.
MAX_AUTO_TILE = 1 << 16


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``x`` (TPU lane/sublane alignment)."""
    return (x + m - 1) // m * m


def validate_tile_m(tile_m: TileM, allow_auto: bool = False) -> None:
    """Shared tile_m validation (TilePolicy, GreedySpec, DPPRerankConfig,
    dpp_greedy_sharded all accept the knob): ``None``, a positive LANE
    multiple, or — where ``allow_auto`` — the string ``"auto"`` (consult
    the measured autotune cache, fall back to the VMEM model).  Call
    sites that cannot consult the cache (the sharded per-device update,
    the jnp backend) keep the default ``allow_auto=False`` so a stray
    ``"auto"`` fails loudly instead of leaking a string into tile
    arithmetic."""
    if tile_m is None:
        return
    if tile_m == "auto":
        if allow_auto:
            return
        raise ValueError(
            'tile_m="auto" (the measured autotune cache) is only '
            "understood by the single-device Pallas dispatch — this "
            f"call site needs None or an explicit positive multiple of "
            f"the {LANE}-lane register width"
        )
    if (not isinstance(tile_m, int) or isinstance(tile_m, bool)
            or tile_m < LANE or tile_m % LANE != 0):
        raise ValueError(
            f'tile_m must be None, "auto" (measured autotune cache with '
            f"VMEM-model fallback), or a positive multiple of the "
            f"{LANE}-lane register width, got {tile_m!r}"
        )


def untiled_vmem_bytes(D: int, M: int, state_rows: int) -> int:
    """Whole-array (resident-mode) VMEM working set.

    ``V`` (D, M) + ``C`` (state_rows, M) + a few (1, M) rows, all f32,
    padded to the (SUBLANE, LANE) f32 tile.  ``state_rows`` is ``k``
    (full slate) or ``w`` (windowed).
    """
    Mp, Dp = round_up(M, LANE), round_up(D, SUBLANE)
    return 4 * (Dp * Mp + round_up(state_rows, SUBLANE) * Mp + 8 * Mp)


def tile_vmem_bytes(
    D: int, tile_m: int, state_rows: int, windowed: bool = False,
    chunked: bool = False,
) -> int:
    """Per-grid-step VMEM working set of the tiled streaming kernels.

    Counts the double-buffered streams (x2: while tile ``i`` computes,
    the pipeline prefetches tile ``i+1`` and drains tile ``i-1``):
    the ``V`` tile (D, tile_m), the Cholesky tile in (state_rows,
    tile_m), the written-back tile and the d2 tile in/out; plus the
    small per-step replicated state (winner column, rotation
    coefficients, reduction cells), which does not scale with
    ``tile_m``.

    The written-back tile is a single appended row for the per-step
    exact sweep, but the **full** (state_rows, tile_m) state when
    ``windowed`` (post-eviction rewrite) *or* ``chunked`` (the fused
    multi-step chunk kernels stream the whole Cholesky block back out
    every step — see ``fused_chunk_exact``'s first out_spec).  The
    ``repro.analysis`` pallas-vmem-model rule cross-checks this count
    against the BlockSpecs the kernels actually declare.
    """
    Dp = round_up(D, SUBLANE)
    Rp = round_up(state_rows, SUBLANE)
    out_rows = Rp if (windowed or chunked) else SUBLANE
    streamed = Dp + Rp + out_rows + 2 * SUBLANE
    small = 4 * (Dp + Rp + 4 * LANE)
    return 4 * 2 * streamed * tile_m + small


@dataclasses.dataclass(frozen=True)
class TilePolicy:
    """How the dpp_greedy kernels use VMEM.

    tile_m:
        Explicit candidate-axis tile width (multiple of ``LANE``).
        Forces the tiled streaming kernels even when the resident
        kernels would fit — that is how tiled-vs-resident parity is
        tested.  ``None`` picks automatically: resident when the whole
        working set fits ``vmem_budget_bytes``, otherwise the widest
        fitting tile.  ``"auto"`` keeps the resident-when-it-fits rule
        but sizes the tiled mode from the *measured* autotune cache
        (``repro.kernels.dpp_greedy.autotune``) when it has an entry
        for this device/geometry, falling back to the analytical model
        — never an error — when it does not.
    vmem_budget_bytes:
        The budget both models are checked against.
    """

    tile_m: TileM = None
    vmem_budget_bytes: int = VMEM_BUDGET_BYTES

    def __post_init__(self):
        validate_tile_m(self.tile_m, allow_auto=True)
        if self.vmem_budget_bytes <= 0:
            raise ValueError(
                f"vmem_budget_bytes must be positive, got "
                f"{self.vmem_budget_bytes}"
            )

    def auto_tile(
        self, D: int, state_rows: int, windowed: bool,
        chunked: bool = False,
    ) -> int:
        """Widest LANE-multiple tile whose working set fits the budget
        (0 when even one lane-width tile does not fit)."""
        lo = tile_vmem_bytes(D, LANE, state_rows, windowed, chunked)
        if lo > self.vmem_budget_bytes:
            return 0
        per_lane = (
            tile_vmem_bytes(D, 2 * LANE, state_rows, windowed, chunked) - lo
        )
        spare = self.vmem_budget_bytes - lo
        tm = LANE * (1 + spare // max(per_lane, 1))
        return min(tm, MAX_AUTO_TILE)

    def decide(
        self, D: int, M: int, state_rows: int, windowed: bool,
        chunked: bool = False,
    ) -> tuple[str, Optional[int]]:
        """-> ("resident", None) | ("tiled", tile_m) | ("jnp", None).

        ``chunked`` must be set when the tile will feed the fused
        multi-step chunk kernels, whose per-tile working set is larger
        than the per-step exact sweep's (full state streams back out
        every step) — sizing a chunked tile with the per-step model
        overflows the budget by ``~8 * state_rows * tile_m`` bytes.
        """
        if self.tile_m is not None and self.tile_m != "auto":
            return "tiled", self.tile_m
        if untiled_vmem_bytes(D, M, state_rows) <= self.vmem_budget_bytes:
            return "resident", None
        tm = None
        if self.tile_m == "auto":
            # measured winner for this device/geometry, prefiltered to
            # the budget; a miss (no cache, unknown device, corrupted
            # JSON) falls through to the analytical model below
            from repro.kernels.dpp_greedy.autotune import lookup_tile

            tm = lookup_tile(
                D=D, M=M, state_rows=state_rows, windowed=windowed,
                chunked=chunked, vmem_budget_bytes=self.vmem_budget_bytes,
            )
        if tm is None:
            tm = self.auto_tile(D, state_rows, windowed, chunked)
        if tm == 0:
            return "jnp", None
        return "tiled", min(tm, round_up(M, LANE))
