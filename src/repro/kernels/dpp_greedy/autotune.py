"""Measured kernel-geometry autotuner for the dpp_greedy Pallas seams.

``TilePolicy``'s analytical VMEM model answers "what *fits*"; it cannot
answer "what is *fastest*" — the best tile on one architecture's memory
hierarchy is not the best on another (one logical device may hide
several local memory domains).  This module measures instead of
modelling:

* **Sweep** (:func:`run_sweep`, ``python -m repro.kernels.autotune``) —
  for each tiled seam family (the exact/windowed per-step passes and
  the fused multi-step chunk kernels) over a small
  ``(D, M-bucket, w, chunk_size)`` grid, time real ``pallas_call``
  launches for every candidate tile.  Candidates are *prefiltered by
  the analytical model* (power-of-two ``LANE`` multiples up to
  ``TilePolicy.auto_tile`` — including the ``chunked=`` working-set
  distinction), so the tuner can only ever persist in-budget
  geometries.
* **Cache** (:class:`AutotuneCache`) — winners persist to an on-disk
  JSON document keyed by ``(device_kind, platform, backend, D,
  M_bucket, state_rows, windowed, chunked)`` with schema versioning and
  atomic writes (tmp file + ``os.replace``).  ``M`` is bucketed to the
  next power of two so one measurement covers a band of slate widths
  and the lookup stays monotone in ``M``.
* **Lookup ladder** (:func:`lookup_tile`, consumed by
  ``TilePolicy.decide`` when ``tile_m="auto"``) — exact key hit →
  nearest M-bucket with otherwise identical key → ``None`` (the caller
  falls back to the analytical model).  Every rung re-validates the
  entry against the VMEM budget, so a stale or hand-edited cache can
  only ever *miss*, never ship an over-budget launch; the
  ``repro.analysis`` ``autotune-cache-invalid`` rule additionally
  re-validates the persisted file against the kernels' declared
  BlockSpecs.  The ladder never raises: a missing file, unknown
  device, or corrupted JSON is a recorded miss.

Every decision lands in the PR-7 dispatch telemetry
(``autotune_cache_hits_total{kind=exact|bucket}`` /
``autotune_cache_misses_total{reason=...}`` and the ``autotune_tile_m``
gauge) so the serving fleet can see which geometry source actually ran.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import tempfile
import time
from typing import Iterable, Optional, Sequence

from repro.kernels.dpp_greedy.tiling import (
    LANE,
    MAX_AUTO_TILE,
    VMEM_BUDGET_BYTES,
    TilePolicy,
    tile_vmem_bytes,
)
from repro.obs.dispatch import record_autotune_lookup

SCHEMA_VERSION = 1
CACHE_ENV = "DPP_AUTOTUNE_CACHE"

FAMILIES = ("step_exact", "step_windowed", "chunk_exact", "chunk_windowed")


# ---------------------------------------------------------------------------
# Cache path, keying, bucketing
# ---------------------------------------------------------------------------


def default_cache_path() -> str:
    """``$XDG_CACHE_HOME``-respecting per-user default, outside any
    source tree so a tuned dev box never dirties a checkout."""
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache"
    )
    return os.path.join(base, "repro", "dpp_autotune.json")


def active_cache_path() -> str:
    """The cache file every lookup and sweep uses: ``$DPP_AUTOTUNE_CACHE``
    when set, else :func:`default_cache_path`."""
    return os.environ.get(CACHE_ENV) or default_cache_path()


def bucket_m(M: int) -> int:
    """Smallest power of two >= ``max(M, LANE)``.

    Monotone in ``M`` (the property tests pin this), so the cache's
    M-resolution coarsens geometrically: one measured bucket covers
    every slate width that pads into it.
    """
    if M < 1:
        raise ValueError(f"M must be >= 1, got {M}")
    b = LANE
    while b < M:
        b <<= 1
    return b


def _norm_field(value: object) -> str:
    """Normalize a free-text key field (device kind etc.): lowercase,
    trimmed, with the ``|`` delimiter and whitespace runs collapsed to
    ``-`` so no field can smuggle a delimiter into the key."""
    s = " ".join(str(value).strip().lower().split())
    return s.replace("|", "-").replace(" ", "-") or "unknown"


def cache_key(
    device_kind: object,
    platform: object,
    backend: object,
    D: int,
    M_bucket: int,
    state_rows: int,
    windowed: bool,
    chunked: bool,
) -> str:
    """Normalized pipe-joined cache key.  The structured fields are also
    stored on the entry; ``repro.analysis`` recomputes the key from them
    and flags any hand-edited divergence."""
    return "|".join((
        _norm_field(device_kind),
        _norm_field(platform),
        _norm_field(backend),
        f"d{int(D)}",
        f"m{int(M_bucket)}",
        f"r{int(state_rows)}",
        "w1" if windowed else "w0",
        "c1" if chunked else "c0",
    ))


def device_fingerprint() -> tuple[str, str, str]:
    """(device_kind, platform, backend) of the device the kernels run on."""
    import jax

    dev = jax.devices()[0]
    return (
        getattr(dev, "device_kind", "unknown"),
        getattr(dev, "platform", "unknown"),
        jax.default_backend(),
    )


# ---------------------------------------------------------------------------
# Persisted cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AutotuneCache:
    """In-memory view of one persisted autotune cache file."""

    path: str
    entries: dict[str, dict]
    corrupt: bool = False  # file existed but did not parse/validate

    @classmethod
    def load(cls, path: str) -> "AutotuneCache":
        """Load a cache file.  Never raises: a missing file is an empty
        cache, an unreadable/foreign-schema file is an empty cache with
        ``corrupt=True`` (the lookup ladder records the miss reason)."""
        try:
            with open(path, encoding="utf-8") as fh:
                doc = json.load(fh)
        except FileNotFoundError:
            return cls(path, {})
        except (OSError, UnicodeDecodeError, ValueError):
            return cls(path, {}, corrupt=True)
        if (
            not isinstance(doc, dict)
            or doc.get("schema") != SCHEMA_VERSION
            or not isinstance(doc.get("entries"), dict)
        ):
            return cls(path, {}, corrupt=True)
        return cls(path, doc["entries"])

    def put(
        self,
        *,
        D: int,
        M_bucket: int,
        state_rows: int,
        windowed: bool,
        chunked: bool,
        tile_m: int,
        best_us: float,
        candidates: dict[int, float],
        interpret: bool,
        device: Optional[tuple[str, str, str]] = None,
    ) -> str:
        """Store one sweep winner; returns its key."""
        dk, plat, backend = device or device_fingerprint()
        key = cache_key(
            dk, plat, backend, D, M_bucket, state_rows, windowed, chunked
        )
        self.entries[key] = {
            "device_kind": dk,
            "platform": plat,
            "backend": backend,
            "D": int(D),
            "M_bucket": int(M_bucket),
            "state_rows": int(state_rows),
            "windowed": bool(windowed),
            "chunked": bool(chunked),
            "tile_m": int(tile_m),
            "best_us": float(best_us),
            "candidates": {str(t): float(us) for t, us in candidates.items()},
            "interpret": bool(interpret),
        }
        return key

    def save(self) -> None:
        """Atomic write: serialize to a tmp file in the destination
        directory, then ``os.replace`` — a concurrent reader sees either
        the old document or the new one, never a torn write."""
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        doc = {"schema": SCHEMA_VERSION, "entries": self.entries}
        fd, tmp = tempfile.mkstemp(prefix=".dpp_autotune.", dir=d)
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as fh:
                json.dump(doc, fh, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


# one parsed cache per (path, mtime, size) — dispatch consults the
# ladder on every tiled decision, so lookups must not re-read the file
_LOAD_MEMO: dict[str, tuple[Optional[tuple[int, int]], AutotuneCache]] = {}


def _load_memoized(path: str) -> AutotuneCache:
    try:
        st = os.stat(path)
        stamp: Optional[tuple[int, int]] = (st.st_mtime_ns, st.st_size)
    except OSError:
        stamp = None
    hit = _LOAD_MEMO.get(path)
    if hit is not None and hit[0] == stamp:
        return hit[1]
    cache = AutotuneCache.load(path)
    _LOAD_MEMO[path] = (stamp, cache)
    return cache


# ---------------------------------------------------------------------------
# Lookup ladder (TilePolicy.decide's tile_m="auto" backend)
# ---------------------------------------------------------------------------


def _entry_tile(
    entry: object, D: int, state_rows: int, windowed: bool, chunked: bool,
    budget: int,
) -> Optional[int]:
    """The entry's tile iff it is a LANE multiple whose *model* working
    set fits the budget for the queried geometry — a stale or
    hand-edited entry degrades to a miss, never to an over-budget
    launch."""
    if not isinstance(entry, dict):
        return None
    tm = entry.get("tile_m")
    if not isinstance(tm, int) or isinstance(tm, bool):
        return None
    if tm < LANE or tm % LANE != 0 or tm > MAX_AUTO_TILE:
        return None
    if tile_vmem_bytes(D, tm, state_rows, windowed, chunked) > budget:
        return None
    return tm


def lookup_tile(
    *,
    D: int,
    M: int,
    state_rows: int,
    windowed: bool,
    chunked: bool,
    vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
    path: Optional[str] = None,
) -> Optional[int]:
    """Measured tile for this device/geometry, or ``None`` (fall back to
    the analytical model).  Exact bucket hit first, then the nearest
    measured bucket with an otherwise identical key; both rungs
    re-validate against the VMEM budget.  Never raises."""
    try:
        cache = _load_memoized(path or active_cache_path())
        if cache.corrupt:
            record_autotune_lookup("miss", reason="corrupt")
            return None
        if not cache.entries:
            record_autotune_lookup("miss", reason="empty")
            return None
        dk, plat, backend = device_fingerprint()
        mb = bucket_m(M)
        key = cache_key(
            dk, plat, backend, D, mb, state_rows, windowed, chunked
        )
        tm = _entry_tile(
            cache.entries.get(key), D, state_rows, windowed, chunked,
            vmem_budget_bytes,
        )
        if tm is not None:
            record_autotune_lookup("exact", tile_m=tm)
            return tm
        # nearest bucket: same device and (D, R, windowed, chunked),
        # different M_bucket, closest in log2(M) — a key recomputed from
        # the entry's own fields must reproduce the stored key, which
        # also screens out hand-edited field/key divergence
        best: Optional[tuple[float, int, int]] = None
        for k2, e2 in cache.entries.items():
            if not isinstance(e2, dict):
                continue
            mb2 = e2.get("M_bucket")
            if not isinstance(mb2, int) or mb2 < 1 or mb2 == mb:
                continue
            if k2 != cache_key(
                dk, plat, backend, D, mb2, state_rows, windowed, chunked
            ):
                continue
            t2 = _entry_tile(
                e2, D, state_rows, windowed, chunked, vmem_budget_bytes
            )
            if t2 is None:
                continue
            dist = abs(math.log2(mb2) - math.log2(mb))
            if best is None or (dist, mb2) < best[:2]:
                best = (dist, mb2, t2)
        if best is not None:
            record_autotune_lookup("bucket", tile_m=best[2])
            return best[2]
        record_autotune_lookup("miss", reason="no_entry")
        return None
    except Exception:
        record_autotune_lookup("miss", reason="error")
        return None


# ---------------------------------------------------------------------------
# Measurement sweep
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SweepCase:
    """One tuned geometry: a seam family at a concrete
    ``(D, M, state_rows[, chunk])``.  ``M`` is measured at its bucket,
    so candidate tiles (powers of two) always divide the padded axis
    and every candidate times identical work."""

    family: str
    D: int
    M: int
    state_rows: int
    chunk: int = 8

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(
                f"unknown family {self.family!r}; expected one of {FAMILIES}"
            )

    @property
    def windowed(self) -> bool:
        return self.family.endswith("windowed")

    @property
    def chunked(self) -> bool:
        return self.family.startswith("chunk")


def candidate_tiles(
    D: int,
    state_rows: int,
    windowed: bool,
    chunked: bool,
    M_bucket: int,
    *,
    vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
    limit: Optional[int] = None,
) -> list[int]:
    """Power-of-two LANE multiples up to the analytical prefilter
    (``auto_tile`` with the family's ``chunked=`` working set) and the
    bucket itself.  ``limit`` keeps only the widest N (smoke mode:
    wide tiles mean few grid steps, which is what keeps an
    interpret-mode sweep cheap)."""
    policy = TilePolicy(vmem_budget_bytes=vmem_budget_bytes)
    cap = min(
        policy.auto_tile(D, state_rows, windowed, chunked),
        M_bucket,
        MAX_AUTO_TILE,
    )
    tiles = []
    t = LANE
    while t <= cap:
        tiles.append(t)
        t <<= 1
    if limit is not None and limit > 0:
        tiles = tiles[-limit:]
    return tiles


def _case_inputs(case: SweepCase):
    """Deterministic measurement inputs at the case's bucketed M."""
    import jax.numpy as jnp
    import numpy as np

    Mb = bucket_m(case.M)
    rng = np.random.default_rng(0)
    F = rng.normal(size=(case.D, Mb)).astype(np.float32)
    F /= np.maximum(np.linalg.norm(F, axis=0, keepdims=True), 1e-12)
    rel = 1.0 + rng.uniform(size=Mb).astype(np.float32)
    return jnp.asarray(F * rel[None, :])[None]  # (1, D, Mb)


def _time_case(case: SweepCase, tile: int, trials: int,
               interpret: bool = True) -> float:
    """Best-of-``trials`` wall seconds for one real dispatch of the
    case's seam with an explicit ``TilePolicy(tile_m=tile)`` (the
    policy object bypasses the ``DPP_TILE_M`` env override, so a sweep
    can never be hijacked by the environment it is tuning for)."""
    import jax

    from repro.kernels.dpp_greedy.ops import (
        dpp_greedy,
        dpp_greedy_stream_chunk,
        dpp_greedy_stream_init,
        dpp_greedy_stream_pad,
    )

    V = _case_inputs(case)
    policy = TilePolicy(tile_m=tile)
    if case.chunked:
        window = case.state_rows if case.windowed else None
        k = 2 * case.state_rows if case.windowed else case.state_rows
        state = dpp_greedy_stream_init(
            V, k, window=window, tile_policy=policy
        )
        Vp = dpp_greedy_stream_pad(V, state)
        fn = lambda: dpp_greedy_stream_chunk(  # noqa: E731
            Vp, state, case.chunk, eps=1e-6, tile_policy=policy,
            interpret=interpret,
        )
    else:
        window = case.state_rows if case.windowed else None
        k = 2 * case.state_rows if case.windowed else case.state_rows
        fn = lambda: dpp_greedy(  # noqa: E731
            V, k, eps=1e-6, window=window, tile_policy=policy,
            interpret=interpret,
        )
    jax.block_until_ready(fn())  # compile + warm
    best = float("inf")
    for _ in range(max(trials, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run_sweep(
    cases: Sequence[SweepCase],
    *,
    trials: int = 2,
    limit: Optional[int] = None,
    path: Optional[str] = None,
    interpret: bool = True,
    vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
    log=None,
) -> tuple[list[dict], str]:
    """Measure every case, persist the winners (merging into whatever
    the cache file already holds), and return
    ``([{case, key, tile_m, best_us, candidates}, ...], path)``."""
    path = path or active_cache_path()
    cache = AutotuneCache.load(path)
    if cache.corrupt:
        # a broken file is replaced wholesale rather than merged into
        cache = AutotuneCache(path, {})
    device = device_fingerprint()
    results: list[dict] = []
    for case in cases:
        Mb = bucket_m(case.M)
        tiles = candidate_tiles(
            case.D, case.state_rows, case.windowed, case.chunked, Mb,
            vmem_budget_bytes=vmem_budget_bytes, limit=limit,
        )
        if not tiles:
            if log is not None:
                log(f"# skip {case.family} D={case.D} R={case.state_rows}: "
                    f"no in-budget candidate tile")
            continue
        cand: dict[int, float] = {}
        for t in tiles:
            cand[t] = _time_case(case, t, trials, interpret=interpret)
            if log is not None:
                log(f"#   {case.family} D={case.D} M={Mb} "
                    f"R={case.state_rows} tile={t}: {cand[t]*1e6:.0f}us")
        best_tile = min(cand, key=lambda t: (cand[t], t))
        key = cache.put(
            D=case.D, M_bucket=Mb, state_rows=case.state_rows,
            windowed=case.windowed, chunked=case.chunked,
            tile_m=best_tile, best_us=cand[best_tile] * 1e6,
            candidates=cand, interpret=interpret, device=device,
        )
        results.append({
            "case": case, "key": key, "tile_m": best_tile,
            "best_us": cand[best_tile] * 1e6,
            "candidates": {t: us * 1e6 for t, us in cand.items()},
        })
    cache.save()
    _LOAD_MEMO.pop(path, None)
    return results, path


def smoke_cases() -> list[SweepCase]:
    """One past-the-resident-budget geometry per seam family — sized so
    that a ``tile_m="auto"`` dispatch at these shapes actually consults
    the cache (``fig9_autotune --smoke`` evaluates exactly this grid)."""
    D, M = 64, 65536
    return [
        SweepCase("step_exact", D, M, state_rows=16),
        SweepCase("step_windowed", D, M, state_rows=8),
        SweepCase("chunk_exact", D, M, state_rows=16, chunk=8),
        SweepCase("chunk_windowed", D, M, state_rows=8, chunk=8),
    ]


def full_cases() -> list[SweepCase]:
    """The full sweep preset: every family over a (D, M-bucket, w,
    chunk_size) grid around the serving shapes."""
    cases = []
    for D in (32, 64, 128):
        for M in (65536, 131072):
            for R in (8, 16):
                cases.append(SweepCase("step_exact", D, M, state_rows=R))
                cases.append(SweepCase("step_windowed", D, M, state_rows=R))
                for chunk in (8, 16):
                    cases.append(SweepCase(
                        "chunk_exact", D, M, state_rows=R, chunk=chunk))
                    cases.append(SweepCase(
                        "chunk_windowed", D, M, state_rows=R, chunk=chunk))
    return cases


def main(argv: Optional[Iterable[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.kernels.autotune",
        description="Measure dpp_greedy kernel geometries and persist "
                    "the per-device winners for tile_m='auto'.",
    )
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep preset: one geometry per seam "
                         "family, widest 3 candidates, 1 trial (CI)")
    ap.add_argument("--full", action="store_true",
                    help="the full (D, M-bucket, w, chunk_size) grid")
    ap.add_argument("--out", default=None,
                    help="cache file (default: $DPP_AUTOTUNE_CACHE or "
                         "~/.cache/repro/dpp_autotune.json)")
    ap.add_argument("--trials", type=int, default=None,
                    help="timing trials per candidate (default 1 smoke, "
                         "3 full)")
    ap.add_argument("--compiled", action="store_true",
                    help="measure compiled pallas_call launches instead "
                         "of interpret mode (real TPU/GPU)")
    args = ap.parse_args(list(argv) if argv is not None else None)

    smoke = args.smoke or not args.full
    cases = smoke_cases() if smoke else full_cases()
    trials = args.trials if args.trials is not None else (1 if smoke else 3)
    limit = 3 if smoke else None

    print("name,us_per_call,derived")
    results, path = run_sweep(
        cases, trials=trials, limit=limit, path=args.out,
        interpret=not args.compiled, log=print,
    )
    for r in results:
        case = r["case"]
        cand = ";".join(f"{t}:{us:.0f}us"
                        for t, us in sorted(r["candidates"].items()))
        print(
            f"autotune_{case.family}_D{case.D}_M{bucket_m(case.M)}"
            f"_R{case.state_rows},{r['best_us']:.1f},"
            f"tile_m={r['tile_m']};candidates={cand}"
        )
    print(f"# wrote {len(results)} entr{'y' if len(results) == 1 else 'ies'}"
          f" -> {path}")
    return 0
