"""Tiled, double-buffered Pallas greedy kernels — past-the-VMEM-gate M.

The resident kernels in ``dpp_greedy.py`` hold ``V (D, M)`` and the
Cholesky state whole in VMEM, which caps M at the VMEM budget.  Here
each greedy step is one **grid sweep over M-tiles**: per grid step only
a ``(D, tile_m)`` block of ``V``, a ``(state_rows, tile_m)`` block of
``C`` and a ``(1, tile_m)`` block of ``d2`` are VMEM-resident, and the
Pallas BlockSpec pipeline double-buffers the HBM->VMEM / VMEM->HBM
copies of consecutive tiles while the current tile computes.

Per-step structure (the paper's eqs. 13/16-18 restructured for
streaming):

1. **streamed pass** (``_pass_full`` / ``_pass_windowed``): every tile
   applies the update for the *previously selected* winner ``j`` —
   ``e = (L_j - c_j^T C) / d_j`` on the MXU, ``d2 -= e^2``, the row
   append (and, windowed, the eviction Givens rotations) — and folds a
   running ``(d2_max, argmax)`` reduction into revisited ``(1, 1)``
   output cells, so the next winner is known when the sweep ends;
2. **winner-column visit**: only the winner's column is touched —
   ``V[:, j]`` and ``C[:, j]`` are gathered at the JAX level (an O(D)
   /O(state_rows) dynamic slice into HBM, not another sweep) and fed
   to the next step's pass as tiny replicated operands.

Everything data-dependent but small — the winner column, the windowed
eviction rotation coefficients (computed from the ``(w, w)`` window
factor ``C[:, win]``), the eps-stop flag — is resolved between sweeps
at the JAX level, so the kernels themselves stay shape-static.

The same pass kernels serve the candidate-sharded backend: each device
of ``repro.core.sharded`` runs the identical local update on its
``(D, M/P)`` shard (``tiled_update_exact`` / ``tiled_update_windowed``
with the shard's global column offset), so sharded M/P blocks scale
past the VMEM budget exactly like the single-device path.

Dispatch between resident and tiled kernels lives in ``ops.py`` via
``repro.kernels.dpp_greedy.tiling.TilePolicy``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


# ---------------------------------------------------------------------------
# Per-tile pass kernels
# ---------------------------------------------------------------------------


def _reduce_running_argmax(i, d2, mx_ref, am_ref, tile_m):
    """Fold this tile's (max, argmax) of ``d2 (1, tile_m)`` into the
    revisited (1, 1) output cells; ties keep the earlier (lower) index,
    matching ``jnp.argmax`` over the concatenated axis."""

    @pl.when(i == 0)
    def _():
        mx_ref[...] = jnp.full(mx_ref.shape, NEG_INF, jnp.float32)
        am_ref[...] = jnp.zeros(am_ref.shape, jnp.int32)

    lm = jnp.max(d2[0])
    la = jnp.argmax(d2[0]).astype(jnp.int32) + i * tile_m
    better = lm > mx_ref[0, 0]
    mx_ref[0, 0] = jnp.where(better, lm, mx_ref[0, 0])
    am_ref[0, 0] = jnp.where(better, la, am_ref[0, 0])


def _tile_update_full(V, C, d2, vj, cj, dj, stopped, j, base, i, tile_m):
    """The exact-step math for one (D, TM) tile, on plain values.

    Shared by the per-step kernel (:func:`_pass_full`, values from
    operands) and the fused multi-step chunk kernel
    (:func:`_chunk_pass_full`, values from VMEM-resident cells) so the
    two paths run the identical op sequence.  ``vj (1, D)`` /
    ``cj (1, R)`` are the winner's columns.  Returns ``(e, d2o)``.
    """
    lj = jnp.dot(vj, V, preferred_element_type=jnp.float32)
    dots = jnp.dot(cj, C, preferred_element_type=jnp.float32)
    e = (lj - dots) / dj
    e = jnp.where(stopped, jnp.zeros_like(e), e)
    gid = jax.lax.broadcasted_iota(jnp.int32, (1, tile_m), 1) + i * tile_m + base
    d2_next = jnp.where(gid == j, NEG_INF, d2 - e * e)
    d2o = jnp.where(stopped, d2, d2_next)
    return e, d2o


def _tile_update_windowed(
    V, C, d2, vj, cj_post, djp, stopped, full, coss, sins, j, base, pos,
    i, w, tile_m,
):
    """The windowed-step math (evict + append fused) for one tile, on
    plain values — shared by :func:`_pass_windowed` and
    :func:`_chunk_pass_windowed`.  ``coss``/``sins`` are length-(w-1)
    sequences of scalar Givens coefficients.  Returns
    ``(C_out, d2o, e)`` with ``C_out`` already holding the
    stopped-passthrough."""
    # ---- evict the oldest pick: first-row Cholesky downdate; the
    # rotation residue u repairs d2 (see repro.core.windowed)
    u = jnp.where(full, C[0:1, :], jnp.zeros((1, tile_m), jnp.float32))
    rows = []
    for r in range(w - 1):
        cos = coss[r]
        sin = sins[r]
        row = jnp.where(full, C[r + 1 : r + 2, :], C[r : r + 1, :])
        rows.append(cos * row + sin * u)
        u = cos * u - sin * row
    last = jnp.where(full, jnp.zeros((1, tile_m), jnp.float32), C[w - 1 : w, :])
    Cpost = jnp.concatenate(rows + [last], axis=0) if w > 1 else last
    d2e = jnp.where(full, d2 + u * u, d2)

    # ---- append j against the post-eviction window (eqs. 16-18)
    lj = jnp.dot(vj, V, preferred_element_type=jnp.float32)
    dots = jnp.dot(cj_post, Cpost, preferred_element_type=jnp.float32)
    e = (lj - dots) / djp
    ridx = jax.lax.broadcasted_iota(jnp.int32, (w, 1), 0)
    Cnew = jnp.where(ridx == pos, e, Cpost)
    C_out = jnp.where(stopped, C, Cnew)

    gid = jax.lax.broadcasted_iota(jnp.int32, (1, tile_m), 1) + i * tile_m + base
    d2_next = jnp.where(gid == j, NEG_INF, d2e - e * e)
    d2o = jnp.where(stopped, d2, d2_next)
    return C_out, d2o, e


def _pass_full(
    v_ref, c_ref, d2_ref, vj_ref, cj_ref, flt_ref, int_ref,
    e_ref, d2o_ref, mx_ref, am_ref, *, tile_m: int,
):
    """One M-tile of one exact-Algorithm-1 greedy step.

    v_ref:  (D, TM) f32 — tile of the scaled features, L = V^T V
    c_ref:  (R, TM) f32 — tile of the Cholesky rows (rows >= t are 0)
    d2_ref: (1, TM) f32 — tile of the marginal gains
    vj_ref: (1, D), cj_ref: (1, R) — the winner's columns (replicated)
    flt_ref:(1, 2) f32 — [d_j, stopped]
    int_ref:(1, 2) i32 — [j (global id), base (global id of column 0)]
    e_ref:  (1, TM) out — the appended Cholesky row (eqs. 16-18)
    d2o_ref:(1, TM) out — updated gains
    mx/am:  (1, 1) out — running (d2_max, argmax), revisited across tiles
    """
    i = pl.program_id(1)
    dj = flt_ref[0, 0]
    stopped = flt_ref[0, 1] > 0
    j = int_ref[0, 0]
    base = int_ref[0, 1]

    e, d2o = _tile_update_full(
        v_ref[...], c_ref[...], d2_ref[...], vj_ref[...], cj_ref[...],
        dj, stopped, j, base, i, tile_m,
    )
    e_ref[...] = e
    d2o_ref[...] = d2o
    _reduce_running_argmax(i, d2o, mx_ref, am_ref, tile_m)


def _pass_windowed(
    v_ref, c_ref, d2_ref, vj_ref, cj_ref, flt_ref, int_ref,
    co_ref, d2o_ref, mx_ref, am_ref, *, w: int, tile_m: int,
):
    """One M-tile of one sliding-window greedy step: eviction (Givens
    rotations with precomputed coefficients) fused with the append.

    c_ref:  (w, TM) — tile of the window Cholesky ring (window order)
    cj_ref: (1, w)  — the winner's POST-eviction column (replicated)
    flt_ref:(1, 3 + 2(w-1)) f32 — [d_j', stopped, full,
            cos_0..cos_{w-2}, sin_0..sin_{w-2}]; identity rotations
            (cos=1, sin=0) are passed when the window is not yet full
    int_ref:(1, 3) i32 — [j, base, pos (ring row receiving the append)]
    co_ref: (w, TM) out — post-eviction, post-append ring tile
    """
    i = pl.program_id(1)
    djp = flt_ref[0, 0]
    stopped = flt_ref[0, 1] > 0
    full = flt_ref[0, 2] > 0
    j = int_ref[0, 0]
    base = int_ref[0, 1]
    pos = int_ref[0, 2]
    coss = [flt_ref[0, 3 + r] for r in range(w - 1)]
    sins = [flt_ref[0, 3 + (w - 1) + r] for r in range(w - 1)]

    C_out, d2o, _ = _tile_update_windowed(
        v_ref[...], c_ref[...], d2_ref[...], vj_ref[...], cj_ref[...],
        djp, stopped, full, coss, sins, j, base, pos, i, w, tile_m,
    )
    co_ref[...] = C_out
    d2o_ref[...] = d2o
    _reduce_running_argmax(i, d2o, mx_ref, am_ref, tile_m)


# ---------------------------------------------------------------------------
# pallas_call wrappers (one grid sweep = one greedy step)
# ---------------------------------------------------------------------------


def _tile_spec(rows, tile_m):
    return pl.BlockSpec((None, rows, tile_m), lambda b, i: (b, 0, i))


def _small_spec(cols):
    return pl.BlockSpec((None, 1, cols), lambda b, i: (b, 0, 0))


def _sweep(kernel, row_out, V, C, d2, vj, cj, flt, ints, tile_m, interpret):
    """Run one per-step grid sweep.  ``row_out`` is the row count of the
    first (streamed) output: 1 for the exact append row, w for the
    windowed post-eviction ring."""
    B, D, Mp = V.shape
    R = C.shape[1]
    nt = Mp // tile_m
    return pl.pallas_call(
        kernel,
        grid=(B, nt),
        in_specs=[
            _tile_spec(D, tile_m),
            _tile_spec(R, tile_m),
            _tile_spec(1, tile_m),
            _small_spec(D),
            _small_spec(R),
            _small_spec(flt.shape[-1]),
            _small_spec(ints.shape[-1]),
        ],
        out_specs=[
            _tile_spec(row_out, tile_m),
            _tile_spec(1, tile_m),
            _small_spec(1),
            _small_spec(1),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, row_out, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(V, C, d2, vj, cj, flt, ints)


def _full_sweep(V, C, d2, vj, cj, flt, ints, *, tile_m, interpret):
    kernel = functools.partial(_pass_full, tile_m=tile_m)
    return _sweep(kernel, 1, V, C, d2, vj, cj, flt, ints, tile_m, interpret)


def _windowed_sweep(V, C, d2, vj, cj, flt, ints, *, w, tile_m, interpret):
    kernel = functools.partial(_pass_windowed, w=w, tile_m=tile_m)
    return _sweep(kernel, w, V, C, d2, vj, cj, flt, ints, tile_m, interpret)


# ---------------------------------------------------------------------------
# Windowed eviction coefficients (shared with repro.core.sharded)
# ---------------------------------------------------------------------------


def eviction_coeffs(Cw, cj, dj2, full, w: int):
    """Precompute the first-row Cholesky-downdate rotations from the
    small replicated state, so a streamed sweep can apply them per tile.

    Cw:   (..., w, w) — the window factor C[:, win] (column s = window
          member s's Cholesky column); junk columns (win slot empty)
          must be zeroed by the caller.
    cj:   (..., w) — the winner's PRE-eviction Cholesky column.
    dj2:  (...,)   — the winner's selection-time marginal gain d_j^2.
    full: (...,) bool — eviction actually happens this step.

    Returns ``(cos (..., w-1), sin (..., w-1), cj_post (..., w),
    d2j (...,))`` — identity rotations, ``cj_post = cj`` and
    ``d2j = dj2`` wherever ``full`` is False.  Applying (cos, sin) to
    any column reproduces bit-for-bit what the in-place rotation sweep
    of ``repro.core.windowed`` / ``core.sharded`` computes, because the
    sweep only ever reads not-yet-rotated rows (row r+1 at iteration r).
    """
    tiny = 1e-30
    fullb = full[..., None]
    u_w = jnp.where(fullb, Cw[..., 0, :], 0.0)
    u_c = jnp.where(full, cj[..., 0], 0.0)
    coss, sins, cpost = [], [], []
    for r in range(w - 1):
        row_w = jnp.where(fullb, Cw[..., r + 1, :], Cw[..., r, :])
        row_c = jnp.where(full, cj[..., r + 1], cj[..., r])
        a = row_w[..., r + 1]
        b = u_w[..., r + 1]
        rho = jnp.maximum(jnp.sqrt(a * a + b * b), tiny)
        cos = jnp.where(full, a / rho, 1.0)
        sin = jnp.where(full, b / rho, 0.0)
        coss.append(cos)
        sins.append(sin)
        cpost.append(cos * row_c + sin * u_c)
        u_c = cos * u_c - sin * row_c
        u_w = cos[..., None] * u_w - sin[..., None] * row_w
    cpost.append(jnp.where(full, jnp.zeros_like(u_c), cj[..., w - 1]))
    shape = full.shape + (w - 1,)
    cos_arr = jnp.stack(coss, -1) if coss else jnp.zeros(shape, jnp.float32)
    sin_arr = jnp.stack(sins, -1) if sins else jnp.zeros(shape, jnp.float32)
    cj_post = jnp.stack(cpost, -1)
    d2j = jnp.where(full, dj2 + u_c * u_c, dj2)
    return cos_arr, sin_arr, cj_post, d2j


# ---------------------------------------------------------------------------
# Shard-local single-step updates (reused by repro.core.sharded)
# ---------------------------------------------------------------------------


def tiled_update_exact(
    Vl, C, d2, vj, cj, dj, stopped, j, base, *, tile_m: int, interpret: bool = True
):
    """One exact greedy step's local update on a column shard.

    Vl (D, Mloc) / C (k, Mloc) / d2 (Mloc,); vj (D,) / cj (k,) the
    winner's replicated columns; ``j`` the winner's *global* id and
    ``base`` this shard's global offset (0 on a single device).
    Returns ``(e (Mloc,), d2 (Mloc,))`` — the caller appends ``e`` as
    Cholesky row ``t``.  ``Mloc`` must be a multiple of ``tile_m``.
    """
    flt = jnp.stack([dj, stopped.astype(jnp.float32)])[None, None, :]
    ints = jnp.stack([j, base]).astype(jnp.int32)[None, None, :]
    e, d2o, _, _ = _full_sweep(
        Vl[None], C[None], d2[None, None, :], vj[None, None, :],
        cj[None, None, :], flt, ints, tile_m=tile_m, interpret=interpret,
    )
    return e[0, 0], d2o[0, 0]


def tiled_update_windowed(
    Vl, C, d2, vj, cj_post, djp, stopped, full, cos, sin, j, base, pos,
    *, w: int, tile_m: int, interpret: bool = True,
):
    """One windowed greedy step's local update (evict + append fused) on
    a column shard; coefficients from :func:`eviction_coeffs`.
    Returns ``(C (w, Mloc), d2 (Mloc,))``."""
    flt = jnp.concatenate(
        [
            jnp.stack([djp, stopped.astype(jnp.float32),
                       full.astype(jnp.float32)]),
            cos, sin,
        ]
    )[None, None, :]
    ints = jnp.stack([j, base, pos]).astype(jnp.int32)[None, None, :]
    Co, d2o, _, _ = _windowed_sweep(
        Vl[None], C[None], d2[None, None, :], vj[None, None, :],
        cj_post[None, None, :], flt, ints, w=w, tile_m=tile_m,
        interpret=interpret,
    )
    return Co[0], d2o[0, 0]


# ---------------------------------------------------------------------------
# Fused multi-step chunk kernels (streaming emission / HBM amortization)
#
# One pallas_call advances ``chunk`` greedy steps: grid (B, chunk, nt),
# step-major, tile-minor.  The Cholesky state and d2 live in *output*
# blocks that sweep s+1 reads back (revisited block index maps ignore
# the step dimension), so C and d2 cross the kernel boundary — one HBM
# round-trip — once per chunk instead of once per step.  Everything the
# next step needs from the previous one (the running argmax, the
# winner's V / Cholesky columns and, windowed, the (w, w) window factor
# and ring ids) is carried in constant-index (1, ·) cells that stay
# VMEM-resident across the whole grid: the per-step JAX-level winner
# gather / row write-back of the per-step path disappears entirely.
#
# Caveat (mirrors the ROADMAP's compiled-mode item): CI exercises
# interpret mode, where revisited output blocks read back the bits the
# previous sweep wrote.  A compiled TPU lowering must preserve that
# read-back (non-consecutive revisits re-fetch from HBM) — on-hardware
# validation of exactly this contract is tracked in the ROADMAP.
# ---------------------------------------------------------------------------


def _reduce_argmax_and_cols(i, d2, V, C, mx_ref, am_ref, wv_ref, wc_ref,
                            tile_m):
    """The running (max, argmax) fold of :func:`_reduce_running_argmax`
    extended to also capture the running winner's columns — its
    ``V[:, j]`` as a (1, D) row in ``wv_ref`` and its post-update
    Cholesky column as a (1, R) row in ``wc_ref`` — so the next sweep
    starts with the winner's columns already VMEM-resident."""

    @pl.when(i == 0)
    def _():
        mx_ref[...] = jnp.full(mx_ref.shape, NEG_INF, jnp.float32)
        am_ref[...] = jnp.zeros(am_ref.shape, jnp.int32)

    lm = jnp.max(d2[0])
    jl = jnp.argmax(d2[0]).astype(jnp.int32)
    la = jl + i * tile_m
    better = lm > mx_ref[0, 0]
    mx_ref[0, 0] = jnp.where(better, lm, mx_ref[0, 0])
    am_ref[0, 0] = jnp.where(better, la, am_ref[0, 0])
    D, R = V.shape[0], C.shape[0]
    vcol = jax.lax.dynamic_slice(V, (0, jl), (D, 1)).reshape(1, D)
    ccol = jax.lax.dynamic_slice(C, (0, jl), (R, 1)).reshape(1, R)
    wv_ref[...] = jnp.where(better, vcol, wv_ref[...])
    wc_ref[...] = jnp.where(better, ccol, wc_ref[...])


def _chunk_pass_full(
    v_ref, cin_ref, d2in_ref, f0_ref, i0_ref, vj0_ref, cj0_ref,
    cout_ref, d2out_ref, sel_ref, dh_ref,
    stepf_ref, stepi_ref, wvc_ref, wcc_ref,
    mxn_ref, amn_ref, wvn_ref, wcn_ref,
    *, eps: float, tile_m: int,
):
    """One (step, tile) grid cell of the fused exact chunk.

    Inputs: V tile (D, TM); C/d2 state tiles (read at sweep 0 only —
    later sweeps read the revisited output blocks); f0 (1, 2) f32
    [dj2_0, stopped_0], i0 (1, 2) i32 [j_0, t0] and the winner's
    columns vj0 (1, D) / cj0 (1, R), all computed at the JAX level once
    per chunk from the resumable state.

    Cells: stepf (1, 2) [d_j, stopped] and stepi (1, 2) [j, t0] hold
    the *current* step's scalars (written by tile 0, read by every
    tile); wvc/wcc the current winner's columns; mxn/amn/wvn/wcn the
    running argmax + columns feeding the *next* sweep.
    """
    s = pl.program_id(1)
    i = pl.program_id(2)
    eps2 = eps * eps
    first = s == 0

    @pl.when(i == 0)
    def _setup():
        dj2 = jnp.where(first, f0_ref[0, 0], mxn_ref[0, 0])
        prev_stop = jnp.where(first, f0_ref[0, 1] > 0, stepf_ref[0, 1] > 0)
        j = jnp.where(first, i0_ref[0, 0], amn_ref[0, 0])
        t0 = i0_ref[0, 1]
        stopped = jnp.logical_or(prev_stop, dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))
        stepf_ref[...] = jnp.stack([dj, stopped.astype(jnp.float32)])[None]
        stepi_ref[...] = jnp.stack([j, t0]).astype(jnp.int32)[None]
        wvc_ref[...] = jnp.where(first, vj0_ref[...], wvn_ref[...])
        wcc_ref[...] = jnp.where(first, cj0_ref[...], wcn_ref[...])
        sel_val = jnp.where(stopped, -1, j).astype(jnp.int32)
        pl.store(sel_ref, (pl.dslice(0, 1), pl.dslice(s, 1)),
                 sel_val[None, None])
        d_val = jnp.where(stopped, 0.0, dj).astype(jnp.float32)
        pl.store(dh_ref, (pl.dslice(0, 1), pl.dslice(s, 1)),
                 d_val[None, None])

    dj = stepf_ref[0, 0]
    stopped = stepf_ref[0, 1] > 0
    j = stepi_ref[0, 0]
    t = stepi_ref[0, 1] + s
    C = jnp.where(first, cin_ref[...], cout_ref[...])
    d2 = jnp.where(first, d2in_ref[...], d2out_ref[...])
    e, d2o = _tile_update_full(
        v_ref[...], C, d2, wvc_ref[...], wcc_ref[...],
        dj, stopped, j, 0, i, tile_m,
    )
    # append the new Cholesky row in place (row t; zeros once stopped,
    # exactly as the per-step driver's dynamic_update_slice writes)
    ridx = jax.lax.broadcasted_iota(jnp.int32, (C.shape[0], 1), 0)
    Cnew = jnp.where(ridx == t, e, C)
    cout_ref[...] = Cnew
    d2out_ref[...] = d2o
    _reduce_argmax_and_cols(
        i, d2o, v_ref[...], Cnew, mxn_ref, amn_ref, wvn_ref, wcn_ref, tile_m
    )


def _chunk_pass_windowed(
    v_ref, cin_ref, d2in_ref, f0_ref, i0_ref, vj0_ref, cj0_ref,
    cw0_ref, win0_ref,
    cout_ref, d2out_ref, sel_ref, dh_ref,
    stepf_ref, stepi_ref, wvc_ref, wcp_ref, cwc_ref, wring_ref,
    mxn_ref, amn_ref, wvn_ref, wcn_ref,
    *, eps: float, w: int, tile_m: int,
):
    """One (step, tile) grid cell of the fused sliding-window chunk.

    Beyond the exact variant, two more resident cells track the window
    through the chunk: ``cwc (w, w)`` — the window factor ``C[:, win]``
    (maintained by applying the same eviction rotations the tiles apply
    to their columns, its appended row filled in by whichever tile owns
    each window member) — and ``wring (1, w)`` — the ring ids.  Tile 0
    derives the step's eviction rotations from these cells with
    :func:`eviction_coeffs` (the identical recurrence the per-step JAX
    driver uses), so no JAX-level gather happens inside a chunk.
    """
    s = pl.program_id(1)
    i = pl.program_id(2)
    eps2 = eps * eps
    first = s == 0

    @pl.when(i == 0)
    def _setup():
        dj2 = jnp.where(first, f0_ref[0, 0], mxn_ref[0, 0])
        prev_stop = jnp.where(first, f0_ref[0, 1] > 0, stepf_ref[0, 1] > 0)
        j = jnp.where(first, i0_ref[0, 0], amn_ref[0, 0])
        t0 = i0_ref[0, 1]
        t = t0 + s
        stopped = jnp.logical_or(prev_stop, dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))
        full = jnp.logical_and(t >= w, jnp.logical_not(stopped))
        cj_pre = jnp.where(first, cj0_ref[...], wcn_ref[...])[0]  # (w,)
        Cw = jnp.where(first, cw0_ref[...], cwc_ref[...])  # (w, w)
        W = jnp.where(first, win0_ref[...], wring_ref[...])  # (1, w) i32
        cos_arr, sin_arr, cj_post, d2j = eviction_coeffs(
            Cw, cj_pre, dj2, full, w
        )
        djp = jnp.sqrt(jnp.maximum(d2j, eps2))
        pos = jnp.minimum(t, w - 1)
        stepf_ref[...] = jnp.concatenate(
            [
                jnp.stack([djp, stopped.astype(jnp.float32),
                           full.astype(jnp.float32)]),
                cos_arr, sin_arr,
            ]
        )[None]
        stepi_ref[...] = jnp.stack([j, pos, t0]).astype(jnp.int32)[None]
        wvc_ref[...] = jnp.where(first, vj0_ref[...], wvn_ref[...])
        wcp_ref[...] = cj_post[None]

        # maintain the (w, w) window factor through evict + append:
        # rotate its rows with the step's coefficients (the same
        # recurrence the tiles apply to their columns) ...
        u_w = jnp.where(full, Cw[0, :], jnp.zeros((w,), jnp.float32))
        rows = []
        for r in range(w - 1):
            row = jnp.where(full, Cw[r + 1, :], Cw[r, :])
            rows.append(cos_arr[r] * row + sin_arr[r] * u_w)
            u_w = cos_arr[r] * u_w - sin_arr[r] * row
        last = jnp.where(full, jnp.zeros((w,), jnp.float32), Cw[w - 1, :])
        rotated = jnp.stack(rows + [last], axis=0)  # (w, w)
        # ... shift out the evicted member's column / enter the winner's
        colidx = jax.lax.broadcasted_iota(jnp.int32, (1, w), 1)
        if w > 1:
            shifted = jnp.concatenate(
                [rotated[:, 1:], cj_post[:, None]], axis=1
            )
        else:
            shifted = cj_post[:, None]
        not_full = jnp.where(colidx == pos, cj_post[:, None], rotated)
        Cw_new = jnp.where(full, shifted, not_full)
        # row pos is the appended e-row: zero it here, the owning tiles
        # fill in e[win_r] for their members during the sweep
        ridxw = jax.lax.broadcasted_iota(jnp.int32, (w, 1), 0)
        Cw_new = jnp.where(ridxw == pos, 0.0, Cw_new)
        cwc_ref[...] = jnp.where(stopped, Cw, Cw_new)

        W_shift = jnp.roll(W, -1, axis=1)
        W1 = jnp.where(full, jnp.where(colidx == w - 1, -1, W_shift), W)
        W_new = jnp.where(stopped, W, jnp.where(colidx == pos, j, W1))
        wring_ref[...] = W_new

        sel_val = jnp.where(stopped, -1, j).astype(jnp.int32)
        pl.store(sel_ref, (pl.dslice(0, 1), pl.dslice(s, 1)),
                 sel_val[None, None])
        d_val = jnp.where(stopped, 0.0, dj).astype(jnp.float32)
        pl.store(dh_ref, (pl.dslice(0, 1), pl.dslice(s, 1)),
                 d_val[None, None])

    djp = stepf_ref[0, 0]
    stopped = stepf_ref[0, 1] > 0
    full = stepf_ref[0, 2] > 0
    coss = [stepf_ref[0, 3 + r] for r in range(w - 1)]
    sins = [stepf_ref[0, 3 + (w - 1) + r] for r in range(w - 1)]
    j = stepi_ref[0, 0]
    pos = stepi_ref[0, 1]
    C = jnp.where(first, cin_ref[...], cout_ref[...])
    d2 = jnp.where(first, d2in_ref[...], d2out_ref[...])
    C_out, d2o, e = _tile_update_windowed(
        v_ref[...], C, d2, wvc_ref[...], wcp_ref[...], djp, stopped, full,
        coss, sins, j, 0, pos, i, w, tile_m,
    )
    cout_ref[...] = C_out
    d2out_ref[...] = d2o

    # fill the appended window-factor row: e[win_r] for the members this
    # tile owns (each global id lives in exactly one tile)
    W_new = wring_ref[...]
    for r in range(w):
        idx = W_new[0, r]
        loc = idx - i * tile_m
        owned = (idx >= 0) & (loc >= 0) & (loc < tile_m) & jnp.logical_not(
            stopped
        )
        val = jax.lax.dynamic_slice(
            e, (0, jnp.clip(loc, 0, tile_m - 1)), (1, 1)
        )[0, 0]
        cur = pl.load(cwc_ref, (pl.dslice(pos, 1), pl.dslice(r, 1)))
        pl.store(
            cwc_ref, (pl.dslice(pos, 1), pl.dslice(r, 1)),
            jnp.where(owned, val, cur[0, 0])[None, None],
        )

    _reduce_argmax_and_cols(
        i, d2o, v_ref[...], C_out, mxn_ref, amn_ref, wvn_ref, wcn_ref, tile_m
    )


def _ctile_spec(rows, tile_m):
    return pl.BlockSpec((None, rows, tile_m), lambda b, s, i: (b, 0, i))


def _ccell_spec(rows, cols):
    return pl.BlockSpec((None, rows, cols), lambda b, s, i: (b, 0, 0))


def _require_interpret_for_multitile(interpret: bool, nt: int) -> None:
    """The fused chunk kernels carry C/d2 across greedy steps in
    *revisited output blocks*: tile block ``i`` is written at grid step
    ``(b, s, i)`` and read again at ``(b, s+1, i)`` with the ``nt - 1``
    other tiles visited in between.  Pallas interpret mode keeps every
    output block live for the whole grid, so the pattern is exact there;
    compiled Mosaic only guarantees a revisited block's contents when
    the revisits are *consecutive* grid steps, which holds only for
    ``nt == 1``.  Until the multi-tile schedule is validated on real
    hardware (ROADMAP: compiled-mode fused chunks), compiling it is an
    error rather than silent wrong slates.  ``repro.analysis``'s
    pallas-revisit-gap rule probes this guard."""
    if not interpret and nt > 1:
        raise NotImplementedError(
            f"fused chunk kernels compile only with a single whole-M tile "
            f"(nt={nt} tiles requested): cross-step state lives in output "
            f"blocks revisited non-consecutively, which compiled Mosaic "
            f"does not guarantee — use interpret=True, widen tile_m to "
            f"cover M, or step with the per-step tiled kernels"
        )


def _fused_chunk_call(kernel, *, grid, in_specs, out_specs, out_shape,
                      interpret, ins):
    """The single ``pallas_call`` a fused chunk makes.  Kept as a named
    seam so tests can count invocations: one call — one C/d2 HBM
    round-trip — per chunk, however many steps the chunk spans."""
    return pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret,
    )(*ins)


def pallas_call_structure(jaxpr, in_loop=False, counts=None):
    """Audit a (closed) jaxpr for kernel-launch structure:
    ``{"flat": n, "looped": n}`` pallas_call eqns, split by whether they
    sit under a loop primitive (while/scan).  A looped launch runs once
    per iteration — per greedy step; a flat one exactly once — per
    chunk.  The fused chunk executors above must trace to exactly one
    flat launch and none looped (asserted by tests/test_streaming.py
    and gated by benchmarks/fig6_streaming.py)."""
    if counts is None:
        counts = {"flat": 0, "looped": 0}
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in jaxpr.eqns:
        loop = in_loop or eqn.primitive.name in ("while", "scan")
        if eqn.primitive.name == "pallas_call":
            counts["looped" if loop else "flat"] += 1
        for v in eqn.params.values():
            for sub in jax.tree_util.tree_leaves(
                v, is_leaf=lambda x: hasattr(x, "eqns")
                or hasattr(x, "jaxpr")
            ):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    pallas_call_structure(sub, loop, counts)
    return counts


@functools.partial(
    jax.jit, static_argnames=("chunk", "eps", "tile_m", "interpret")
)
def fused_chunk_exact(V, C, d2, t0, stopped, *, chunk: int, eps: float,
                      tile_m: int, interpret: bool = True):
    """Advance ``chunk`` exact greedy steps in one fused pallas_call.

    V (B, D, Mp) / C (B, R, Mp) / d2 (B, Mp) / stopped (B,), ``t0`` the
    absolute step of the chunk's first selection.  Returns
    ``(C', d2', stopped', sel (B, chunk), dh (B, chunk))``.
    """
    B, D, Mp = V.shape
    R = C.shape[1]
    nt = Mp // tile_m
    _require_interpret_for_multitile(interpret, nt)
    j0 = jnp.argmax(d2, axis=1).astype(jnp.int32)
    dj20 = jnp.take_along_axis(d2, j0[:, None], axis=1)[:, 0]
    vj0 = jnp.take_along_axis(V, j0[:, None, None], axis=2)[:, :, 0][:, None, :]
    cj0 = jnp.take_along_axis(C, j0[:, None, None], axis=2)[:, :, 0][:, None, :]
    f0 = jnp.stack([dj20, stopped.astype(jnp.float32)], axis=1)[:, None, :]
    t0b = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (B,))
    i0 = jnp.stack([j0, t0b], axis=1)[:, None, :]
    kernel = functools.partial(_chunk_pass_full, eps=eps, tile_m=tile_m)
    outs = _fused_chunk_call(
        kernel,
        grid=(B, chunk, nt),
        in_specs=[
            _ctile_spec(D, tile_m), _ctile_spec(R, tile_m),
            _ctile_spec(1, tile_m),
            _ccell_spec(1, 2), _ccell_spec(1, 2),
            _ccell_spec(1, D), _ccell_spec(1, R),
        ],
        out_specs=[
            _ctile_spec(R, tile_m), _ctile_spec(1, tile_m),
            _ccell_spec(1, chunk), _ccell_spec(1, chunk),
            _ccell_spec(1, 2), _ccell_spec(1, 2),
            _ccell_spec(1, D), _ccell_spec(1, R),
            _ccell_spec(1, 1), _ccell_spec(1, 1),
            _ccell_spec(1, D), _ccell_spec(1, R),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, R, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, chunk), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, chunk), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 2), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 2), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, R), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, R), jnp.float32),
        ],
        interpret=interpret,
        ins=(V, C, d2[:, None, :], f0, i0, vj0, cj0),
    )
    cout, d2out, sel, dh, stepf = outs[:5]
    stopped_out = stepf[:, 0, 1] > 0
    return cout, d2out[:, 0], stopped_out, sel[:, 0], dh[:, 0]


@functools.partial(
    jax.jit, static_argnames=("chunk", "eps", "w", "tile_m", "interpret")
)
def fused_chunk_windowed(V, C, d2, win, t0, stopped, *, chunk: int,
                         eps: float, w: int, tile_m: int,
                         interpret: bool = True):
    """Advance ``chunk`` sliding-window greedy steps in one fused
    pallas_call.  ``C (B, w, Mp)`` is the window ring, ``win (B, w)``
    the ring ids (oldest first).  Returns
    ``(C', d2', win', stopped', sel (B, chunk), dh (B, chunk))``.
    """
    B, D, Mp = V.shape
    nt = Mp // tile_m
    _require_interpret_for_multitile(interpret, nt)
    j0 = jnp.argmax(d2, axis=1).astype(jnp.int32)
    dj20 = jnp.take_along_axis(d2, j0[:, None], axis=1)[:, 0]
    vj0 = jnp.take_along_axis(V, j0[:, None, None], axis=2)[:, :, 0][:, None, :]
    cj0 = jnp.take_along_axis(C, j0[:, None, None], axis=2)[:, :, 0][:, None, :]
    Cw0 = jnp.take_along_axis(C, jnp.clip(win, 0)[:, None, :], axis=2)
    Cw0 = jnp.where((win >= 0)[:, None, :], Cw0, 0.0)  # (B, w, w)
    win0 = win[:, None, :]
    f0 = jnp.stack([dj20, stopped.astype(jnp.float32)], axis=1)[:, None, :]
    t0b = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (B,))
    i0 = jnp.stack([j0, t0b], axis=1)[:, None, :]
    nf = 3 + 2 * (w - 1)
    kernel = functools.partial(
        _chunk_pass_windowed, eps=eps, w=w, tile_m=tile_m
    )
    outs = _fused_chunk_call(
        kernel,
        grid=(B, chunk, nt),
        in_specs=[
            _ctile_spec(D, tile_m), _ctile_spec(w, tile_m),
            _ctile_spec(1, tile_m),
            _ccell_spec(1, 2), _ccell_spec(1, 2),
            _ccell_spec(1, D), _ccell_spec(1, w),
            _ccell_spec(w, w), _ccell_spec(1, w),
        ],
        out_specs=[
            _ctile_spec(w, tile_m), _ctile_spec(1, tile_m),
            _ccell_spec(1, chunk), _ccell_spec(1, chunk),
            _ccell_spec(1, nf), _ccell_spec(1, 3),
            _ccell_spec(1, D), _ccell_spec(1, w),
            _ccell_spec(w, w), _ccell_spec(1, w),
            _ccell_spec(1, 1), _ccell_spec(1, 1),
            _ccell_spec(1, D), _ccell_spec(1, w),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, w, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, chunk), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, chunk), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, nf), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 3), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, w), jnp.float32),
            jax.ShapeDtypeStruct((B, w, w), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, w), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, 1, D), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, w), jnp.float32),
        ],
        interpret=interpret,
        ins=(V, C, d2[:, None, :], f0, i0, vj0, cj0, Cw0, win0),
    )
    cout, d2out, sel, dh, stepf = outs[:5]
    wring = outs[9]
    stopped_out = stepf[:, 0, 1] > 0
    return cout, d2out[:, 0], wring[:, 0], stopped_out, sel[:, 0], dh[:, 0]


# ---------------------------------------------------------------------------
# Whole-slate driver
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("k", "window", "eps", "tile_m", "interpret")
)
def dpp_greedy_tiled(
    V: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    window: int | None = None,
    eps: float = 1e-3,
    tile_m: int = 512,
    interpret: bool = True,
):
    """Batched greedy DPP MAP with the candidate axis streamed in tiles.

    V:    (B, D, M) f32, M a multiple of ``tile_m`` (ops.py pads)
    mask: (B, M) float/bool — selectable candidates (padding False)
    Returns (sel (B, k) i32, d_hist (B, k) f32), identical to the
    resident kernels / the jnp oracle.

    The k-step loop runs at the JAX level; each step launches one grid
    sweep (see module docstring).  Unlike the resident kernels the
    Cholesky state round-trips through HBM between steps — that is the
    price of M not fitting in VMEM, and it is streamed, double-buffered
    traffic, not a fallback to unfused jnp.
    """
    B, D, M = V.shape
    if M % tile_m != 0:
        raise ValueError(f"M={M} must be a multiple of tile_m={tile_m}")
    V = V.astype(jnp.float32)
    w = window if (window is not None and window < k) else None
    R = k if w is None else w
    eps2 = eps * eps

    diag = jnp.sum(V * V, axis=1)  # (B, M)
    d2 = jnp.where(mask > 0, diag, NEG_INF)[:, None, :]  # (B, 1, M)
    C = jnp.zeros((B, R, M), jnp.float32)
    sel = jnp.full((B, k), -1, jnp.int32)
    dh = jnp.zeros((B, k), jnp.float32)
    j0 = jnp.argmax(d2[:, 0, :], axis=1).astype(jnp.int32)
    dj20 = jnp.take_along_axis(d2[:, 0, :], j0[:, None], axis=1)[:, 0]
    stopped0 = jnp.zeros((B,), bool)
    zero = jnp.zeros((B,), jnp.int32)

    def select(t, sel, dh, stopped, j, dj2):
        stopped = stopped | (dj2 <= eps2)
        dj = jnp.sqrt(jnp.maximum(dj2, eps2))
        sel = sel.at[:, t].set(jnp.where(stopped, -1, j))
        dh = dh.at[:, t].set(jnp.where(stopped, 0.0, dj))
        vj = jnp.take_along_axis(V, j[:, None, None], axis=2)[:, :, 0]
        return sel, dh, stopped, dj, vj

    def step_full(t, carry):
        C, d2, sel, dh, stopped, j, dj2 = carry
        sel, dh, stopped, dj, vj = select(t, sel, dh, stopped, j, dj2)
        cj = jnp.take_along_axis(C, j[:, None, None], axis=2)[:, :, 0]
        flt = jnp.stack([dj, stopped.astype(jnp.float32)], 1)[:, None, :]
        ints = jnp.stack([j, zero], 1)[:, None, :]
        e, d2, mx, am = _full_sweep(
            V, C, d2, vj[:, None, :], cj[:, None, :], flt, ints,
            tile_m=tile_m, interpret=interpret,
        )
        C = jax.lax.dynamic_update_slice(C, e, (0, t, 0))
        return C, d2, sel, dh, stopped, am[:, 0, 0], mx[:, 0, 0]

    def step_windowed(t, carry):
        C, d2, win, sel, dh, stopped, j, dj2 = carry
        sel, dh, stopped, dj, vj = select(t, sel, dh, stopped, j, dj2)
        cj_pre = jnp.take_along_axis(C, j[:, None, None], axis=2)[:, :, 0]
        full = (t >= w) & ~stopped  # (B,)
        Cw = jnp.take_along_axis(C, jnp.clip(win, 0)[:, None, :], axis=2)
        Cw = jnp.where((win >= 0)[:, None, :], Cw, 0.0)
        cos, sin, cj_post, d2j = eviction_coeffs(Cw, cj_pre, dj2, full, w)
        djp = jnp.sqrt(jnp.maximum(d2j, eps2))
        pos = jnp.minimum(t, w - 1)
        flt = jnp.concatenate(
            [
                jnp.stack(
                    [djp, stopped.astype(jnp.float32), full.astype(jnp.float32)],
                    1,
                ),
                cos, sin,
            ],
            axis=1,
        )[:, None, :]
        ints = jnp.stack([j, zero, zero + pos], 1)[:, None, :]
        C, d2, mx, am = _windowed_sweep(
            V, C, d2, vj[:, None, :], cj_post[:, None, :], flt, ints,
            w=w, tile_m=tile_m, interpret=interpret,
        )
        win_shift = jnp.roll(win, -1, axis=1)
        win1 = jnp.where(full[:, None], win_shift.at[:, w - 1].set(-1), win)
        win = jnp.where(stopped[:, None], win, win1.at[:, pos].set(j))
        return C, d2, win, sel, dh, stopped, am[:, 0, 0], mx[:, 0, 0]

    if w is None:
        state = (C, d2, sel, dh, stopped0, j0, dj20)
        _, _, sel, dh, _, _, _ = jax.lax.fori_loop(0, k, step_full, state)
    else:
        win0 = jnp.full((B, w), -1, jnp.int32)
        state = (C, d2, win0, sel, dh, stopped0, j0, dj20)
        _, _, _, sel, dh, _, _, _ = jax.lax.fori_loop(
            0, k, step_windowed, state
        )
    return sel, dh
