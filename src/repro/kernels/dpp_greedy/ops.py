"""Jitted public wrapper for the dpp_greedy Pallas kernel.

Handles TPU-friendly padding (M to a lane multiple, D to a sublane
multiple) and falls back to the pure-jnp path when the VMEM working set
would not fit (large M) or when the caller asks for it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dpp_greedy.dpp_greedy import dpp_greedy_kernel
from repro.kernels.dpp_greedy.ref import dpp_greedy_ref

LANE = 128
SUBLANE = 8
# V (D*M) + C (N*M) + a few (1, M) rows, all f32, must fit in ~16 MB VMEM.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def vmem_bytes(D: int, M: int, k: int) -> int:
    Mp, Dp = _round_up(M, LANE), _round_up(D, SUBLANE)
    return 4 * (Dp * Mp + _round_up(k, SUBLANE) * Mp + 8 * Mp)


def dpp_greedy(
    V: jnp.ndarray,
    k: int,
    mask: jnp.ndarray | None = None,
    eps: float = 1e-3,
    interpret: bool = True,
    force_jnp: bool = False,
):
    """Batched greedy DPP MAP inference.

    V (B, D, M) scaled features, mask (B, M). Returns (sel, d_hist) with
    shape (B, k); sel slots after an eps-stop hold -1.
    """
    B, D, M = V.shape
    if mask is None:
        mask = jnp.ones((B, M), bool)
    if force_jnp or vmem_bytes(D, M, k) > VMEM_BUDGET_BYTES:
        return dpp_greedy_ref(V, mask, k, eps)

    Mp, Dp = _round_up(M, LANE), _round_up(D, SUBLANE)
    if (Mp, Dp) != (M, D):
        V = jnp.pad(V, ((0, 0), (0, Dp - D), (0, Mp - M)))
        mask = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, Mp - M)))
    sel, dhist = dpp_greedy_kernel(V, mask, k=k, eps=eps, interpret=interpret)
    return sel, dhist
