"""Jitted public wrapper for the dpp_greedy Pallas kernels.

Kernel-first dispatch (``TilePolicy``): when the whole working set
``V (D, M)`` + Cholesky state fits the VMEM budget, the resident
whole-slate kernels in ``dpp_greedy.py`` run (the entire greedy loop in
one ``pallas_call``); past the budget the **tiled streaming kernels**
in ``tiled.py`` run instead — each greedy step is a double-buffered
grid sweep over ``(D, tile_m)`` / ``(state_rows, tile_m)`` blocks, so
large M no longer degrades to the pure-jnp path.  VMEM accounting is
per *tile* (``tiling.tile_vmem_bytes``); the old whole-array
``vmem_bytes`` survives as a deprecation shim and no longer gates
anything.

The pure-jnp reference remains reachable via ``force_jnp=True`` (and as
a last resort when even one lane-width tile would not fit — pathological
``D``/``state_rows``).

``window=w`` selects the sliding-window variants: the Cholesky state
shrinks from (k, M) to (w, M), so both the resident-mode budget check
and the per-tile model depend on ``w`` rather than the slate length.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.dpp_greedy.dpp_greedy import dpp_greedy_kernel
from repro.kernels.dpp_greedy.ref import dpp_greedy_ref
from repro.kernels.dpp_greedy.tiled import dpp_greedy_tiled
# VMEM_BUDGET_BYTES / tile_vmem_bytes / untiled_vmem_bytes / vmem_bytes
# are re-exported for back-compat: pre-tiling callers imported the
# budget and accounting from ops (the module that used to own the gate).
from repro.kernels.dpp_greedy.tiling import (  # noqa: F401
    LANE,
    SUBLANE,
    VMEM_BUDGET_BYTES,
    TilePolicy,
    round_up as _round_up,
    tile_vmem_bytes,
    untiled_vmem_bytes,
    vmem_bytes,
)


def dpp_greedy(
    V: jnp.ndarray,
    k: int,
    mask: jnp.ndarray | None = None,
    eps: float = 1e-3,
    interpret: bool = True,
    force_jnp: bool = False,
    window: int | None = None,
    tile_m: Optional[int] = None,
    tile_policy: Optional[TilePolicy] = None,
):
    """Batched greedy DPP MAP inference.

    V (B, D, M) scaled features, mask (B, M). Returns (sel, d_hist) with
    shape (B, k); sel slots after an eps-stop hold -1.  ``window=w``
    enforces diversity only against the last w picks (O(w M) VMEM state,
    unbounded k); ``window >= k`` or None is the exact Algorithm 1.

    ``tile_m`` (or a full ``tile_policy``) forces the tiled streaming
    kernels with that candidate-axis tile; by default ``TilePolicy``
    picks the resident kernels when the working set fits VMEM and the
    widest fitting tile otherwise.
    """
    B, D, M = V.shape
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if tile_m is not None and tile_policy is not None:
        raise ValueError("pass at most one of tile_m= or tile_policy=")
    if mask is None:
        mask = jnp.ones((B, M), bool)
    state_rows = k if window is None else min(window, k)
    if force_jnp:
        return dpp_greedy_ref(V, mask, k, eps, window=window)

    policy = tile_policy or TilePolicy(tile_m=tile_m)
    windowed = window is not None and window < k
    mode, tm = policy.decide(D, M, state_rows, windowed)
    if mode == "jnp":  # even a single lane-width tile exceeds the budget
        return dpp_greedy_ref(V, mask, k, eps, window=window)

    Dp = _round_up(D, SUBLANE)
    Mp = _round_up(M, LANE if mode == "resident" else tm)
    if (Mp, Dp) != (M, D):
        V = jnp.pad(V, ((0, 0), (0, Dp - D), (0, Mp - M)))
        mask = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, Mp - M)))
    if mode == "resident":
        return dpp_greedy_kernel(
            V, mask, k=k, window=window, eps=eps, interpret=interpret
        )
    return dpp_greedy_tiled(
        V, mask, k, window=window, eps=eps, tile_m=min(tm, Mp),
        interpret=interpret,
    )
