"""Jitted public wrapper for the dpp_greedy Pallas kernels.

Kernel-first dispatch (``TilePolicy``): when the whole working set
``V (D, M)`` + Cholesky state fits the VMEM budget, the resident
whole-slate kernels in ``dpp_greedy.py`` run (the entire greedy loop in
one ``pallas_call``); past the budget the **tiled streaming kernels**
in ``tiled.py`` run instead — each greedy step is a double-buffered
grid sweep over ``(D, tile_m)`` / ``(state_rows, tile_m)`` blocks, so
large M no longer degrades to the pure-jnp path.  VMEM accounting is
per *tile* (``tiling.tile_vmem_bytes``); the resident-mode whole-array
working set is ``tiling.untiled_vmem_bytes`` (the pre-PR-4
``vmem_bytes`` shim over it is gone).

The pure-jnp reference remains reachable via ``force_jnp=True`` (and as
a last resort when even one lane-width tile would not fit — pathological
``D``/``state_rows``).

``window=w`` selects the sliding-window variants: the Cholesky state
shrinks from (k, M) to (w, M), so both the resident-mode budget check
and the per-tile model depend on ``w`` rather than the slate length.
"""
from __future__ import annotations

import os
from typing import Optional, Union

import jax.numpy as jnp

from repro.kernels.dpp_greedy.dpp_greedy import dpp_greedy_kernel
from repro.kernels.dpp_greedy.ref import dpp_greedy_ref
from repro.kernels.dpp_greedy.tiled import (
    dpp_greedy_tiled,
    fused_chunk_exact,
    fused_chunk_windowed,
)
# VMEM_BUDGET_BYTES / tile_vmem_bytes / untiled_vmem_bytes are
# re-exported for back-compat: pre-tiling callers imported the budget
# and accounting from ops (the module that used to own the gate).
from repro.kernels.dpp_greedy.tiling import (  # noqa: F401
    LANE,
    SUBLANE,
    VMEM_BUDGET_BYTES,
    TilePolicy,
    round_up as _round_up,
    tile_vmem_bytes,
    untiled_vmem_bytes,
    validate_tile_m,
)
from repro.obs.dispatch import (
    record_kernel_dispatch,
    record_tile_override,
    record_tile_resolution,
)

_TileM = Union[int, str, None]  # int | "auto" | None


def _env_tile_m() -> _TileM:
    """Parse the ``DPP_TILE_M`` process override: unset/empty -> None,
    ``auto`` -> the autotune ladder, anything else an explicit LANE
    multiple.  Invalid values raise — a typo'd fleet-wide override must
    fail loudly, not silently fall back to the model."""
    raw = os.environ.get("DPP_TILE_M", "").strip()
    if not raw:
        return None
    if raw.lower() == "auto":
        return "auto"
    try:
        tm = int(raw)
    except ValueError:
        raise ValueError(
            f'DPP_TILE_M must be an integer LANE multiple or "auto", '
            f"got {raw!r}"
        ) from None
    validate_tile_m(tm)
    return tm


def _resolve_tile_policy(
    tile_m: _TileM, tile_policy: Optional[TilePolicy]
) -> TilePolicy:
    """The tile_m precedence ladder, applied once per dispatch:

        DPP_TILE_M env > explicit ``tile_m=`` > ``"auto"`` cache >
        analytical model

    (the cache-vs-model rungs resolve inside ``TilePolicy.decide``).
    An explicit ``tile_policy=`` *object* bypasses the env override —
    the power-user escape hatch the autotune sweep itself uses so the
    environment being tuned cannot hijack its measurements.  Losing
    sources are recorded in dispatch telemetry, not silently ignored.
    """
    if tile_m is not None and tile_policy is not None:
        raise ValueError("pass at most one of tile_m= or tile_policy=")
    if tile_policy is not None:
        record_tile_resolution("policy")
        return tile_policy
    env = _env_tile_m()
    if env is not None:
        if tile_m is not None and env != tile_m:
            record_tile_override(
                winner="env",
                lost="auto" if tile_m == "auto" else "explicit",
            )
        record_tile_resolution("env")
        return TilePolicy(tile_m=env)
    if tile_m == "auto":
        record_tile_resolution("auto")
    elif tile_m is not None:
        record_tile_resolution("explicit")
    else:
        record_tile_resolution("model")
    return TilePolicy(tile_m=tile_m)


def dpp_greedy(
    V: jnp.ndarray,
    k: int,
    mask: jnp.ndarray | None = None,
    eps: float = 1e-3,
    interpret: bool = True,
    force_jnp: bool = False,
    window: int | None = None,
    tile_m: _TileM = None,
    tile_policy: Optional[TilePolicy] = None,
):
    """Batched greedy DPP MAP inference.

    V (B, D, M) scaled features, mask (B, M). Returns (sel, d_hist) with
    shape (B, k); sel slots after an eps-stop hold -1.  ``window=w``
    enforces diversity only against the last w picks (O(w M) VMEM state,
    unbounded k); ``window >= k`` or None is the exact Algorithm 1.

    ``tile_m`` (or a full ``tile_policy``) forces the tiled streaming
    kernels with that candidate-axis tile; ``tile_m="auto"`` sizes the
    tile from the measured autotune cache (model fallback on a miss);
    by default ``TilePolicy`` picks the resident kernels when the
    working set fits VMEM and the widest model-fitting tile otherwise.
    The ``DPP_TILE_M`` env var (an int or ``auto``) overrides ``tile_m``
    process-wide; an explicit ``tile_policy=`` object bypasses the env.
    """
    B, D, M = V.shape
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if mask is None:
        mask = jnp.ones((B, M), bool)
    state_rows = k if window is None else min(window, k)
    windowed = window is not None and window < k
    if force_jnp:
        record_kernel_dispatch(
            "jnp", D=D, M=M, state_rows=state_rows, windowed=windowed
        )
        return dpp_greedy_ref(V, mask, k, eps, window=window)

    policy = _resolve_tile_policy(tile_m, tile_policy)
    mode, tm = policy.decide(D, M, state_rows, windowed)
    record_kernel_dispatch(
        mode, D=D, M=M, state_rows=state_rows, windowed=windowed, tile_m=tm,
        vmem_bytes=(
            untiled_vmem_bytes(D, M, state_rows) if mode == "resident"
            else tile_vmem_bytes(D, tm, state_rows, windowed)
            if mode == "tiled" else None
        ),
    )
    if mode == "jnp":  # even a single lane-width tile exceeds the budget
        return dpp_greedy_ref(V, mask, k, eps, window=window)

    Dp = _round_up(D, SUBLANE)
    Mp = _round_up(M, LANE if mode == "resident" else tm)
    if (Mp, Dp) != (M, D):
        V = jnp.pad(V, ((0, 0), (0, Dp - D), (0, Mp - M)))
        mask = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, Mp - M)))
    if mode == "resident":
        return dpp_greedy_kernel(
            V, mask, k=k, window=window, eps=eps, interpret=interpret
        )
    return dpp_greedy_tiled(
        V, mask, k, window=window, eps=eps, tile_m=min(tm, Mp),
        interpret=interpret,
    )


# ---------------------------------------------------------------------------
# Resumable streaming execution (chunk-emitting; repro.core.streaming)
# ---------------------------------------------------------------------------


def _stream_tile(D: int, M: int, state_rows: int, windowed: bool,
                 tile_m: _TileM, tile_policy: Optional[TilePolicy]):
    """The candidate-axis tile a streaming state uses, derived
    deterministically from the problem shape so init and every chunk
    agree (the autotune cache is memoized per file stamp, so a cache
    rewritten mid-stream surfaces as the existing padded-geometry
    mismatch error, not silent divergence).  Resident-size working sets
    run the fused chunk kernel as a single whole-M tile (the
    VMEM-resident analogue)."""
    policy = _resolve_tile_policy(tile_m, tile_policy)
    # chunked=True: the fused chunk kernels stream the full Cholesky
    # block back out every step, so their per-tile working set is wider
    # than the per-step sweep the default model describes.
    mode, tm = policy.decide(D, M, state_rows, windowed, chunked=True)
    if mode == "jnp":
        raise ValueError(
            "pathological shape: even one lane-width tile exceeds the VMEM "
            "budget — stream through the jnp backend instead"
        )
    if mode == "resident":
        Mp = _round_up(M, LANE)
        return Mp, Mp
    Mp = _round_up(M, tm)
    return min(tm, Mp), Mp


def dpp_greedy_stream_init(
    V: jnp.ndarray,
    k: int,
    mask: jnp.ndarray | None = None,
    window: int | None = None,
    tile_m: _TileM = None,
    tile_policy: Optional[TilePolicy] = None,
):
    """Initial resumable state for the Pallas streaming path.

    V (D, M) single or (B, D, M) batched.  Returns a
    ``repro.core.streaming.GreedyState`` in the kernels' layout: padded
    row-layout Cholesky state ``C (B, R, Mp)``, ``d2 (B, Mp)`` with the
    mask (and padding) folded in, ``win (B, w)`` ring ids (``(B, 0)``
    exact), per-user ``stopped (B,)``.
    """
    from repro.core.streaming import GreedyState

    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    single = V.ndim == 2
    Vb = (V[None] if single else V).astype(jnp.float32)
    B, D, M = Vb.shape
    windowed = window is not None and window < k
    R = min(window, k) if windowed else k
    tile, Mp = _stream_tile(D, M, R, windowed, tile_m, tile_policy)
    record_kernel_dispatch(
        "fused_chunk", D=D, M=M, state_rows=R, windowed=windowed,
        tile_m=tile,
        vmem_bytes=tile_vmem_bytes(D, tile, R, windowed, chunked=True),
    )
    if mask is None:
        mask = jnp.ones((B, M), bool)
    elif mask.ndim == 1:
        mask = mask[None]
    Dp = _round_up(D, SUBLANE)
    if (Mp, Dp) != (M, D):
        Vb = jnp.pad(Vb, ((0, 0), (0, Dp - D), (0, Mp - M)))
        mask = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, Mp - M)))
    diag = jnp.sum(Vb * Vb, axis=1)  # (B, Mp)
    d2 = jnp.where(mask > 0, diag, float("-inf"))
    C = jnp.zeros((B, R, Mp), jnp.float32)
    win = (
        jnp.full((B, R), -1, jnp.int32) if windowed
        else jnp.zeros((B, 0), jnp.int32)
    )
    return GreedyState(
        jnp.zeros((), jnp.int32), jnp.zeros((B,), bool), C, d2, win
    )


def dpp_greedy_stream_pad(V: jnp.ndarray, state) -> jnp.ndarray:
    """Pad/cast ``V`` once to the streaming state's (Dp, Mp) geometry.

    ``dpp_greedy_stream_chunk`` accepts raw ``V`` and pads on the fly,
    but that re-copies the full array every chunk; a generator looping
    many chunks should pad once up front (the chunk executor detects
    the already-padded shape and skips the copy) —
    ``repro.core.dispatch.greedy_map_chunks`` does this."""
    single = V.ndim == 2
    Vb = (V[None] if single else V).astype(jnp.float32)
    B, D, M = Vb.shape
    Mp = state.d2.shape[-1]
    Dp = _round_up(D, SUBLANE)
    if (Mp, Dp) != (M, D):
        Vb = jnp.pad(Vb, ((0, 0), (0, Dp - D), (0, Mp - M)))
    return Vb[0] if single else Vb


def dpp_greedy_stream_chunk(
    V: jnp.ndarray,
    state,
    chunk: int,
    *,
    eps: float = 1e-3,
    tile_m: _TileM = None,
    tile_policy: Optional[TilePolicy] = None,
    interpret: bool = True,
):
    """Advance ``chunk`` greedy steps on a Pallas streaming state.

    One fused ``pallas_call`` — one HBM C/d2 round-trip — per chunk
    (see ``repro.kernels.dpp_greedy.tiled``).  The state is
    authoritative for the mode (its ``win`` leaf decides windowed vs
    exact).  Returns ``(state, sel, dh)`` with ``sel``/``dh`` shaped
    ``(chunk,)`` for a single-problem ``V (D, M)`` and ``(B, chunk)``
    batched.

    ``state.t`` may be the shared scalar the uniform batch paths use
    or a per-lane ``(B,)`` counter (the continuous-batching slot
    layout of ``repro.core.streaming`` — slots join mid-flight at
    heterogeneous progress): the fused kernels carry ``t`` per grid
    lane in their ``stepi`` cells either way, so each lane's Cholesky
    row index / ring position follows its own counter.
    """
    single = V.ndim == 2
    Vb = (V[None] if single else V).astype(jnp.float32)
    B, D, M = Vb.shape
    windowed = state.win.shape[-1] > 0
    R = state.C.shape[1]
    tile, Mp = _stream_tile(D, M, R, windowed, tile_m, tile_policy)
    if Mp != state.d2.shape[-1]:
        raise ValueError(
            f"state was built for a padded candidate axis of "
            f"{state.d2.shape[-1]}, but V (M={M}) pads to {Mp} — "
            f"pass the same V/tile configuration used at init"
        )
    Dp = _round_up(D, SUBLANE)
    if (Mp, Dp) != (M, D):
        Vb = jnp.pad(Vb, ((0, 0), (0, Dp - D), (0, Mp - M)))
    if windowed:
        C, d2, win, stopped, sel, dh = fused_chunk_windowed(
            Vb, state.C, state.d2, state.win, state.t, state.stopped,
            chunk=chunk, eps=float(eps), w=R, tile_m=tile,
            interpret=interpret,
        )
    else:
        C, d2, stopped, sel, dh = fused_chunk_exact(
            Vb, state.C, state.d2, state.t, state.stopped,
            chunk=chunk, eps=float(eps), tile_m=tile, interpret=interpret,
        )
        win = state.win
    new_state = type(state)(state.t + chunk, stopped, C, d2, win)
    if single:
        return new_state, sel[0], dh[0]
    return new_state, sel, dh
