"""Jitted public wrapper for the dpp_greedy Pallas kernel.

Handles TPU-friendly padding (M to a lane multiple, D to a sublane
multiple) and falls back to the pure-jnp path when the VMEM working set
would not fit (large M) or when the caller asks for it.

``window=w`` selects the sliding-window kernel: the Cholesky state in
VMEM shrinks from (k, M) to (w, M), so the VMEM budget check — and
therefore the largest candidate set M the kernel accepts — depends on
``w`` rather than the slate length ``k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dpp_greedy.dpp_greedy import dpp_greedy_kernel
from repro.kernels.dpp_greedy.ref import dpp_greedy_ref

LANE = 128
SUBLANE = 8
# V (D*M) + C (state_rows*M) + a few (1, M) rows, all f32, in ~16 MB VMEM.
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def vmem_bytes(D: int, M: int, state_rows: int) -> int:
    """VMEM working set; ``state_rows`` is k (full) or w (windowed)."""
    Mp, Dp = _round_up(M, LANE), _round_up(D, SUBLANE)
    return 4 * (Dp * Mp + _round_up(state_rows, SUBLANE) * Mp + 8 * Mp)


def dpp_greedy(
    V: jnp.ndarray,
    k: int,
    mask: jnp.ndarray | None = None,
    eps: float = 1e-3,
    interpret: bool = True,
    force_jnp: bool = False,
    window: int | None = None,
):
    """Batched greedy DPP MAP inference.

    V (B, D, M) scaled features, mask (B, M). Returns (sel, d_hist) with
    shape (B, k); sel slots after an eps-stop hold -1.  ``window=w``
    enforces diversity only against the last w picks (O(w M) VMEM state,
    unbounded k); ``window >= k`` or None is the exact Algorithm 1.
    """
    B, D, M = V.shape
    if window is not None and window < 1:
        raise ValueError(f"window must be >= 1, got {window}")
    if mask is None:
        mask = jnp.ones((B, M), bool)
    state_rows = k if window is None else min(window, k)
    if force_jnp or vmem_bytes(D, M, state_rows) > VMEM_BUDGET_BYTES:
        return dpp_greedy_ref(V, mask, k, eps, window=window)

    Mp, Dp = _round_up(M, LANE), _round_up(D, SUBLANE)
    if (Mp, Dp) != (M, D):
        V = jnp.pad(V, ((0, 0), (0, Dp - D), (0, Mp - M)))
        mask = jnp.pad(mask.astype(jnp.float32), ((0, 0), (0, Mp - M)))
    sel, dhist = dpp_greedy_kernel(
        V, mask, k=k, window=window, eps=eps, interpret=interpret
    )
    return sel, dhist
