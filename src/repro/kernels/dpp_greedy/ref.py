"""Pure-jnp oracle for the dpp_greedy Pallas kernel.

An independent implementation path: ``repro.core.greedy_chol`` keeps the
Cholesky state as (M, N) columns (the paper's layout), while the kernel
uses the transposed (N, M) row layout — agreement between the two is a
meaningful check.  The windowed mode is checked against
``repro.core.windowed``'s incremental path (itself tested against the
rebuild-every-step reference).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.greedy_chol import dpp_greedy_lowrank_batch
from repro.core.windowed import dpp_greedy_windowed_lowrank_batch


def dpp_greedy_ref(
    V: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    eps: float = 1e-3,
    window: int | None = None,
):
    """V (B, D, M), mask (B, M) -> (sel (B, k) i32, d_hist (B, k) f32)."""
    if window is not None and window < k:
        res = dpp_greedy_windowed_lowrank_batch(
            V.astype(jnp.float32), k, window, eps, mask.astype(bool)
        )
    else:
        res = dpp_greedy_lowrank_batch(
            V.astype(jnp.float32), k, eps, mask.astype(bool)
        )
    return res.indices, res.d_hist
