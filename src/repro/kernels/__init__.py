"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each subpackage ships ``<name>.py`` (pl.pallas_call + BlockSpec),
``ops.py`` (jit'd public wrapper with padding + fallback) and ``ref.py``
(pure-jnp oracle); tests sweep shapes/dtypes in interpret mode.
"""
from repro.kernels.dpp_greedy import dpp_greedy
from repro.kernels.fm_interaction import fm_interaction
from repro.kernels.scored_topk import scored_topk

__all__ = ["dpp_greedy", "fm_interaction", "scored_topk"]
