"""Jitted wrapper: block-survivor kernel + final reduce.

Ragged candidate counts (``M`` not a multiple of ``block_m``, or
``M < 2 * block_m``) no longer fall back to the jnp reference: the
kernel pads ``emb`` up to the block multiple and masks the padded rows
to ``-inf`` by global index, so the shortlist kernel survives any M.
The jnp path remains reachable via ``force_jnp=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.scored_topk.scored_topk import scored_topk_kernel
from repro.kernels.scored_topk.ref import scored_topk_ref


def scored_topk(
    emb: jnp.ndarray,
    query: jnp.ndarray,
    c: int = 128,
    block_m: int = 8192,
    interpret: bool = True,
    force_jnp: bool = False,
):
    """Global top-c of ``emb @ query``: (vals (c,), idx (c,))."""
    M = emb.shape[0]
    if c > M:
        raise ValueError(f"c={c} exceeds the candidate count M={M}")
    if force_jnp:
        return scored_topk_ref(emb, query, c)
    bvals, bidx = scored_topk_kernel(
        emb, query, c=c, block_m=block_m, interpret=interpret
    )
    flat_v, flat_i = bvals.reshape(-1), bidx.reshape(-1)
    vals, pos = jax.lax.top_k(flat_v, c)
    return vals, flat_i[pos]
