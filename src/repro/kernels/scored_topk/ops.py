"""Jitted wrapper: block-survivor kernel + final reduce; jnp fallback."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.scored_topk.scored_topk import scored_topk_kernel
from repro.kernels.scored_topk.ref import scored_topk_ref


def scored_topk(
    emb: jnp.ndarray,
    query: jnp.ndarray,
    c: int = 128,
    block_m: int = 8192,
    interpret: bool = True,
    force_jnp: bool = False,
):
    """Global top-c of ``emb @ query``: (vals (c,), idx (c,))."""
    M = emb.shape[0]
    if force_jnp or M < 2 * min(block_m, M) or M % min(block_m, M) != 0:
        return scored_topk_ref(emb, query, c)
    bvals, bidx = scored_topk_kernel(
        emb, query, c=c, block_m=block_m, interpret=interpret
    )
    flat_v, flat_i = bvals.reshape(-1), bidx.reshape(-1)
    vals, pos = jax.lax.top_k(flat_v, c)
    return vals, flat_i[pos]
