"""Pure-jnp oracle for scored_topk."""
import jax
import jax.numpy as jnp


def scored_topk_ref(emb: jnp.ndarray, query: jnp.ndarray, c: int):
    """emb (M, D), query (D,) -> (vals (c,), idx (c,)) global top-c."""
    s = emb.astype(jnp.float32) @ query.astype(jnp.float32)
    return jax.lax.top_k(s, c)
