"""Pallas TPU kernel: fused candidate scoring + hierarchical top-C.

The retrieval serving shape scores ONE query against 10^6 candidates and
shortlists C for DPP re-ranking.  The kernel fuses, per (query, candidate
block): the dot-product scoring ``s = E_blk @ q`` (MXU) and a per-block
``top_c`` partial reduction, so the full (M,) score vector is never
written back to HBM — only (M / BM) * C survivors are.  A final cheap
``top_c`` over survivors runs outside the kernel (ops.py).

This is the flash-decoding-style split-reduce pattern applied to
retrieval: HBM traffic drops from  M*(D+1)*4  to  M*D*4 + tiny.

Note: validated in interpret mode (this container is CPU-only);
``jax.lax.top_k`` inside a kernel body lowers on TPU Mosaic for the
(8, 128)-aligned shapes used here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.dpp_greedy.tiling import LANE, round_up as _round_up


def _kernel(e_ref, q_ref, vals_ref, idx_ref, *, c: int, block_m: int, m: int):
    """e_ref (BM, D), q_ref (1, D); vals/idx (1, C) per grid step.

    ``m`` is the unpadded candidate count: rows at global index >= m are
    zero padding (ops.py pads ragged M up to a block multiple) and are
    scored -inf so they can never survive the block top-c."""
    b = pl.program_id(0)
    e = e_ref[...].astype(jnp.float32)
    q = q_ref[...].astype(jnp.float32)  # (1, D)
    s = jnp.dot(e, q.T, preferred_element_type=jnp.float32)[:, 0]  # (BM,)
    gid = jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)[:, 0]
    s = jnp.where(gid + b * block_m < m, s, -jnp.inf)
    vals, idx = jax.lax.top_k(s, c)
    vals_ref[...] = vals[None, :]
    idx_ref[...] = (idx + b * block_m)[None, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("c", "block_m", "interpret"))
def scored_topk_kernel(
    emb: jnp.ndarray,
    query: jnp.ndarray,
    c: int = 128,
    block_m: int = 8192,
    interpret: bool = True,
):
    """emb (M, D), query (D,) -> (vals (nb, c), idx (nb, c)) block survivors.

    Ragged M is handled by zero-padding emb up to the block multiple;
    the kernel masks padded rows to -inf by global index, so survivors
    are identical to the unpadded problem."""
    M, D = emb.shape
    bm = _round_up(min(block_m, _round_up(M, LANE)), LANE)
    bm = max(bm, _round_up(c, LANE))
    Mp = _round_up(M, bm)
    if Mp != M:
        emb = jnp.pad(emb, ((0, Mp - M), (0, 0)))
    assert Mp % bm == 0 and c <= bm, (M, bm, c)
    nb = Mp // bm
    vals, idx = pl.pallas_call(
        functools.partial(_kernel, c=c, block_m=bm, m=M),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, c), jnp.float32),
            jax.ShapeDtypeStruct((nb, c), jnp.int32),
        ],
        interpret=interpret,
    )(emb, query[None, :])
    return vals, idx
