from repro.kernels.scored_topk.ops import scored_topk
from repro.kernels.scored_topk.ref import scored_topk_ref

__all__ = ["scored_topk", "scored_topk_ref"]
