"""Pallas TPU kernel: fused FM second-order interaction (DeepFM hot path).

Computes, per example, the factorization-machine pairwise term

    y_b = 0.5 * sum_d [ (sum_f v_{bfd})^2  -  sum_f v_{bfd}^2 ]

in one VMEM pass over the (F, D) embedding block — the unfused jnp
version materializes both the squared-sum and sum-of-squares tensors in
HBM.  Arithmetic intensity is O(1) FLOP/byte, i.e. purely memory-bound:
fusion is exactly what the roofline prescribes for it.

Grid: (B // BB,) — one program handles a block of BB examples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(emb_ref, out_ref):
    """emb_ref: (BB, F, D) f32; out_ref: (BB, 1) f32."""
    v = emb_ref[...].astype(jnp.float32)
    s = jnp.sum(v, axis=1)  # (BB, D)
    sq = jnp.sum(v * v, axis=1)  # (BB, D)
    out_ref[...] = 0.5 * jnp.sum(s * s - sq, axis=1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fm_interaction_kernel(
    emb: jnp.ndarray, block_b: int = 128, interpret: bool = True
) -> jnp.ndarray:
    """emb (B, F, D) -> (B,) f32 FM second-order logits."""
    B, F, D = emb.shape
    bb = min(block_b, B)
    assert B % bb == 0, (B, bb)
    out = pl.pallas_call(
        _kernel,
        grid=(B // bb,),
        in_specs=[pl.BlockSpec((bb, F, D), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=interpret,
    )(emb)
    return out[:, 0]
