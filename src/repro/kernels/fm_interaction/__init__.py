from repro.kernels.fm_interaction.ops import fm_interaction
from repro.kernels.fm_interaction.ref import fm_interaction_ref

__all__ = ["fm_interaction", "fm_interaction_ref"]
