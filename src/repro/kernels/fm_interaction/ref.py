"""Pure-jnp oracle for the FM interaction kernel."""
import jax.numpy as jnp


def fm_interaction_ref(emb: jnp.ndarray) -> jnp.ndarray:
    """emb (B, F, D) -> (B,): 0.5 * sum_d[(sum_f v)^2 - sum_f v^2]."""
    v = emb.astype(jnp.float32)
    s = jnp.sum(v, axis=1)
    sq = jnp.sum(v * v, axis=1)
    return 0.5 * jnp.sum(s * s - sq, axis=1)
