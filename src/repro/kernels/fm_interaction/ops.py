"""Jitted wrapper: pads the batch to the block size, dispatches kernel/ref."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fm_interaction.fm_interaction import fm_interaction_kernel
from repro.kernels.fm_interaction.ref import fm_interaction_ref


def fm_interaction(
    emb: jnp.ndarray,
    block_b: int = 128,
    interpret: bool = True,
    force_jnp: bool = False,
) -> jnp.ndarray:
    """emb (B, F, D) -> (B,) fused FM second-order term."""
    if force_jnp:
        return fm_interaction_ref(emb)
    B = emb.shape[0]
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        emb = jnp.pad(emb, ((0, pad), (0, 0), (0, 0)))
    out = fm_interaction_kernel(emb, block_b=bb, interpret=interpret)
    return out[:B]
