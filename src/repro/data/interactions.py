"""Synthetic user-item interaction datasets shaped like the paper's §5.2
evaluation (MovieLens / Last.FM / Jester are unavailable offline).

Generation model: items live in ``n_clusters`` latent taste clusters;
users have mixed cluster affinities; interactions are sampled by
affinity.  This reproduces the structural properties the paper's
protocol depends on: clustered item-item similarity (so diversification
has something to trade off) and per-user relevance concentration.

The evaluation protocol mirrors §5.2.1:
  * leave-one-out split (one held-out test item per user);
  * item-item cosine similarity from co-occurrence (SUGGEST-style
    item-based CF);
  * per-user candidate set = top-K similar items of profile items;
  * relevance = aggregated similarity to the profile (as in [14]).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass
class InteractionDataset:
    name: str
    n_users: int
    n_items: int
    train: List[np.ndarray]  # per-user profile item ids
    test: np.ndarray  # (U,) held-out item per user


def synth_interactions(
    name: str,
    n_users: int,
    n_items: int,
    n_clusters: int,
    items_per_user: Tuple[int, int],
    seed: int = 0,
) -> InteractionDataset:
    rng = np.random.default_rng(seed)
    item_cluster_aff = rng.dirichlet(np.full(n_clusters, 0.2), size=n_items)
    user_aff = rng.dirichlet(np.full(n_clusters, 0.3), size=n_users)
    item_pop = rng.zipf(1.3, size=n_items).astype(np.float64)
    item_pop /= item_pop.sum()

    train, test = [], np.zeros(n_users, np.int64)
    for u in range(n_users):
        k = int(rng.integers(items_per_user[0], items_per_user[1] + 1))
        w = (item_cluster_aff @ user_aff[u]) * item_pop
        w /= w.sum()
        items = rng.choice(n_items, size=min(k, n_items), replace=False, p=w)
        test[u] = items[-1]
        train.append(np.sort(items[:-1]))
    return InteractionDataset(name, n_users, n_items, train, test)


def item_similarity(ds: InteractionDataset, shrink: float = 10.0) -> np.ndarray:
    """SUGGEST-style item-based CF similarity: cosine over the user-item
    co-occurrence matrix with a shrinkage prior (dense — M is small)."""
    M = ds.n_items
    X = np.zeros((ds.n_users, M), np.float32)
    for u, items in enumerate(ds.train):
        X[u, items] = 1.0
    co = X.T @ X  # (M, M) co-occurrence
    norms = np.sqrt(np.diag(co))
    denom = norms[:, None] * norms[None, :] + shrink
    S = co / np.maximum(denom, 1e-9)
    np.fill_diagonal(S, 1.0)
    return S.astype(np.float32)


def candidates_and_relevance(
    ds: InteractionDataset, S: np.ndarray, top_k_similar: int
) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    """Per user: candidate ids + relevance (aggregated similarity to the
    profile, as in [14]); candidates = union of top-K similar items of
    each profile item, minus the profile."""
    out = {}
    for u, profile in enumerate(ds.train):
        if profile.size == 0:
            out[u] = (np.zeros(0, np.int64), np.zeros(0, np.float32))
            continue
        sims = S[profile]  # (P, M)
        cand = set()
        for row in sims:
            cand.update(np.argpartition(-row, top_k_similar)[:top_k_similar].tolist())
        cand -= set(profile.tolist())
        cand = np.array(sorted(cand), np.int64)
        rel = S[np.ix_(profile, cand)].sum(axis=0).astype(np.float32)
        out[u] = (cand, rel)
    return out


PRESETS = {
    # scaled-down stand-ins for the paper's three datasets
    "movielens-like": dict(n_users=300, n_items=400, n_clusters=18, items_per_user=(20, 60)),
    "lastfm-like": dict(n_users=200, n_items=320, n_clusters=24, items_per_user=(15, 40)),
    "jester-like": dict(n_users=400, n_items=140, n_clusters=8, items_per_user=(20, 60)),
}


def load_preset(name: str, seed: int = 0) -> InteractionDataset:
    return synth_interactions(name, seed=seed, **PRESETS[name])
