"""Synthetic data pipelines (offline container: no external datasets).

Deterministic, seeded generators for every model family, shaped exactly
like the production inputs.  Each generator is an infinite iterator of
ready-to-jit batches (host numpy -> device arrays at the step boundary),
mirroring a real input pipeline's prefetch contract.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------


def lm_batches(
    vocab: int, batch: int, seq: int, seed: int = 0
) -> Iterator[dict]:
    """Zipf-ish token stream (heavy-tail like natural text)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    while True:
        toks = rng.choice(vocab, size=(batch, seq), p=probs).astype(np.int32)
        yield {"tokens": toks}


# ---------------------------------------------------------------------------
# RecSys click logs (planted-logit ground truth so training can learn)
# ---------------------------------------------------------------------------


def recsys_batches(
    vocab_sizes: Tuple[int, ...],
    batch: int,
    hot: int = 1,
    seed: int = 0,
    planted_dim: int = 8,
    signal_scale: float = 3.0,
) -> Iterator[dict]:
    """ids (B, F, H) int32 (-1 pad), labels (B,) in {0,1} from a planted
    low-rank logistic model over hashed field embeddings."""
    rng = np.random.default_rng(seed)
    F = len(vocab_sizes)
    # planted per-field hash projections -> a fixed logistic teacher
    planted = [rng.normal(size=(min(v, 64), planted_dim)) * 0.5 for v in vocab_sizes]
    w = rng.normal(size=(planted_dim,)) * signal_scale
    while True:
        ids = np.stack(
            [rng.integers(0, v, size=(batch, hot)) for v in vocab_sizes], axis=1
        ).astype(np.int32)
        if hot > 1:  # random multi-hot padding to exercise bags
            drop = rng.uniform(size=ids.shape) < 0.3
            drop[:, :, 0] = False
            ids = np.where(drop, -1, ids)
        z = np.zeros((batch,))
        for f in range(F):
            emb = planted[f][ids[:, f, 0] % planted[f].shape[0]]
            z += emb @ w / np.sqrt(F)
        labels = (rng.uniform(size=batch) < 1 / (1 + np.exp(-z))).astype(np.float32)
        yield {"ids": ids, "labels": labels}


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Graph:
    node_feats: np.ndarray  # (N, d_feat) f32
    edges: np.ndarray  # (E, 2) int32 [src, dst]
    targets: np.ndarray  # (N, n_vars) f32
    csr_indptr: np.ndarray  # (N+1,) — for neighbor sampling
    csr_indices: np.ndarray  # (E,)


def random_graph(
    n_nodes: int, n_edges: int, d_feat: int, n_vars: int, seed: int = 0
) -> Graph:
    """Random graph with mild degree skew + smooth planted targets."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-ish skew: square a uniform for dst popularity
    src = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    dst = ((rng.uniform(size=n_edges) ** 2) * n_nodes).astype(np.int32)
    edges = np.stack([src, dst], axis=1)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    w = rng.normal(size=(d_feat, n_vars)).astype(np.float32) / np.sqrt(d_feat)
    targets = (feats @ w).astype(np.float32)
    order = np.argsort(dst, kind="stable")
    sorted_dst = dst[order]
    indptr = np.searchsorted(sorted_dst, np.arange(n_nodes + 1)).astype(np.int64)
    return Graph(feats, edges, targets, indptr, src[order].astype(np.int32))


def neighbor_sample(
    g: Graph, batch_nodes: np.ndarray, fanouts: Tuple[int, ...], rng: np.random.Generator
) -> dict:
    """GraphSAGE-style sampled subgraph with fixed fanouts.

    Returns padded arrays (static shapes): node ids (layer-wise frontier),
    remapped edge list, masks.  in-edges are sampled per destination node
    from the CSR structure.
    """
    frontier = batch_nodes.astype(np.int64)
    all_nodes = [frontier]
    all_edges = []
    for fan in fanouts:
        srcs = np.full((frontier.size, fan), -1, np.int64)
        for i, n in enumerate(frontier):
            lo, hi = g.csr_indptr[n], g.csr_indptr[n + 1]
            deg = hi - lo
            if deg == 0:
                continue
            pick = rng.integers(lo, hi, size=fan)
            srcs[i] = g.csr_indices[pick]
        dsts = np.repeat(frontier, fan)
        flat_src = srcs.reshape(-1)
        valid = flat_src >= 0
        all_edges.append(np.stack([flat_src, dsts], axis=1)[valid])
        frontier = np.unique(flat_src[valid])
        all_nodes.append(frontier)

    nodes = np.unique(np.concatenate(all_nodes))
    remap = {int(n): i for i, n in enumerate(nodes)}
    edges = np.concatenate(all_edges) if all_edges else np.zeros((0, 2), np.int64)
    edges = np.array(
        [[remap[int(s)], remap[int(d)]] for s, d in edges], np.int32
    ).reshape(-1, 2)
    seeds_local = np.array([remap[int(n)] for n in batch_nodes], np.int32)
    return {
        "node_ids": nodes.astype(np.int64),
        "node_feats": g.node_feats[nodes],
        "edges": edges,
        "targets": g.targets[nodes],
        "seed_mask_ids": seeds_local,
    }


def pad_subgraph(sub: dict, max_nodes: int, max_edges: int) -> dict:
    """Pad a sampled subgraph to static shapes with masks."""
    n, e = sub["node_feats"].shape[0], sub["edges"].shape[0]
    assert n <= max_nodes and e <= max_edges, (n, e, max_nodes, max_edges)
    node_feats = np.zeros((max_nodes,) + sub["node_feats"].shape[1:], np.float32)
    node_feats[:n] = sub["node_feats"]
    targets = np.zeros((max_nodes,) + sub["targets"].shape[1:], np.float32)
    targets[:n] = sub["targets"]
    edges = np.zeros((max_edges, 2), np.int32)
    edges[:e] = sub["edges"]
    node_mask = np.zeros((max_nodes,), bool)
    node_mask[sub["seed_mask_ids"]] = True  # loss only on seed nodes
    edge_mask = np.zeros((max_edges,), bool)
    edge_mask[:e] = True
    return {
        "node_feats": node_feats,
        "edges": edges,
        "targets": targets,
        "node_mask": node_mask,
        "edge_mask": edge_mask,
    }


def batched_molecules(
    n_graphs: int, nodes_per: int, edges_per: int, d_feat: int, n_vars: int, seed: int = 0
) -> dict:
    """Disjoint union of small graphs (the ``molecule`` shape)."""
    rng = np.random.default_rng(seed)
    feats, edges, targets = [], [], []
    for i in range(n_graphs):
        g = random_graph(nodes_per, edges_per, d_feat, n_vars, seed=seed * 131 + i)
        feats.append(g.node_feats)
        edges.append(g.edges + i * nodes_per)
        targets.append(g.targets)
    return {
        "node_feats": np.concatenate(feats),
        "edges": np.concatenate(edges).astype(np.int32),
        "targets": np.concatenate(targets),
    }
