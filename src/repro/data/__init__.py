from repro.data.synthetic import (
    Graph,
    batched_molecules,
    lm_batches,
    neighbor_sample,
    pad_subgraph,
    random_graph,
    recsys_batches,
)
from repro.data.interactions import (
    InteractionDataset,
    candidates_and_relevance,
    item_similarity,
    load_preset,
    synth_interactions,
)
