"""Three-term roofline from a compiled dry-run artifact (reported via benchmarks/run.py, DESIGN.md §7).

Hardware model: TPU v5e —
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

  compute    = HLO_FLOPs / (chips * peak)
  memory     = HLO_bytes / (chips * hbm_bw)
  collective = collective_bytes / (chips * link_bw)

``cost_analysis()`` FLOPs/bytes are for the per-device partitioned module
on this jax version — detected and normalized so the table always reports
GLOBAL quantities (x chips) with per-chip terms in seconds.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.roofline.hlo_collectives import analyze_hlo, collective_op_counts

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
LINK_BW = 50e9  # B/s / link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_global: float
    hlo_bytes_global: float
    coll_bytes_global: float
    coll_by_kind: Dict[str, float]
    coll_op_counts: Dict[str, int]
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_flops_ratio: float
    memory_stats: Optional[dict] = None
    notes: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: dict,
    hlo_text: str,
    model_flops: float,
    memory_stats: Optional[dict] = None,
    notes: str = "",
) -> RooflineReport:
    # cost_analysis() counts while (scan) bodies once — use the
    # trip-weighted HLO walk instead (per-device), x chips for global.
    walk = analyze_hlo(hlo_text)
    flops_global = walk["_flops"] * chips
    bytes_global = walk["_mem_bytes"] * chips
    coll = {k: v for k, v in walk.items() if not k.startswith("_")}
    coll["_total"] = walk["_total"]
    coll_global = walk["_total"] * chips
    # raw cost_analysis kept for reference / cross-checks
    raw_flops = float(cost.get("flops", 0.0)) * chips
    raw_bytes = float(cost.get("bytes accessed", 0.0)) * chips

    t_comp = flops_global / (chips * PEAK_FLOPS)
    t_mem = bytes_global / (chips * HBM_BW)
    t_coll = coll_global / (chips * LINK_BW)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops_global=flops_global, hlo_bytes_global=bytes_global,
        coll_bytes_global=coll_global,
        coll_by_kind={k: v * chips for k, v in coll.items() if k != "_total"},
        coll_op_counts=collective_op_counts(hlo_text),
        t_compute=t_comp, t_memory=t_mem, t_collective=t_coll,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_flops_ratio=(model_flops / flops_global) if flops_global else 0.0,
        memory_stats=dict(memory_stats or {}, raw_cost_flops_global=raw_flops,
                          raw_cost_bytes_global=raw_bytes),
        notes=notes,
    )


def roofline_fraction(r: RooflineReport) -> float:
    """MODEL_FLOPS-time over the dominant term: how close the compiled
    program is to the hardware bound if perfectly overlapped."""
    ideal = r.model_flops / (r.chips * PEAK_FLOPS)
    dom = max(r.t_compute, r.t_memory, r.t_collective)
    return ideal / dom if dom > 0 else 0.0


def save_report(path: str, report: RooflineReport):
    try:
        with open(path) as f:
            data = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        data = {}
    data[f"{report.arch}|{report.shape}|{report.mesh}"] = report.to_dict()
    with open(path, "w") as f:
        json.dump(data, f, indent=1)
