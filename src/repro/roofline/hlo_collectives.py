"""Post-SPMD HLO accounting: collectives, dot FLOPs, HBM traffic.

``compiled.as_text()`` is the per-device partitioned module.  Three
corrections a naive reading gets wrong:

1. **Trip weighting** — collectives/FLOPs/bytes inside ``while`` bodies
   (layer scans, attention chunk scans) execute once per trip; the parser
   builds the computation call graph, estimates trip counts from each
   loop condition's comparison constant, and multiplies.
2. **In-place slice ops** — dynamic-(update-)slice moves only the slice,
   not the buffer.
3. **Fusion slice-reads** — a fusion whose callee consumes a parameter
   only through dynamic-slice/slice reads only slice-sized bytes of that
   operand (scan xs/ys buffers); likewise a fusion whose root is a
   dynamic-update-slice writes only the update.

Per-collective transferred-bytes model (ring algorithms, per device):
  all-gather:        out_bytes * (g-1)/g      (out is the gathered buffer)
  all-reduce:        2 * bytes * (g-1)/g
  reduce-scatter:    out_bytes * (g-1)        (out is the scattered shard)
  all-to-all:        bytes * (g-1)/g
  collective-permute: bytes
where g = replica-group size parsed from the instruction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALL_RE = re.compile(r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"([\w\-]+)\(")

_MEM_OPS = {
    "fusion", "dot", "copy", "convert", "transpose", "broadcast", "reduce",
    "pad", "concatenate", "gather", "scatter", "dynamic-slice", "slice",
    "dynamic-update-slice", "sort", "iota", "reverse", "select", "add",
    "multiply", "subtract", "divide", "exponential", "log", "rsqrt", "tanh",
    "compare", "maximum", "minimum", "rng", "clamp", "custom-call", "reshape",
}


def _shape_bytes(text: str) -> int:
    """Sum bytes over all shapes in a result type (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str):
    m = _SHAPE_RE.search(text)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None
    return m.group(1), [int(d) for d in m.group(2).split(",") if d != ""]


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def _transfer_bytes(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(result_bytes) * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


def _operand_names(rhs: str, op: str) -> List[str]:
    m = re.search(rf"{re.escape(op)}(?:-start)?\(([^)]*)\)", rhs)
    if not m:
        return []
    return [
        t.strip().lstrip("%").split(" ")[-1].lstrip("%")
        for t in m.group(1).split(",")
        if t.strip()
    ]


@dataclasses.dataclass
class RawComp:
    name: str
    lines: List[Tuple[str, str, str, str]]  # (instr_name, op, type_text, rhs)
    shapes: Dict[str, str]  # instr name -> type text
    max_const: int = 1


@dataclasses.dataclass
class Computation:
    name: str
    collectives: List[Tuple[str, float, int]] = dataclasses.field(default_factory=list)
    calls: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    while_pairs: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_const: int = 1
    flops: float = 0.0
    mem_bytes: float = 0.0


def _parse_raw(hlo: str) -> Dict[str, RawComp]:
    comps: Dict[str, RawComp] = {}
    cur: Optional[RawComp] = None
    for raw in hlo.splitlines():
        line = raw.strip()
        hdr = _COMP_HDR_RE.match(raw) if (raw and not raw.startswith(" ")) else None
        if hdr and "{" in raw:
            cur = RawComp(hdr.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None or not line or line.startswith("}") or line.startswith("//"):
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        name = name.lstrip("%")
        if name.startswith("ROOT"):
            name = name.split()[-1].lstrip("%")
        mop = _OP_RE.search(rhs)
        op = mop.group(1) if mop else ""
        type_text = rhs[: mop.start()] if mop else rhs
        cur.shapes[name] = type_text
        cur.lines.append((name, op, type_text, rhs))
        mc = re.search(r"constant\((\d+)\)", line)
        if mc:
            cur.max_const = max(cur.max_const, int(mc.group(1)))
    return comps


def _root_instr(rc: RawComp) -> Optional[Tuple[str, str, str, str]]:
    return rc.lines[-1] if rc.lines else None


def _param_access_bytes(rc: RawComp, param_idx: int, full_bytes: int) -> int:
    """Bytes a fusion actually reads of operand ``param_idx``: if the
    callee consumes the parameter only via (dynamic-)slice, count the
    slice results; else the full operand."""
    pname = None
    for name, op, type_text, rhs in rc.lines:
        if op == "parameter" and rhs.rstrip().endswith(f"parameter({param_idx})"):
            pname = name
            break
    if pname is None:
        return full_bytes
    consumers = []
    for name, op, type_text, rhs in rc.lines:
        if op == "parameter":
            continue
        if re.search(rf"%{re.escape(pname)}\b", rhs):
            consumers.append((op, type_text))
    if not consumers:
        return 0
    if all(op in ("dynamic-slice", "slice", "gather") for op, _ in consumers):
        return sum(_shape_bytes(t) for _, t in consumers)
    if all(op == "dynamic-update-slice" for op, _ in consumers):
        return 0  # pass-through buffer being updated in place
    return full_bytes


def parse_computations(hlo: str) -> Dict[str, Computation]:
    raw = _parse_raw(hlo)
    comps: Dict[str, Computation] = {}

    for rname, rc in raw.items():
        c = Computation(rname, max_const=rc.max_const)
        comps[rname] = c
        for name, op, type_text, rhs in rc.lines:
            # while loops
            if op == "while" or re.search(r"\bwhile\(", rhs):
                body = re.search(r"body=%?([\w\.\-]+)", rhs)
                cond = re.search(r"condition=%?([\w\.\-]+)", rhs)
                if body and cond:
                    c.while_pairs.append((body.group(1), cond.group(1)))
                continue
            # collectives
            matched = False
            for kind in _COLL_KINDS:
                if re.search(rf"\b{kind}(?:-start)?\(", rhs) and f"{kind}-done" not in rhs:
                    b = _shape_bytes(type_text)
                    c.collectives.append((kind, float(b), _group_size(rhs)))
                    c.mem_bytes += b
                    matched = True
                    break
                if f"{kind}-done" in rhs:
                    matched = True
                    break
            if matched:
                continue
            # dot flops
            if op == "dot":
                res = _shape_dims(type_text)
                opnames = _operand_names(rhs, "dot")
                mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
                if res and opnames and mcd and opnames[0] in rc.shapes:
                    lhs = _shape_dims(rc.shapes[opnames[0]])
                    if lhs:
                        csize = 1
                        for d in [int(x) for x in mcd.group(1).split(",") if x]:
                            if d < len(lhs[1]):
                                csize *= lhs[1][d]
                        rsize = 1
                        for d in res[1]:
                            rsize *= d
                        c.flops += 2.0 * rsize * csize
            # calls (fusion callees handled inline below; whiles above)
            if "body=" not in rhs and "condition=" not in rhs:
                for m in _CALL_RE.finditer(rhs):
                    c.calls.append((m.group(1), "call"))

            # memory accounting
            if op not in _MEM_OPS:
                continue
            if op in ("dynamic-slice", "slice"):
                c.mem_bytes += 2 * _shape_bytes(type_text)
            elif op == "dynamic-update-slice":
                opnames = _operand_names(rhs, op)
                if len(opnames) >= 2 and opnames[1] in rc.shapes:
                    c.mem_bytes += 2 * _shape_bytes(rc.shapes[opnames[1]])
            elif op == "fusion":
                callee_m = re.search(r"calls=%?([\w\.\-]+)", rhs)
                callee = raw.get(callee_m.group(1)) if callee_m else None
                opnames = _operand_names(rhs, op)
                total = 0
                for i, nm in enumerate(opnames):
                    full = _shape_bytes(rc.shapes.get(nm, ""))
                    total += _param_access_bytes(callee, i, full) if callee else full
                # result: DUS-rooted fusions write only the update
                root = _root_instr(callee) if callee else None
                if root is not None and root[1] == "dynamic-update-slice":
                    upd_ops = _operand_names(root[3], "dynamic-update-slice")
                    if len(upd_ops) >= 2 and callee and upd_ops[1] in callee.shapes:
                        total += _shape_bytes(callee.shapes[upd_ops[1]])
                    else:
                        total += _shape_bytes(type_text)
                else:
                    total += _shape_bytes(type_text)
                c.mem_bytes += total
            else:
                b = _shape_bytes(type_text)
                for nm in _operand_names(rhs, op):
                    if nm in rc.shapes:
                        b += _shape_bytes(rc.shapes[nm])
                c.mem_bytes += b
    return comps


def analyze_hlo(hlo: str) -> Dict[str, float]:
    """Trip-count-weighted per-device totals: collective transferred bytes
    (by kind + '_total'), dot FLOPs ('_flops'), approx HBM traffic
    ('_mem_bytes').  Needed because ``compiled.cost_analysis()`` counts
    while bodies ONCE, undercounting scanned layer stacks by ~n_layers."""
    comps = parse_computations(hlo)
    referenced = set()
    for c in comps.values():
        for callee, _ in c.calls:
            referenced.add(callee)
        for b, cond in c.while_pairs:
            referenced.add(b)
            referenced.add(cond)
    roots = [n for n in comps if n not in referenced]
    totals: Dict[str, float] = {k: 0.0 for k in _COLL_KINDS}
    totals["_flops"] = 0.0
    totals["_mem_bytes"] = 0.0

    def visit(name: str, mult: float, depth=0):
        if name not in comps or depth > 50:
            return
        c = comps[name]
        for kind, b, g in c.collectives:
            totals[kind] += mult * _transfer_bytes(kind, int(b), g)
        totals["_flops"] += mult * c.flops
        totals["_mem_bytes"] += mult * c.mem_bytes
        for callee, _ in c.calls:
            if "fused" in callee:  # fusion internals never touch HBM
                continue
            visit(callee, mult, depth + 1)
        for body, cond in c.while_pairs:
            trips = comps[cond].max_const if cond in comps else 1
            visit(body, mult * max(trips, 1), depth + 1)

    for r in roots:
        visit(r, 1.0)
    totals["_total"] = sum(totals[k] for k in _COLL_KINDS)
    return totals


def collective_bytes_per_device(hlo: str) -> Dict[str, float]:
    return analyze_hlo(hlo)


def collective_op_counts(hlo: str) -> Dict[str, int]:
    """Static instruction counts (no trip weighting) — for reports."""
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo.splitlines():
        for kind in _COLL_KINDS:
            if re.search(rf"=.*\b{kind}(?:-start)?\(", line):
                if f"{kind}-done" not in line:
                    counts[kind] += 1
                break
    return counts
