"""Sharded candidate-axis DPP rerank — one slate over millions of candidates.

Same contract as ``repro.serving.reranker.rerank`` but the candidate
axis M is sharded over ``cfg.mesh``'s ``cfg.axis_name``:

* the top-C shortlist is a **sharded top-k** (local top-k per shard,
  one small all-gather merge) that produces a selectable *mask* over
  the full candidate axis — features are never gathered into a dense
  (C, D) shortlist in shortlist order;
* greedy MAP runs through ``repro.core.sharded.dpp_greedy_sharded``:
  each device computes on only its (D, M/P) column shard of the scaled
  feature matrix ``V`` and its slice of the Cholesky ring state, with
  one tiny argmax-allreduce + winner-broadcast per step.

The host-side front end still assembles the full (D, M) ``V`` once
before resharding (fine for host-memory-sized M; per-shard feature
feeds are a ROADMAP item) — the O(M)-per-device scaling claim is about
the per-step compute and device state, not host staging memory.

The returned indices are global ids into the original M, identical to
what the single-device ``rerank`` would select on the same inputs
(same argmax sequence; see ``repro.core.sharded``) — up to argmax ties
between *exactly* float-equal marginal gains of distinct items, where
the single-device path breaks by score-sorted shortlist position and
this path by lowest global index (measure-zero on continuous scores).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.kernel_matrix import map_relevance
from repro.core.sharded import dpp_greedy_sharded, sharded_topk


def sharded_rerank(
    scores: jnp.ndarray,
    feats: jnp.ndarray,
    cfg,
    mask: Optional[jnp.ndarray] = None,
):
    """scores (M,), feats (M, D) -> (slate (N,) int32 global ids, d_hist (N,)).

    ``cfg`` is a ``DPPRerankConfig`` with ``mesh`` set; ``mask`` (M,)
    bool excludes candidates from both the shortlist and the slate.
    """
    if cfg.mesh is None:
        raise ValueError("sharded_rerank needs cfg.mesh (see DPPRerankConfig)")
    if scores.ndim != 1:
        raise ValueError(
            "sharded_rerank takes a single request (scores (M,)); user "
            "batching composes at the caller (see ROADMAP)"
        )
    M = scores.shape[0]
    C = min(cfg.shortlist, M)
    smask = mask
    if C < M:
        s = scores if mask is None else jnp.where(
            mask, scores, jnp.finfo(scores.dtype).min
        )
        _, top_i = sharded_topk(s, C, mesh=cfg.mesh, axis_name=cfg.axis_name)
        shortlisted = jnp.zeros((M,), bool).at[top_i].set(True)
        smask = shortlisted if mask is None else shortlisted & mask
    V = (feats * map_relevance(scores.astype(jnp.float32), cfg.alpha)[:, None]).T
    res = dpp_greedy_sharded(
        V,
        cfg.slate_size,
        mesh=cfg.mesh,
        axis_name=cfg.axis_name,
        window=cfg.window,
        eps=cfg.eps,
        mask=smask,
    )
    return res.indices.astype(jnp.int32), res.d_hist
