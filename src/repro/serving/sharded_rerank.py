"""Sharded candidate-axis DPP rerank — slates over millions of candidates.

Same contract as the single-device ``Reranker.rerank`` dispatch but the
candidate axis M is sharded over ``cfg.mesh``'s ``cfg.axis_name``
(``Reranker`` routes here automatically when ``cfg.mesh`` is set):

* the top-C shortlist is a **sharded top-k** (local top-k per shard,
  one small all-gather merge) that produces a selectable *mask* over
  the full candidate axis — features are never gathered into a dense
  (C, D) shortlist in shortlist order;
* greedy MAP runs through ``repro.core.sharded.dpp_greedy_sharded``:
  each device computes on only its (D, M/P) column shard of the scaled
  feature matrix ``V`` and its slice of the Cholesky ring state, with
  one tiny argmax-allreduce + winner-broadcast per step; with
  ``cfg.tile_m`` set the per-device update streams through the tiled
  Pallas pass (``repro.kernels.dpp_greedy.tiled``), so even M/P shards
  past the VMEM budget stay on the kernel path.

A request batch of B users shares the mesh: ``scores (B, M)`` (features
per-user ``(B, M, D)`` or shared ``(M, D)``) keeps the candidate axis
sharded, the shortlist becomes one batched sharded top-k, and the greedy
loop state grows a leading B axis per device — the per-step collectives
move B values at once instead of running B sequential single-slate
calls.

The host-side front end still assembles the full (D, M) ``V`` once
before resharding (fine for host-memory-sized M; per-shard feature
feeds are a ROADMAP item) — the O(M)-per-device scaling claim is about
the per-step compute and device state, not host staging memory.

The returned indices are global ids into the original M, identical to
what the single-device ``Reranker.rerank`` (or a ``vmap`` of it) would
select on the same inputs (same argmax sequence; see ``repro.core.sharded``) —
up to argmax ties between *exactly* float-equal marginal gains of
distinct items, where the single-device path breaks by score-sorted
shortlist position and this path by lowest global index (measure-zero
on continuous scores).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.kernel_matrix import map_relevance
from repro.core.sharded import sharded_topk


def _sharded_kernel(scores, feats, cfg, mask):
    """Sharded shortlist mask + scaled-feature kernel build — shared by
    the whole-slate ``Reranker.rerank`` dispatch and the chunk-emitting
    ``Reranker.stream`` / router admission paths so every consumer
    diversifies the identical V.
    Returns ``(V (..., D, M), selectability mask or None)``."""
    if cfg.mesh is None:
        raise ValueError(
            "the sharded rerank path needs cfg.mesh (see DPPRerankConfig)"
        )
    if scores.ndim not in (1, 2):
        raise ValueError(
            f"sharded rerank takes scores (M,) or a user batch (B, M), "
            f"got ndim={scores.ndim}"
        )
    batched = scores.ndim == 2
    if feats.ndim != 2 and not (batched and feats.ndim == 3):
        raise ValueError(
            f"feats must be (M, D) (shared) or, with batched scores, "
            f"per-user (B, M, D); got feats ndim={feats.ndim} with "
            f"scores ndim={scores.ndim}"
        )
    if mask is not None and mask.ndim != 1 and not (batched and mask.ndim == 2):
        raise ValueError(
            f"mask must be (M,) (shared) or, with batched scores, "
            f"per-user (B, M); got mask ndim={mask.ndim} with "
            f"scores ndim={scores.ndim}"
        )
    M = scores.shape[-1]
    C = min(cfg.shortlist, M)
    smask = mask
    if C < M:
        s = scores if mask is None else jnp.where(
            mask, scores, jnp.finfo(scores.dtype).min
        )
        _, top_i = sharded_topk(s, C, mesh=cfg.mesh, axis_name=cfg.axis_name)
        if batched:
            B = scores.shape[0]
            shortlisted = (
                jnp.zeros((B, M), bool).at[jnp.arange(B)[:, None], top_i].set(True)
            )
        else:
            shortlisted = jnp.zeros((M,), bool).at[top_i].set(True)
        smask = shortlisted if mask is None else shortlisted & mask
    rel = map_relevance(scores.astype(jnp.float32), cfg.alpha)
    if smask is not None:
        # non-selectable items (user-masked or shortlisted out) can never
        # enter the slate, but their raw scores still scale columns of V
        # — a NaN/inf relevance on such an item would poison the per-step
        # matvec for everyone.  Zero every column the single-device
        # rerank would never even build (it only gathers the shortlist).
        rel = jnp.where(smask, rel, 0.0)
    if batched and feats.ndim == 2:
        feats = feats[None]  # shared features broadcast over the batch
    V = jnp.swapaxes(feats * rel[..., None], -1, -2)  # (..., D, M)
    return V, smask
