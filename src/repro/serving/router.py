"""Continuous-batching rerank router — heterogeneous live requests on
one slot-batched greedy state.

The whole-slate and streaming serving paths run one compiled program
per request (or per uniform batch that must arrive together).  A live
reranker sees neither shape: requests with different candidate counts,
slate lengths and masks arrive at different times, and a slate that
eps-stops after 7 picks should hand its device lane to the next request
immediately, not idle until its neighbours finish.  This router serves
that shape the way LLM servers batch token generation continuously:

* a fixed micro-batch of ``slots`` lanes advances ``chunk_size`` greedy
  steps per cycle through **one** batched chunk call
  (``repro.core.streaming.greedy_chunk_slots``) — the per-slot step
  counter ``t (S,)`` lets every lane sit at its own depth;
* requests are padded into a common bucket: the candidate axis to
  ``max_candidates`` columns (padding masked to -inf, which argmax can
  never pick, so slates are index-for-index those of a per-request
  ``rerank``) and the slot Cholesky capacity to ``max_slate`` rows —
  per-request k, mask and progress live in data and host-side loop
  bounds, so admission never recompiles;
* completed, eps-stopped and deadline-expired lanes are evicted
  (``state_evict``) and refilled from a bounded FIFO admission queue
  (``state_splice``) between cycles;
* the pump is **double-buffered on JAX async dispatch**: each cycle
  syncs only the previous chunk's tiny ``stopped`` flags, decides
  evictions/admissions, *launches the next chunk* (returns immediately),
  and only then materializes the previous chunk's selections for
  delivery — host-side trimming and id-mapping overlap with the device
  computing the next chunk.

The pump is synchronous and caller-driven: ``submit`` enqueues and
returns a :class:`SlateHandle`; ``pump()`` advances the world one
cycle; ``handle.result()`` pumps until that request finishes.  Requests
past ``max_queue`` are refused with :class:`RouterQueueFull`
(backpressure), admission is strictly FIFO (no starvation), and a
request whose ``deadline`` lapses is evicted with its partial slate and
``timed_out=True``.

**Observability.**  The router's counters live in a
``repro.obs.MetricsRegistry`` — the process-global one when an
observability session is installed (``RouterConfig.obs`` /
``DPPRerankConfig.obs`` install it at construction), else a private
per-router registry — labeled ``router="rN"`` so concurrent routers
never mix.  :class:`RouterStats` is a *view* built from those metrics:
``router.stats`` and the per-pump ``RouterConfig.metrics_hook``
snapshot keep their exact pre-registry shape (fields, ``fill_ratio``,
``mean_ttfc``), so existing hooks work unchanged.  A hook that raises
is logged and counted (``router_hook_errors_total``), never fatal.
Every ``pump()`` emits a ``router.pump`` span decomposed into
``.sync`` / ``.evict`` / ``.admit`` / ``.launch`` / ``.materialize``
child spans (see DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import itertools
import logging
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro import obs
from repro.core.streaming import (
    greedy_chunk_slots,
    greedy_slot_state,
    greedy_slots_init,
    slot_pad_v,
    state_evict,
    state_splice,
)
from repro.obs import MetricsRegistry, ObsConfig
from repro.serving.reranker import DPPRerankConfig, _shortlist_kernel

_log = logging.getLogger(__name__)

# router="rN" label values; one registry can host many routers
_ROUTER_IDS = itertools.count()


class RouterQueueFull(RuntimeError):
    """The admission queue is at ``max_queue`` — resubmit after pumping."""


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Router shape: the micro-batch geometry and admission policy.

    ``max_slate`` is the slot capacity (every lane shares one Cholesky
    geometry; a request's own k only bounds how much of it is consumed)
    and ``max_candidates`` the padded candidate bucket each request's
    shortlist lands in — both default to the session config's
    ``slate_size`` / ``shortlist``.
    """

    slots: int = 4
    max_queue: int = 32
    chunk_size: int = 8
    max_slate: Optional[int] = None  # slot capacity; None -> cfg.slate_size
    max_candidates: Optional[int] = None  # bucket width; None -> cfg.shortlist
    metrics_hook: Optional[Callable[["RouterStats"], None]] = None
    obs: Optional[ObsConfig] = None  # installed at router construction

    def __post_init__(self):
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {self.max_queue}")
        if self.chunk_size < 1:
            raise ValueError(
                f"chunk_size must be >= 1, got {self.chunk_size}"
            )
        if self.max_slate is not None and self.max_slate < 1:
            raise ValueError(f"max_slate must be >= 1, got {self.max_slate}")
        if self.max_candidates is not None and self.max_candidates < 1:
            raise ValueError(
                f"max_candidates must be >= 1, got {self.max_candidates}"
            )


@dataclasses.dataclass
class RouterStats:
    """Counters (monotonic) and gauges (last pump) for the router.

    Since the metrics-registry refactor this is a *value object* built
    on demand from the router's labeled metrics (``router.stats`` /
    the ``metrics_hook`` snapshot) — same fields and derived
    properties as when it was the storage itself."""

    submitted: int = 0
    admitted: int = 0
    completed: int = 0
    eps_stopped: int = 0
    timed_out: int = 0
    rejected: int = 0
    chunks_launched: int = 0
    lane_steps_active: int = 0  # occupied-lane steps launched
    lane_steps_total: int = 0  # all-lane steps launched (active + parked)
    queue_depth: int = 0  # gauge
    slot_occupancy: int = 0  # gauge
    ttfc_sum: float = 0.0
    ttfc_count: int = 0

    @property
    def fill_ratio(self) -> float:
        """Occupied fraction of launched lane-steps — the continuous-
        batching payoff metric (1.0 = no lane ever idles)."""
        if self.lane_steps_total == 0:
            return 0.0
        return self.lane_steps_active / self.lane_steps_total

    @property
    def mean_ttfc(self) -> float:
        """Mean seconds from submit to the first delivered chunk."""
        if self.ttfc_count == 0:
            return 0.0
        return self.ttfc_sum / self.ttfc_count

    def snapshot(self) -> "RouterStats":
        return dataclasses.replace(self)


class SlateHandle:
    """One submitted request's future slate.

    ``result()`` pumps the owning router until this request finishes and
    returns ``(indices, d_hist)`` — global ids into the request's own
    candidate axis, length k with -1/0 fill past an eps-stop, or the
    shorter partial slate with ``timed_out=True`` after a deadline
    eviction.  ``ttfc`` is the seconds from submit to the first chunk.
    """

    def __init__(self, router: "RerankRouter", rid, k: int,
                 dtype=np.float32):
        self.rid = rid
        self.timed_out = False
        self.ttfc: Optional[float] = None
        self._router = router
        self._k = k
        self._dt = np.dtype(dtype)
        self._done = False
        self._idx: List[np.ndarray] = []
        self._dh: List[np.ndarray] = []

    @property
    def done(self) -> bool:
        return self._done

    @property
    def delivered(self) -> int:
        return sum(len(c) for c in self._idx)

    def result(self) -> Tuple[np.ndarray, np.ndarray]:
        while not self._done:
            self._router.pump()
        return self.slate()

    def slate(self) -> Tuple[np.ndarray, np.ndarray]:
        """The chunks delivered so far (the full slate once ``done``)."""
        idx = (
            np.concatenate(self._idx) if self._idx
            else np.zeros((0,), np.int32)
        )
        dh = (
            np.concatenate(self._dh) if self._dh
            else np.zeros((0,), self._dt)
        )
        return idx.astype(np.int32), dh.astype(self._dt)

    # router-side delivery ---------------------------------------------------

    def _deliver(self, idx: np.ndarray, dh: np.ndarray, now: float,
                 submit_t: float):
        if self.ttfc is None:
            self.ttfc = now - submit_t
        self._idx.append(idx)
        self._dh.append(dh)

    def _finish(self, timed_out: bool):
        if not timed_out:
            # the whole-slate contract: length k, -1/0 fill after a stop
            short = self._k - self.delivered
            if short > 0:
                self._idx.append(np.full((short,), -1, np.int32))
                self._dh.append(np.zeros((short,), self._dt))
        self.timed_out = timed_out
        self._done = True


class _Live:
    """Router-internal per-request record (queued or in a slot)."""

    __slots__ = (
        "req", "handle", "k", "top_i", "submit_t", "deadline_at", "count",
    )

    def __init__(self, req, handle, k, submit_t, deadline_at):
        self.req = req
        self.handle = handle
        self.k = k
        self.top_i: Optional[np.ndarray] = None  # set at admission
        self.submit_t = submit_t
        self.deadline_at = deadline_at
        self.count = 0  # selections delivered so far


class RerankRouter:
    """Continuous-batching executor over one ``DPPRerankConfig`` session.

    See the module docstring for the serving model.  Construction is
    cheap; the slot-batched device state is allocated lazily at the
    first admission (when the feature dimension is known).
    """

    def __init__(self, cfg: DPPRerankConfig,
                 router_config: Optional[RouterConfig] = None):
        self.cfg = cfg
        self.rcfg = router_config or RouterConfig()
        self.capacity = (
            self.rcfg.max_slate if self.rcfg.max_slate is not None
            else cfg.slate_size
        )
        self.bucket = (
            self.rcfg.max_candidates if self.rcfg.max_candidates is not None
            else cfg.shortlist
        )
        self.chunk = self.rcfg.chunk_size
        # one spec for every lane: k is the slot capacity
        self.spec = dataclasses.replace(
            cfg, slate_size=self.capacity
        ).greedy_spec()
        # observability: thread the config through (enabled=False and
        # None are both no-ops); publish into the global registry when a
        # session is installed, else into a private one, labeled with a
        # per-router id so concurrent routers never mix counters
        ocfg = self.rcfg.obs if self.rcfg.obs is not None else cfg.obs
        if ocfg is not None:
            obs.enable(ocfg)
        self._reg: MetricsRegistry = obs.registry() or MetricsRegistry()
        self._rid_label = f"r{next(_ROUTER_IDS)}"
        self._queue: Deque[_Live] = deque()
        self._active: Dict[int, _Live] = {}
        self._free: List[int] = list(range(self.rcfg.slots))
        self._state = None  # slot-batched GreedyState (lazy)
        self._V = None  # (S, D*, M*) stacked kernel operand (lazy)
        self._D: Optional[int] = None  # session feature dim (first submit)
        self._dtype = None  # resident slot dtype (first submit)
        self._inflight = None  # (state, sel, dh) of the launched chunk

    # -- metrics -------------------------------------------------------------

    def _count(self, event: str, n: int = 1) -> None:
        self._reg.counter(
            "router_requests_total",
            "request lifecycle events through the router",
        ).inc(n, router=self._rid_label, event=event)

    def _gauge(self, name: str, value: float, help: str = "") -> None:
        self._reg.gauge(name, help).set(value, router=self._rid_label)

    @property
    def stats(self) -> RouterStats:
        """The serving counters and gauges as a :class:`RouterStats`
        value object — a fresh snapshot on every read, built from this
        router's labeled metrics."""
        reg, rid = self._reg, self._rid_label
        ev = reg.counter("router_requests_total")
        lanes = reg.counter("router_lane_steps_total")
        ttfc = reg.histogram("router_ttfc_seconds")
        return RouterStats(
            submitted=int(ev.value(router=rid, event="submitted")),
            admitted=int(ev.value(router=rid, event="admitted")),
            completed=int(ev.value(router=rid, event="completed")),
            eps_stopped=int(ev.value(router=rid, event="eps_stopped")),
            timed_out=int(ev.value(router=rid, event="timed_out")),
            rejected=int(ev.value(router=rid, event="rejected")),
            chunks_launched=int(
                reg.counter("router_chunks_launched_total").value(router=rid)
            ),
            lane_steps_active=int(lanes.value(router=rid, lanes="active")),
            lane_steps_total=int(lanes.value(router=rid, lanes="all")),
            queue_depth=int(reg.gauge("router_queue_depth").value(router=rid)),
            slot_occupancy=int(
                reg.gauge("router_slot_occupancy").value(router=rid)
            ),
            ttfc_sum=ttfc.sum(router=rid),
            ttfc_count=ttfc.count(router=rid),
        )

    # -- admission -----------------------------------------------------------

    def submit(self, req) -> SlateHandle:
        """Enqueue one single-user :class:`RerankRequest`; returns its
        handle immediately.  Raises :class:`RouterQueueFull` past
        ``max_queue`` (backpressure) and ``ValueError`` for requests the
        router's bucket can never hold — both before enqueueing, so a
        refused request costs nothing."""
        if req.batched:
            raise ValueError(
                "the router serves single requests (scores (M,)); submit "
                "each user separately — they share the micro-batch"
            )
        k = req.slate_size if req.slate_size is not None else self.cfg.slate_size
        if k > self.capacity:
            raise ValueError(
                f"slate_size {k} exceeds the router's slot capacity "
                f"{self.capacity} (RouterConfig.max_slate)"
            )
        shortlist = (
            req.shortlist if req.shortlist is not None else self.cfg.shortlist
        )
        width = (
            req.num_candidates if self.cfg.mesh is not None  # sharded: full M
            else min(shortlist, req.num_candidates)
        )
        if width > self.bucket:
            raise ValueError(
                f"request needs {width} candidate columns, over the "
                f"router's bucket {self.bucket} (RouterConfig.max_candidates)"
            )
        D = np.shape(req.feats)[-1]
        if self._D is None:
            self._D = D
        elif D != self._D:
            raise ValueError(
                f"feature dim {D} != the session's {self._D} — one router "
                f"serves one model"
            )
        # the dtype the shortlist kernel will actually emit for these
        # feats (f32 relevance weights promote bf16/f16 feats to f32;
        # f64 survives under x64) — the resident slot batch must be
        # built in it, or state_splice's leaf-wise astype silently
        # rounds every lane through float32
        feats_dt = getattr(req.feats, "dtype", None)
        dt = np.result_type(
            np.float32 if feats_dt is None else feats_dt, np.float32
        )
        if self._dtype is None:
            self._dtype = dt
        elif dt != self._dtype:
            raise ValueError(
                f"feats dtype maps to resident dtype {dt}, but the "
                f"session serves {self._dtype} — one router serves one "
                f"model (and one precision)"
            )
        if len(self._queue) >= self.rcfg.max_queue:
            self._count("rejected")
            raise RouterQueueFull(
                f"admission queue full ({self.rcfg.max_queue}); pump() "
                f"or consume handles before resubmitting"
            )
        now = time.monotonic()
        handle = SlateHandle(self, req.rid, k, dtype=self._dtype)
        live = _Live(
            req, handle, k, now,
            None if req.deadline is None else now + req.deadline,
        )
        self._queue.append(live)
        self._count("submitted")
        self._gauge("router_queue_depth", len(self._queue))
        return handle

    # -- request preparation -------------------------------------------------

    def _cfg_for(self, req) -> DPPRerankConfig:
        c = req.shortlist if req.shortlist is not None else self.cfg.shortlist
        if c == self.cfg.shortlist:
            return self.cfg
        return dataclasses.replace(self.cfg, shortlist=c)

    def _prep(self, live: _Live):
        """Host-side admission prep: shortlist, bucket padding, the
        single-request slot state.  Returns ``(single_state, V_lane)``."""
        req, cfg = live.req, self._cfg_for(live.req)
        if self.cfg.mesh is not None:
            from repro.serving.sharded_rerank import _sharded_kernel

            V, m = _sharded_kernel(req.scores, req.feats, cfg, req.mask)
            live.top_i = None  # sharded ids are already global
        else:
            V, m, top_i = _shortlist_kernel(req.scores, req.feats, cfg,
                                            req.mask)
            live.top_i = np.asarray(top_i)
        width = V.shape[-1]
        if m is None:
            m = jnp.ones((width,), bool)
        if width < self.bucket:
            pad = self.bucket - width
            V = jnp.pad(V, ((0, 0), (0, pad)))
            m = jnp.pad(m, (0, pad))  # padding is never selectable
        single = greedy_slot_state(self.spec, V, mask=m, dtype=self._dtype)
        return single, slot_pad_v(self.spec, V.astype(self._dtype), single)

    def _admit(self, now: float):
        """FIFO admission into free slots; expired queued requests are
        finished (empty partial, timed_out) without ever occupying one."""
        while self._queue and self._free:
            live = self._queue.popleft()
            if live.deadline_at is not None and now > live.deadline_at:
                live.handle._finish(timed_out=True)
                self._count("timed_out")
                continue
            if self._state is None:
                self._state, self._V = greedy_slots_init(
                    self.spec, self.rcfg.slots, self._D, self.bucket,
                    dtype=self._dtype,
                )
            slot = self._free.pop()
            single, V_lane = self._prep(live)
            self._state = state_splice(self._state, single, slot)
            self._V = self._V.at[slot].set(V_lane)
            self._active[slot] = live
            self._count("admitted")

    # -- the pump ------------------------------------------------------------

    def _launch(self):
        if not self._active:
            return None
        rid = self._rid_label
        self._reg.counter(
            "router_chunks_launched_total", "batched chunk calls dispatched"
        ).inc(router=rid)
        self._reg.counter(
            "router_lane_steps_total",
            "greedy lane-steps launched (lanes=active: occupied lanes "
            "only; lanes=all: including parked lanes — the ratio is the "
            "batch fill)",
        ).inc(len(self._active) * self.chunk, router=rid, lanes="active")
        self._reg.counter("router_lane_steps_total").inc(
            self.rcfg.slots * self.chunk, router=rid, lanes="all"
        )
        return greedy_chunk_slots(self.spec, self._state, self._V, self.chunk)

    def _evict(self, slot: int):
        self._state = state_evict(self._state, slot)
        self._V = self._V.at[slot].set(0.0)
        del self._active[slot]
        self._free.append(slot)

    def pump(self):
        """One router cycle.

        Sync the previous chunk's stopped flags -> evict finished /
        eps-stopped / expired lanes -> admit from the queue -> launch
        the next chunk (async) -> materialize and deliver the previous
        chunk's selections while the device computes the next one.

        Each phase runs inside its own span (``router.pump.sync`` /
        ``.evict`` / ``.admit`` / ``.launch`` / ``.materialize``) under
        one ``router.pump`` parent, so a trace decomposes every cycle's
        latency; all spans are no-ops while observability is off.
        """
        with obs.span("router.pump"):
            now = time.monotonic()
            sel = dh = None
            deliveries: list = []
            evictions: List[int] = []
            if self._inflight is not None:
                st, sel, dh = self._inflight
                with obs.span("router.pump.sync"):
                    # the one device sync of the cycle: S bools
                    stopped = np.asarray(st.stopped)
                self._state = st
                for slot, live in sorted(self._active.items()):
                    consume = min(self.chunk, live.k - live.count)
                    lane_stopped = bool(stopped[slot])
                    expired = (
                        live.deadline_at is not None and now > live.deadline_at
                    )
                    complete = live.count + consume >= live.k
                    deliveries.append(
                        (slot, live, consume, lane_stopped, expired, complete)
                    )
                    if lane_stopped or expired or complete:
                        evictions.append(slot)
            with obs.span("router.pump.evict", lanes=len(evictions)):
                for slot in evictions:
                    self._evict(slot)
            with obs.span("router.pump.admit", queued=len(self._queue)):
                self._admit(now)
            with obs.span("router.pump.launch", lanes=len(self._active)):
                nxt = self._launch()  # async: device starts chunk N+1
            # ... while the host unpacks chunk N
            with obs.span("router.pump.materialize",
                          deliveries=len(deliveries)):
                if deliveries:
                    sel_np, dh_np = np.asarray(sel), np.asarray(dh)
                for slot, live, consume, lane_stopped, expired, complete in (
                        deliveries):
                    idx = sel_np[slot, :consume].astype(np.int32)
                    if live.top_i is not None:
                        idx = np.where(
                            idx >= 0, live.top_i[np.clip(idx, 0, None)], -1
                        ).astype(np.int32)
                    first = live.handle.ttfc is None
                    live.handle._deliver(
                        idx, dh_np[slot, :consume].astype(self._dtype),
                        time.monotonic(), live.submit_t,
                    )
                    if first and live.handle.ttfc is not None:
                        self._reg.histogram(
                            "router_ttfc_seconds",
                            "seconds from submit to the first delivered chunk",
                        ).observe(live.handle.ttfc, router=self._rid_label)
                    live.count += consume
                    if lane_stopped or complete:
                        live.handle._finish(timed_out=False)
                        self._count("completed")
                        if lane_stopped and not complete:
                            self._count("eps_stopped")
                    elif expired:
                        live.handle._finish(timed_out=True)
                        self._count("timed_out")
            self._inflight = nxt
            self._gauge(
                "router_queue_depth", len(self._queue),
                "requests waiting for admission",
            )
            self._gauge(
                "router_slot_occupancy", len(self._active),
                "slots holding a live request",
            )
            if self.rcfg.metrics_hook is not None:
                snap = self.stats
                try:
                    self.rcfg.metrics_hook(snap)
                except Exception:
                    # a broken hook must never take the serving loop down
                    _log.exception(
                        "RouterConfig.metrics_hook raised; continuing"
                    )
                    self._reg.counter(
                        "router_hook_errors_total",
                        "metrics_hook exceptions swallowed by pump()",
                    ).inc(router=self._rid_label)

    def drain(self, max_pumps: int = 100_000):
        """Pump until every queued and active request has finished."""
        pumps = 0
        while self._queue or self._active or self._inflight is not None:
            self.pump()
            pumps += 1
            if pumps > max_pumps:
                raise RuntimeError("router failed to drain (livelock?)")
