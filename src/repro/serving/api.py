"""The serving front door: one session object, one request object.

``Reranker(cfg)`` replaces the six-way function surface the serving
layer grew across PRs 1-5 (``rerank``, ``rerank_batch``,
``rerank_stream``, ``sharded_rerank``, ``sharded_rerank_stream``, plus
per-driver glue).  One session holds the model-side configuration — the
knobs that shape compiled computations (window, eps, backend, mesh,
tile_m, chunk_size, alpha) — and every call supplies a
:class:`RerankRequest` carrying the request-side knobs (slate length,
shortlist width, candidate mask, deadline).  The split is what lets the
continuous-batching router (``repro.serving.router``) vary k and mask
per live request without ever re-jitting: request knobs live in data
and host-side loop bounds, never in compiled statics.

Dispatch is by configuration and request shape, not by function name:

* ``cfg.mesh`` set          -> candidate-sharded SPMD paths;
* ``scores (B, M)``         -> the whole user batch on one mesh
                               (or a vmap of the single-device path);
* ``cfg.use_kernel``        -> Pallas kernels;
* otherwise                 -> the jnp reference path.

Methods::

    out = rr.rerank(req)              # whole slate(s), blocking
    for ids, dh in rr.stream(req):    # chunk-by-chunk emission
    handle = rr.submit(req)           # continuous-batching router
    handle.result()

``stream`` prepares eagerly: validation, the top-C shortlist, the
greedy state, and the kernel-operand padding all happen at call time —
once, O(M) — and each generator resume does only O(chunk) host-side
work (the previous serving generator re-entered validation per resume
and deferred the shortlist to the first ``next()``).

The legacy functions survived one release as ``DeprecationWarning``
shims and are now removed — this module is the only serving surface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.dispatch import greedy_map
from repro.serving.reranker import DPPRerankConfig, _shortlist_kernel


@dataclasses.dataclass(frozen=True)
class RerankRequest:
    """One rerank request: the data plus the request-side knobs.

    ``scores`` is ``(M,)`` (single) or ``(B, M)`` (user batch);
    ``feats`` is ``(M, D)`` — shared across a batch — or per-user
    ``(B, M, D)``.  ``slate_size`` / ``shortlist`` default to the
    session config's values; ``mask`` (``(M,)`` or ``(B, M)``) marks
    selectable candidates; ``deadline`` is a per-request latency budget
    in seconds, honoured by the router (timeout eviction returns the
    partial slate with ``timed_out=True``).  ``rid`` is an opaque
    caller tag echoed back on router handles.

    Validates at construction, like ``GreedySpec`` — a nonsensical
    request raises ``ValueError`` when it is built, not as a shape
    error inside a jitted serve step.
    """

    scores: Any
    feats: Any
    slate_size: Optional[int] = None
    shortlist: Optional[int] = None
    mask: Optional[Any] = None
    deadline: Optional[float] = None
    rid: Optional[Any] = None

    def __post_init__(self):
        if self.slate_size is not None and self.slate_size <= 0:
            raise ValueError(
                f"slate_size must be >= 1, got {self.slate_size}"
            )
        if self.shortlist is not None and self.shortlist <= 0:
            raise ValueError(f"shortlist must be >= 1, got {self.shortlist}")
        if self.deadline is not None and not self.deadline > 0:
            raise ValueError(
                f"deadline must be a positive seconds budget, got "
                f"{self.deadline}"
            )
        s_nd, f_nd = jnp.ndim(self.scores), jnp.ndim(self.feats)
        if s_nd not in (1, 2):
            raise ValueError(
                f"scores must be (M,) or a user batch (B, M), got "
                f"ndim={s_nd}"
            )
        if f_nd != 2 and not (s_nd == 2 and f_nd == 3):
            raise ValueError(
                f"feats must be (M, D) (shared) or, with batched scores, "
                f"per-user (B, M, D); got feats ndim={f_nd} with scores "
                f"ndim={s_nd}"
            )
        if self.mask is not None:
            m_nd = jnp.ndim(self.mask)
            if m_nd != 1 and not (s_nd == 2 and m_nd == 2):
                raise ValueError(
                    f"mask must be (M,) (shared) or, with batched scores, "
                    f"per-user (B, M); got mask ndim={m_nd} with scores "
                    f"ndim={s_nd}"
                )
        # one shared candidate axis M (and batch axis B) across all three
        # operands — caught here, at construction, instead of surfacing as
        # a shape error deep inside a jitted serve step
        M = jnp.shape(self.scores)[-1]
        f_shape = jnp.shape(self.feats)
        if f_shape[-2] != M:
            raise ValueError(
                f"scores and feats disagree on the candidate count: scores "
                f"carry M={M} candidates but feats "
                f"{tuple(f_shape)} carry {f_shape[-2]} — every operand "
                f"must share one M axis"
            )
        if s_nd == 2 and f_nd == 3 and f_shape[0] != jnp.shape(self.scores)[0]:
            raise ValueError(
                f"scores and feats disagree on the user batch: scores "
                f"carry B={jnp.shape(self.scores)[0]} users but feats "
                f"{tuple(f_shape)} carry {f_shape[0]}"
            )
        if self.mask is not None:
            m_shape = jnp.shape(self.mask)
            if m_shape[-1] != M:
                raise ValueError(
                    f"scores and mask disagree on the candidate count: "
                    f"scores carry M={M} candidates but mask "
                    f"{tuple(m_shape)} carries {m_shape[-1]} — every "
                    f"operand must share one M axis"
                )
            if len(m_shape) == 2 and m_shape[0] != jnp.shape(self.scores)[0]:
                raise ValueError(
                    f"scores and mask disagree on the user batch: scores "
                    f"carry B={jnp.shape(self.scores)[0]} users but mask "
                    f"{tuple(m_shape)} carries {m_shape[0]}"
                )

    @property
    def batched(self) -> bool:
        return jnp.ndim(self.scores) == 2

    @property
    def num_candidates(self) -> int:
        return jnp.shape(self.scores)[-1]


class Reranker:
    """A DPP rerank serving session.

    Holds one model-side :class:`DPPRerankConfig` and serves any number
    of :class:`RerankRequest`\\ s through three verbs — ``rerank``
    (whole slate, blocking), ``stream`` (chunk-emitting generator) and
    ``submit`` (continuous-batching router handle).  The compiled
    computations are keyed by the session config plus request *shapes*;
    request-side knobs (k, shortlist, mask, deadline) never force a
    recompile.
    """

    def __init__(self, cfg: DPPRerankConfig, router_config=None,
                 session_config=None):
        if not isinstance(cfg, DPPRerankConfig):
            raise TypeError(
                f"Reranker takes a DPPRerankConfig, got {type(cfg).__name__}"
            )
        self.cfg = cfg
        self._router_config = router_config
        self._router = None
        self._session_config = session_config
        self._sessions = None
        if cfg.obs is not None:  # enabled=False configs are a no-op
            obs.enable(cfg.obs)

    # -- request-side resolution -------------------------------------------

    def _cfg_for(self, req: RerankRequest) -> DPPRerankConfig:
        """The effective config for one request: the session's
        model-side knobs with the request's k / shortlist folded in."""
        k = req.slate_size if req.slate_size is not None else self.cfg.slate_size
        c = req.shortlist if req.shortlist is not None else self.cfg.shortlist
        if (k, c) == (self.cfg.slate_size, self.cfg.shortlist):
            return self.cfg
        return dataclasses.replace(self.cfg, slate_size=k, shortlist=c)

    @staticmethod
    def _as_request(req, kwargs) -> RerankRequest:
        if isinstance(req, RerankRequest):
            if kwargs:
                raise TypeError(
                    "pass request knobs inside the RerankRequest, not as "
                    f"keyword overrides: {sorted(kwargs)}"
                )
            return req
        raise TypeError(
            f"expected a RerankRequest, got {type(req).__name__}; build one "
            f"with RerankRequest(scores=..., feats=..., ...)"
        )

    # -- whole-slate -------------------------------------------------------

    def rerank(self, req: RerankRequest, **kwargs):
        """Whole-slate rerank: ``(indices, d_hist)``, shapes ``(N,)``
        single / ``(B, N)`` batched, global ids into the request's M
        (-1 after an eps-stop).  Dispatch: ``cfg.mesh`` -> sharded;
        batched scores -> the whole batch on the mesh, or a vmap of
        the single-device path."""
        req = self._as_request(req, kwargs)
        cfg = self._cfg_for(req)
        with obs.span(
            "serving.rerank", M=req.num_candidates, k=cfg.slate_size,
            batched=req.batched,
        ):
            if cfg.mesh is not None:
                from repro.serving.sharded_rerank import _sharded_kernel

                return _sharded_rerank_impl(
                    req.scores, req.feats, cfg, req.mask, _sharded_kernel
                )
            if req.batched:
                return _rerank_batch_impl(
                    req.scores, req.feats, cfg, req.mask
                )
            return _rerank_impl(req.scores, req.feats, cfg, req.mask)

    # -- chunked streaming -------------------------------------------------

    def stream(
        self, req: RerankRequest, chunk_size: Optional[int] = None, **kwargs
    ) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
        """Stream one request's slate as it is selected.

        Returns a generator of ``(indices (c,) int32 global ids,
        d_hist (c,))`` chunks whose concatenation is a prefix of
        ``rerank(req)`` (same shortlist, same greedy sequence) covering
        every real selection; the last chunk is short when ``chunk``
        does not divide the slate, and once an eps-stop surfaces (a -1
        tail slot) the generator ends instead of launching further
        all--1 chunks.  ``chunk_size`` overrides ``cfg.chunk_size``.

        Preparation — validation, the top-C shortlist, the resumable
        greedy state, the kernel-operand padding — happens *here*, not
        at the first ``next()``: the returned generator's resume path
        costs O(chunk) host-side, nothing O(M).
        """
        req = self._as_request(req, kwargs)
        cfg = self._cfg_for(req)
        if req.batched:
            raise ValueError(
                "stream serves a single request (scores (M,)); batch "
                "serving goes through rerank or the router"
            )
        from repro.core.streaming import (
            greedy_chunk,
            greedy_init,
            resolve_chunk,
            slot_pad_v,
        )

        spec = cfg.greedy_spec()
        chunk = resolve_chunk(
            spec, chunk_size if chunk_size is not None else cfg.chunk_size
        )
        with obs.span(
            "serving.stream.prep", M=req.num_candidates, k=cfg.slate_size,
            chunk=chunk,
        ):
            if cfg.mesh is not None:
                from repro.serving.sharded_rerank import _sharded_kernel

                V, m_sel = _sharded_kernel(
                    req.scores, req.feats, cfg, req.mask
                )
                top_i = None
            else:
                V, m_sel, top_i = _shortlist_kernel(
                    req.scores, req.feats, cfg, req.mask
                )
            state = greedy_init(spec, V=V, mask=m_sel)
            V = slot_pad_v(spec, V, state)

        def emit():
            done, st = 0, state
            while done < cfg.slate_size:
                c = min(chunk, cfg.slate_size - done)
                with obs.span("serving.stream.chunk", chunk=c, done=done):
                    st, sel, dh = greedy_chunk(spec, st, V=V, chunk_size=c)
                    if top_i is not None:
                        sel = jnp.where(sel >= 0, top_i[jnp.clip(sel, 0)], -1)
                sel = sel.astype(jnp.int32)
                yield sel, dh
                done += c
                # eps-stop latch: once a chunk's tail slot is -1 the state
                # is stopped and every further chunk would be a dead
                # dispatch emitting all -1s.  The yielded chunk is already
                # materialized host-side by the consumer's inspection of
                # it, so reading its last slot costs no extra device sync.
                if done < cfg.slate_size and int(sel.reshape(-1)[-1]) < 0:
                    break

        return emit()

    # -- session-aware incremental rerank ----------------------------------

    @property
    def sessions(self):
        """The session store (created lazily on first use; see
        ``repro.serving.session``): per-user windowed greedy states kept
        device-resident between scroll events under an LRU byte budget."""
        if self._sessions is None:
            from repro.serving.session import SessionConfig, SessionStore

            self._sessions = SessionStore(
                self.cfg, self._session_config or SessionConfig()
            )
        return self._sessions

    def session(self, req: RerankRequest, sid=None, **kwargs):
        """Open a :class:`~repro.serving.session.RerankSession` over one
        request's shortlist: ``next_chunk(n)`` emits the next ``n``
        items conditioned on everything the session has already shown
        (never replaying selected steps), ``extend`` / ``rescore``
        delta-update the candidate pool in O(w * dM), and the store
        evicts cold sessions to ``session_config.budget_bytes``
        (transparently rebuilt on the next touch).  ``sid`` names the
        session (auto-assigned when None); calling again with an
        existing ``sid`` resumes that session and ignores ``req``.
        Requires a windowed config (``cfg.window < slate_size``);
        single requests only.
        """
        req = self._as_request(req, kwargs)
        if sid is not None and sid in self.sessions:
            return self.sessions.get(sid)
        return self.sessions.create(req, sid=sid, cfg=self._cfg_for(req))

    # -- continuous batching -----------------------------------------------

    @property
    def router(self):
        """The session's continuous-batching router (created lazily on
        first use; see ``repro.serving.router``)."""
        if self._router is None:
            from repro.serving.router import RerankRouter, RouterConfig

            self._router = RerankRouter(
                self.cfg, self._router_config or RouterConfig()
            )
        return self._router

    def submit(self, req: RerankRequest, **kwargs):
        """Submit one request to the session's continuous-batching
        router; returns a ``SlateHandle`` immediately.  The request
        joins the shared micro-batch at the next free slot — call
        ``handle.result()`` (or pump the router) to drive it."""
        req = self._as_request(req, kwargs)
        return self.router.submit(req)


# ---------------------------------------------------------------------------
# Implementation bodies (module-level so every Reranker session shares
# the same jit caches)
# ---------------------------------------------------------------------------


def _rerank_impl(scores, feats, cfg, mask):
    if jnp.ndim(scores) != 1:
        raise ValueError(
            f"rerank takes a single request (scores (M,)), got "
            f"ndim={jnp.ndim(scores)}; batched scores dispatch through "
            f"Reranker.rerank"
        )
    V, m_top, top_i = _shortlist_kernel(scores, feats, cfg, mask)
    res = greedy_map(cfg.greedy_spec(), V=V, mask=m_top)
    sel, dh = res.indices, res.d_hist
    out = jnp.where(sel >= 0, top_i[jnp.clip(sel, 0)], -1)
    return out.astype(jnp.int32), dh


def _rerank_batch_impl(scores, feats, cfg, mask):
    if mask is not None and mask.ndim == 1:
        mask = jnp.broadcast_to(mask, scores.shape)
    f_ax = 0 if feats.ndim == 3 else None
    if mask is None:  # keep the unmasked hot path free of mask plumbing
        return jax.vmap(
            lambda s, f: _rerank_impl(s, f, cfg, None), in_axes=(0, f_ax)
        )(scores, feats)
    return jax.vmap(
        lambda s, f, m: _rerank_impl(s, f, cfg, m), in_axes=(0, f_ax, 0)
    )(scores, feats, mask)


def _sharded_rerank_impl(scores, feats, cfg, mask, sharded_kernel):
    from repro.core.sharded import dpp_greedy_sharded

    V, smask = sharded_kernel(scores, feats, cfg, mask)
    res = dpp_greedy_sharded(
        V,
        cfg.slate_size,
        mesh=cfg.mesh,
        axis_name=cfg.axis_name,
        window=cfg.window,
        eps=cfg.eps,
        mask=smask,
        tile_m=cfg.tile_m,
        interpret=cfg.interpret,
    )
    return res.indices.astype(jnp.int32), res.d_hist
