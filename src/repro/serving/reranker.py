"""DPP slate re-ranking as a first-class serving stage.

Any scorer that yields ``(relevance scores, item feature vectors)`` can be
diversified: shortlist the top-C candidates, build the implicit DPP
kernel ``L = Diag(a^r) F^T F Diag(a^r)`` over the shortlist, and run the
paper's fast greedy MAP (Algorithm 1) — all inside the jitted serve step.

All greedy variants are reached through ``repro.core.greedy_map``:

* ``use_kernel=True`` routes through the Pallas kernels (interpret-mode
  on CPU); the default jnp path lowers through XLA for the dry-run
  cells.  Shortlists whose working set fits VMEM run the resident
  whole-slate-in-VMEM kernel; past the budget the tiled streaming
  kernels take over (``TilePolicy`` — there is no silent jnp fallback
  at scale any more), and ``tile_m=`` pins the tile width explicitly.
* ``window=w`` enforces diversity only against the last ``w`` picks
  (the NeurIPS'18 sliding-window variant, O(w M) per step) so the
  serving path can produce long diversified feeds — slates longer than
  the kernel rank keep selecting instead of eps-stopping.
* ``mesh=`` (with ``axis_name=``) shards the candidate axis over a
  device mesh and delegates to ``repro.serving.sharded_rerank`` —
  slates drawn from a candidate set far larger than a single device
  holds, with a sharded top-k shortlist instead of ``jax.lax.top_k``.
  A batched request (scores ``(B, M)``) keeps the candidate axis
  sharded and runs the whole user batch on the mesh at once (batched
  shortlist, batched greedy loop state, one batched collective per
  step).
* ``mask=`` excludes candidates (already-seen / business-filtered
  items) before the shortlist and inside greedy selection; a masked
  item can never appear in the slate.
* ``Reranker.stream`` emits the slate **incrementally**: a generator
  yielding ``chunk_size``-item chunks (global ids + per-chunk d_hist)
  as the greedy loop produces them, instead of blocking until the
  whole slate is selected — the serving shape the paper's windowed
  variant exists for (repulsion only among nearby items means a long
  feed can start rendering after the first chunk).  Chunks concatenate
  exactly to the whole-slate result on every backend; with ``mesh=``
  the chunked state stays device-resident between chunks.

``DPPRerankConfig`` validates itself at construction (mirroring
``GreedySpec``): a nonsensical slate/shortlist/window/eps raises a
``ValueError`` when the config is built, not as a shape or trace error
deep inside the jitted serve step.

**History.** The function-per-shape surface this module grew
(``rerank`` / ``rerank_batch`` / ``rerank_stream``, plus the sharded
twins in ``repro.serving.sharded_rerank``) was superseded by the
session API in ``repro.serving.api`` — ``Reranker(cfg)`` with
``.rerank`` / ``.stream`` / ``.submit`` dispatching on the config and
the request shape.  The functions survived one release as
``DeprecationWarning`` shims and are now **removed** (pinned by
``tests/test_api.py::test_legacy_shims_are_removed``; the
``dead-shim`` rule of ``repro.analysis`` flags any straggler import).
This module keeps only the model-side config and the shortlist/kernel
builder the session API dispatches through.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.dispatch import GreedySpec
from repro.core.kernel_matrix import map_relevance
from repro.obs import ObsConfig


@dataclasses.dataclass(frozen=True)
class DPPRerankConfig:
    """Model-side serving configuration.

    These are the knobs that shape *compiled* computations — window,
    eps, backend selection (use_kernel / mesh / tile_m), chunk size,
    the relevance trade-off alpha.  The request-side knobs (slate
    length k, shortlist width, candidate mask, deadline) moved to
    ``repro.serving.api.RerankRequest``; the ``slate_size`` /
    ``shortlist`` fields kept here act as *session defaults* for
    requests that do not override them, so pre-split configs keep
    working unchanged.
    """

    slate_size: int = 50  # N (session default; RerankRequest overrides)
    shortlist: int = 1000  # C (session default; RerankRequest overrides)
    alpha: float = 4.0  # trade-off (paper eq. 21); 1.0 = pure diversity
    eps: float = 1e-3
    use_kernel: bool = False  # Pallas path (interpret on CPU)
    window: Optional[int] = None  # sliding diversity window (None = exact)
    mesh: Optional[object] = None  # shard the candidate axis over this mesh
    axis_name: str = "data"  # mesh axis carrying the candidate shards
    # Pallas candidate-axis tile: an explicit LANE multiple, "auto"
    # (measured autotune cache, model fallback), or None (VMEM model)
    tile_m: Union[int, str, None] = None
    interpret: bool = True  # Pallas interpret mode (False on real TPU)
    chunk_size: Optional[int] = None  # Reranker.stream emission granularity
    obs: Optional[ObsConfig] = None  # observability (installed by Reranker)

    def __post_init__(self):
        if self.slate_size <= 0:
            raise ValueError(f"slate_size must be >= 1, got {self.slate_size}")
        if self.shortlist <= 0:
            raise ValueError(f"shortlist must be >= 1, got {self.shortlist}")
        if self.window is not None and self.window <= 0:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.eps < 0:
            raise ValueError(f"eps must be >= 0, got {self.eps}")
        if self.chunk_size is not None and self.chunk_size <= 0:
            raise ValueError(
                f"chunk_size must be >= 1, got {self.chunk_size}"
            )
        if self.mesh is not None and self.use_kernel:
            raise ValueError(
                "use_kernel (Pallas) and mesh (sharded) are mutually "
                "exclusive rerank backends"
            )
        if self.tile_m is not None:
            from repro.kernels.dpp_greedy.tiling import validate_tile_m

            validate_tile_m(self.tile_m, allow_auto=True)
            if self.tile_m == "auto" and not self.use_kernel:
                raise ValueError(
                    'tile_m="auto" consults the measured autotune cache, '
                    "which only the Pallas kernels do — set "
                    "use_kernel=True (the jnp and sharded backends do "
                    "not consult the cache)"
                )
            if not self.use_kernel and self.mesh is None:
                raise ValueError(
                    'tile_m= (an int or "auto") tiles the Pallas kernels '
                    "— it needs use_kernel=True or mesh= (the jnp "
                    "backend would silently ignore it)"
                )

    def greedy_spec(self) -> GreedySpec:
        if self.mesh is not None:
            backend = "sharded"
        elif self.use_kernel:
            backend = "pallas"
        else:
            backend = "jnp"
        return GreedySpec(
            k=self.slate_size,
            window=self.window,
            backend=backend,
            eps=self.eps,
            mesh=self.mesh,
            axis_name=self.axis_name,
            tile_m=self.tile_m,
            interpret=self.interpret,
            # the jnp spec cannot carry a chunk size (its whole-slate
            # path would silently ignore it — GreedySpec rejects that);
            # Reranker.stream passes it to the chunk executor directly
            chunk_size=self.chunk_size if backend != "jnp" else None,
        )


def _shortlist_kernel(scores, feats, cfg, mask):
    """The top-C shortlist and its implicit DPP kernel — shared by the
    whole-slate ``Reranker.rerank`` and the chunk-emitting
    ``Reranker.stream`` so the two paths diversify the identical V.
    Returns
    ``(V (D, C), shortlist mask or None, top_i (C,) global ids)``."""
    C = min(cfg.shortlist, scores.shape[0])
    s = scores if mask is None else jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    top_s, top_i = jax.lax.top_k(s, C)
    f = feats[top_i]  # (C, D)
    rel = map_relevance(top_s.astype(jnp.float32), cfg.alpha)
    m_top = None if mask is None else mask[top_i]
    if m_top is not None:
        # the sentinel score only exists to rank masked items last; keep
        # it out of the kernel (alpha < 1 maps it to inf) — masked
        # columns are zeroed and excluded from selection by the mask
        rel = jnp.where(m_top, rel, 0.0)
    V = (f * rel[:, None]).T  # (D, C)
    return V, m_top, top_i
