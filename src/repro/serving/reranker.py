"""DPP slate re-ranking as a first-class serving stage (DESIGN.md §2, §5).

Any scorer that yields ``(relevance scores, item feature vectors)`` can be
diversified: shortlist the top-C candidates, build the implicit DPP
kernel ``L = Diag(a^r) F^T F Diag(a^r)`` over the shortlist, and run the
paper's fast greedy MAP (Algorithm 1) — all inside the jitted serve step.

All greedy variants are reached through ``repro.core.greedy_map``:

* ``use_kernel=True`` routes through the Pallas whole-slate-in-VMEM
  kernel (interpret-mode on CPU); the default jnp path lowers through
  XLA for the dry-run cells.
* ``window=w`` enforces diversity only against the last ``w`` picks
  (the NeurIPS'18 sliding-window variant, O(w M) per step) so the
  serving path can produce long diversified feeds — slates longer than
  the kernel rank keep selecting instead of eps-stopping.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import GreedySpec, greedy_map
from repro.core.kernel_matrix import map_relevance


@dataclasses.dataclass(frozen=True)
class DPPRerankConfig:
    slate_size: int = 50  # N
    shortlist: int = 1000  # C (the paper's "few hundreds pre-selected")
    alpha: float = 4.0  # trade-off (paper eq. 21); 1.0 = pure diversity
    eps: float = 1e-3
    use_kernel: bool = False  # Pallas path (interpret on CPU)
    window: Optional[int] = None  # sliding diversity window (None = exact)

    def greedy_spec(self) -> GreedySpec:
        return GreedySpec(
            k=self.slate_size,
            window=self.window,
            backend="pallas" if self.use_kernel else "jnp",
            eps=self.eps,
        )


def rerank(scores: jnp.ndarray, feats: jnp.ndarray, cfg: DPPRerankConfig):
    """scores (M,), feats (M, D) l2-normalized rows -> slate (N,) global ids.

    Returns (indices (N,) int32 into the original M, d_hist (N,)).
    """
    C = min(cfg.shortlist, scores.shape[0])
    top_s, top_i = jax.lax.top_k(scores, C)
    f = feats[top_i]  # (C, D)
    V = (f * map_relevance(top_s.astype(jnp.float32), cfg.alpha)[:, None]).T  # (D, C)
    res = greedy_map(cfg.greedy_spec(), V=V)
    sel, dh = res.indices, res.d_hist
    out = jnp.where(sel >= 0, top_i[jnp.clip(sel, 0)], -1)
    return out.astype(jnp.int32), dh


def rerank_batch(scores: jnp.ndarray, feats: jnp.ndarray, cfg: DPPRerankConfig):
    """scores (B, M), feats (B, M, D) or shared (M, D)."""
    if feats.ndim == 2:
        fn = lambda s: rerank(s, feats, cfg)
        return jax.vmap(fn)(scores)
    return jax.vmap(lambda s, f: rerank(s, f, cfg))(scores, feats)
