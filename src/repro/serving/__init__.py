from repro.serving.reranker import (
    DPPRerankConfig,
    rerank,
    rerank_batch,
    rerank_stream,
)
from repro.serving.sharded_rerank import sharded_rerank, sharded_rerank_stream
