"""DPP rerank serving.

Everything goes through the session API: ``Reranker(cfg)`` +
``RerankRequest`` (``repro.serving.api``) and, for continuous batching,
``RerankRouter`` (``repro.serving.router``).  The PR-6
function-per-shape surface (``rerank`` / ``rerank_batch`` /
``rerank_stream`` / ``sharded_rerank`` / ``sharded_rerank_stream``)
served its one-release ``DeprecationWarning`` grace period and is gone
(removal pinned by ``tests/test_api.py::test_legacy_shims_are_removed``).
"""
from repro.obs import ObsConfig
from repro.serving.api import Reranker, RerankRequest
from repro.serving.reranker import DPPRerankConfig
from repro.serving.router import (
    RerankRouter,
    RouterConfig,
    RouterStats,
    SlateHandle,
)
from repro.serving.session import RerankSession, SessionConfig, SessionStore

__all__ = [
    "DPPRerankConfig",
    "ObsConfig",
    "Reranker",
    "RerankRequest",
    "RerankRouter",
    "RerankSession",
    "RouterConfig",
    "RouterStats",
    "SessionConfig",
    "SessionStore",
    "SlateHandle",
]
