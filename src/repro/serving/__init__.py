from repro.serving.reranker import DPPRerankConfig, rerank, rerank_batch
