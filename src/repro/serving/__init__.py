from repro.serving.reranker import DPPRerankConfig, rerank, rerank_batch
from repro.serving.sharded_rerank import sharded_rerank
