"""DPP rerank serving.

New code goes through the session API: ``Reranker(cfg)`` +
``RerankRequest`` (``repro.serving.api``) and, for continuous batching,
``RerankRouter`` (``repro.serving.router``).  The function-per-shape
surface (``rerank`` / ``rerank_batch`` / ``rerank_stream`` /
``sharded_rerank`` / ``sharded_rerank_stream``) survives one release as
``DeprecationWarning`` shims.
"""
from repro.obs import ObsConfig
from repro.serving.api import Reranker, RerankRequest
from repro.serving.reranker import (
    DPPRerankConfig,
    rerank,
    rerank_batch,
    rerank_stream,
)
from repro.serving.router import (
    RerankRouter,
    RouterConfig,
    RouterStats,
    SlateHandle,
)
from repro.serving.sharded_rerank import sharded_rerank, sharded_rerank_stream

__all__ = [
    "DPPRerankConfig",
    "ObsConfig",
    "Reranker",
    "RerankRequest",
    "RerankRouter",
    "RouterConfig",
    "RouterStats",
    "SlateHandle",
    "rerank",
    "rerank_batch",
    "rerank_stream",
    "sharded_rerank",
    "sharded_rerank_stream",
]
