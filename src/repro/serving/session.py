"""Session-aware incremental rerank: condition on shown items instead
of recomputing.

A feed session is a sequence of reranks over a drifting candidate pool.
The paper's §2.4 sliding-window semantics (repulsion only among the
last ``w`` shown items) means the windowed ``GreedyState`` — the
``(w, M)`` Cholesky ring plus the marginal gains ``d2`` — already *is*
the session's conditioning state: everything the next pick needs to
know about the items already shown.  So instead of replaying a full
greedy run from step 0 on every scroll event, this layer

* **resumes** — each session keeps its windowed state device-resident
  between scroll events; ``next_chunk(n)`` emits the next ``n`` items
  conditioned on the shown history, never replaying selected steps
  (O(n * w * M) device work, independent of how much was shown);
* **delta-updates** — when new candidates arrive (``extend``) or
  scores refresh (``rescore``), only the affected columns of the
  session's shortlisted ``V`` are written and only *their* ``C``
  columns / ``d2`` entries re-solved against the current window
  (``greedy_state_extend`` / ``greedy_state_rescore`` in
  ``repro.core.streaming`` — O(w * dM), never O(k * M));
* **evicts** — :class:`SessionStore` keeps every session under one LRU
  device-byte budget.  An evicted session is *not* lost: the windowed
  state is a pure function of the pool and the shown history (both
  mirrored on host), so the next touch rebuilds it bit-compatibly via
  ``repro.core.windowed.windowed_state_rebuild`` — one Cholesky +
  one triangular solve, transparent to the caller.

State ownership: the device arrays (``_state``, ``_V``) are owned by
the session and may vanish at any moment (eviction); the host mirrors
(pool vectors, raw features, global ids, shown history, dead set) are
authoritative and never evicted.  DESIGN.md §11 has the delta-update
math and the LRU contract.

Observability: spans ``serving.session.{resume,extend,rescore,
rebuild,evict}``; metrics ``session_evictions_total``,
``session_resident_bytes``, ``session_deltas_total`` (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import OrderedDict
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.kernel_matrix import map_relevance
from repro.core.streaming import (
    GreedyState,
    greedy_chunk,
    greedy_init,
    greedy_state_extend,
    greedy_state_rescore,
    slot_pad_v,
)
from repro.core.windowed import windowed_state_rebuild
from repro.obs.dispatch import (
    record_session_delta,
    record_session_evict,
    record_session_resident,
)
from repro.serving.reranker import DPPRerankConfig, _shortlist_kernel


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    """Store-side knobs.

    ``budget_bytes`` caps the *device* bytes held by resident session
    states across the store (LRU eviction; host mirrors are exempt —
    they are what makes eviction reversible).  ``capacity`` is each
    session's candidate-pool width in columns; extends append into the
    headroom above the initial shortlist.  Default: twice the
    shortlist, so a session can double its pool before exhausting.
    """

    budget_bytes: int = 64 << 20
    capacity: Optional[int] = None

    def __post_init__(self):
        if self.budget_bytes <= 0:
            raise ValueError(
                f"budget_bytes must be >= 1, got {self.budget_bytes}"
            )
        if self.capacity is not None and self.capacity <= 0:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")


def _check_session_cfg(cfg: DPPRerankConfig) -> None:
    if cfg.mesh is not None:
        raise NotImplementedError(
            "sessions over sharded pools are not implemented: the window "
            "ring lives sharded behind shard_map and a column delta "
            "crosses device boundaries.  Lands with the ROADMAP 'Router "
            "scale-up' item (sharded slot batches + window heterogeneity)."
        )
    if cfg.window is None or cfg.window >= cfg.slate_size:
        raise ValueError(
            f"sessions need a windowed config (window < slate_size): the "
            f"exact C (M, k) layout retains the whole selection history "
            f"instead of a w-item conditioning window, so shown items "
            f"cannot be conditioned on in O(w*M) — got window="
            f"{cfg.window}, slate_size={cfg.slate_size}"
        )


class SessionStore:
    """LRU store of :class:`RerankSession`\\ s under one device-byte
    budget.  Created lazily by ``Reranker.sessions``; sessions are
    opened with ``Reranker.session(req, sid=...)``."""

    def __init__(self, cfg: DPPRerankConfig, scfg: SessionConfig):
        _check_session_cfg(cfg)
        self.cfg = cfg
        self.scfg = scfg
        self._sessions: "OrderedDict[object, RerankSession]" = OrderedDict()
        self._ids = itertools.count()

    def __contains__(self, sid) -> bool:
        return sid in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    def get(self, sid) -> "RerankSession":
        """The named session, touched to most-recently-used."""
        sess = self._sessions[sid]
        self._touch(sess)
        return sess

    def create(self, req, sid=None, cfg=None) -> "RerankSession":
        """Open a session over one request's shortlist."""
        cfg = cfg if cfg is not None else self.cfg
        _check_session_cfg(cfg)
        if sid is None:
            sid = next(self._ids)
        if sid in self._sessions:
            raise ValueError(
                f"session {sid!r} already exists — resume it with "
                f"Reranker.session(req, sid={sid!r}) / store.get, or "
                f"close it first"
            )
        sess = RerankSession(self, sid, cfg, req)
        self._sessions[sid] = sess
        self._balance(keep=sess)
        return sess

    def close(self, sid) -> None:
        """Drop a session entirely (device state and host mirrors)."""
        sess = self._sessions.pop(sid)
        sess._drop()
        record_session_resident(
            self.resident_bytes(), sessions=self._resident_count()
        )

    def resident_bytes(self) -> int:
        return sum(
            s._resident_bytes for s in self._sessions.values()
            if s._state is not None
        )

    def _resident_count(self) -> int:
        return sum(
            1 for s in self._sessions.values() if s._state is not None
        )

    def _touch(self, sess: "RerankSession") -> None:
        self._sessions.move_to_end(sess.sid)

    def _balance(self, keep: "RerankSession") -> None:
        """Evict least-recently-used resident sessions until the store
        fits ``budget_bytes``.  The session being served is never
        evicted, even when it alone exceeds the budget."""
        total = self.resident_bytes()
        for sess in list(self._sessions.values()):  # LRU order first
            if total <= self.scfg.budget_bytes:
                break
            if sess is keep or sess._state is None:
                continue
            freed = sess._resident_bytes
            with obs.span("serving.session.evict", sid=str(sess.sid),
                          bytes=freed):
                sess._drop()
            total -= freed
            record_session_evict(total)
        record_session_resident(total, sessions=self._resident_count())


class RerankSession:
    """One user's stateful diversified feed.

    Holds the windowed greedy state over a shortlisted, capacity-padded
    candidate pool.  Selections are reported as *global ids*: the
    request's original candidate indices for the initial shortlist,
    then the ids :meth:`extend` returns for appended candidates.
    """

    def __init__(self, store: SessionStore, sid, cfg: DPPRerankConfig, req):
        if req.batched:
            raise ValueError(
                "a session serves one user's feed (scores (M,)); open one "
                "session per user"
            )
        self.store = store
        self.sid = sid
        self.cfg = cfg
        self.spec = cfg.greedy_spec()
        self.w = min(cfg.window, cfg.slate_size)

        V, m_top, top_i = _shortlist_kernel(
            req.scores, req.feats, cfg, req.mask
        )
        D, C0 = V.shape
        cap = store.scfg.capacity or 2 * C0
        self.cap = max(cap, C0)
        self.D = D

        # host mirrors — authoritative, never evicted; what makes
        # device eviction reversible
        self._Vh = np.zeros((D, self.cap), np.asarray(V).dtype)
        self._Vh[:, :C0] = np.asarray(V)
        self._Fh = np.zeros((D, self.cap), np.asarray(req.feats).dtype)
        self._Fh[:, :C0] = np.asarray(req.feats)[np.asarray(top_i)].T
        self._gid = np.full((self.cap,), -1, np.int64)
        self._gid[:C0] = np.asarray(top_i)
        self._col_of = {int(g): i for i, g in enumerate(self._gid[:C0])}
        self._dead = np.ones((self.cap,), bool)
        self._dead[:C0] = (
            False if m_top is None else ~np.asarray(m_top)
        )
        self._shown: list[int] = []
        self._m_live = C0
        self._next_gid = int(req.num_candidates)
        self._stopped_h = False

        # device state — owned here, droppable by the store's LRU
        self._state: Optional[GreedyState] = None
        self._V = None
        self._resident_bytes = 0
        self._materialize()

    # -- device residency ---------------------------------------------------

    def _materialize(self) -> None:
        """(Re)build the device state from the host mirrors + history.

        Fresh sessions get the plain windowed init; touched-after-evict
        sessions additionally rebuild the ring rows from the last-w
        shown columns (unique Cholesky factor — bit-compatible with the
        state the incremental path reached, see
        ``windowed_state_rebuild``)."""
        Vp = jnp.asarray(self._Vh)
        st = greedy_init(self.spec, V=Vp, mask=jnp.asarray(~self._dead))
        Vop = slot_pad_v(self.spec, Vp, st)
        if self._shown:
            ring = self._shown[-self.w:]
            ring = ring + [-1] * (self.w - len(ring))
            Mp = st.d2.shape[-1]
            dead_p = np.ones((Mp,), bool)
            dead_p[: self.cap] = self._dead
            ring_j = jnp.asarray(ring, jnp.int32)
            C, d2 = windowed_state_rebuild(
                Vop, ring_j, jnp.asarray(dead_p)
            )
            batched = st.C.ndim == 3  # Pallas stream layout, B == 1
            st = GreedyState(
                jnp.asarray(len(self._shown), jnp.int32),
                jnp.full_like(st.stopped, self._stopped_h),
                C[None] if batched else C,
                d2[None] if batched else d2,
                ring_j[None] if st.win.ndim == 2 else ring_j,
            )
            record_session_delta("rebuild", w=self.w, dm=self.cap)
        self._state = st
        self._V = Vop
        self._resident_bytes = (
            sum(leaf.nbytes for leaf in st) + Vop.nbytes
        )

    def _ensure_resident(self) -> None:
        if self._state is None:
            with obs.span("serving.session.rebuild", sid=str(self.sid),
                          shown=len(self._shown)):
                self._materialize()
            self.store._balance(keep=self)

    def _drop(self) -> None:
        self._state = None
        self._V = None

    @property
    def resident(self) -> bool:
        return self._state is not None

    @property
    def shown(self) -> np.ndarray:
        """Global ids of everything this session has emitted, in order."""
        return self._gid[np.asarray(self._shown, np.int64)]

    # -- the three session verbs -------------------------------------------

    def next_chunk(self, n: Optional[int] = None):
        """Emit the next ``n`` feed items conditioned on the shown
        history: ``(ids (m,) int64 global ids, gains (m,))`` with
        ``m <= n`` — short exactly when the session eps-stops (no
        remaining candidate clears the gate; a later ``extend`` /
        ``rescore`` can revive it).  Never replays selected steps."""
        n = n if n is not None else self.cfg.chunk_size
        if n is None or n < 1:
            raise ValueError(
                f"next_chunk needs n >= 1 (or cfg.chunk_size set), got {n}"
            )
        if self._stopped_h:
            return (
                np.empty((0,), np.int64),
                np.empty((0,), self._Vh.dtype),
            )
        with obs.span("serving.session.resume", sid=str(self.sid), n=n,
                      shown=len(self._shown)):
            self.store._touch(self)
            self._ensure_resident()
            self._state, sel, dh = greedy_chunk(
                self.spec, self._state, V=self._V, chunk_size=n
            )
        sel_h = np.asarray(sel).reshape(-1)
        dh_h = np.asarray(dh).reshape(-1)
        live = sel_h >= 0
        cols = sel_h[live].astype(np.int64)
        self._shown.extend(int(c) for c in cols)
        self._dead[cols] = True
        if cols.size < n:
            self._stopped_h = True
        return self._gid[cols].copy(), dh_h[live].copy()

    def extend(self, scores, feats, mask=None) -> np.ndarray:
        """Append ``dM`` new candidates to the session's pool.

        ``scores (dM,)`` and ``feats (dM, D)`` enter the kernel exactly
        as the initial shortlist did (relevance-scaled columns, paper
        eq. 21); ``mask`` False keeps a column unselectable.  Only the
        new columns' Cholesky state is computed — O(w * dM) — and a
        stopped session is revived.  Returns the ``(dM,)`` global ids
        assigned to the new candidates."""
        scores = jnp.asarray(scores)
        feats = jnp.asarray(feats)
        if scores.ndim != 1 or feats.ndim != 2:
            raise ValueError(
                f"extend takes scores (dM,) and feats (dM, D), got "
                f"ndim={scores.ndim}/{feats.ndim}"
            )
        dm = scores.shape[0]
        if feats.shape != (dm, self.D):
            raise ValueError(
                f"extend feats must be ({dm}, {self.D}) to match the "
                f"session's pool, got {tuple(feats.shape)}"
            )
        start = self._m_live
        if start + dm > self.cap:
            raise ValueError(
                f"session pool exhausted: {start} columns used + {dm} new "
                f"> capacity {self.cap} — size SessionConfig.capacity for "
                f"the feed's total candidate churn"
            )
        with obs.span("serving.session.extend", sid=str(self.sid), dm=dm,
                      start=start):
            self.store._touch(self)
            self._ensure_resident()
            rel = map_relevance(scores.astype(jnp.float32), self.cfg.alpha)
            if mask is not None:
                rel = jnp.where(jnp.asarray(mask), rel, 0.0)
            V_blk = (feats * rel[:, None]).T
            mask_j = None if mask is None else jnp.asarray(mask)
            self._state, self._V = greedy_state_extend(
                self.spec, self._state, self._V, start, V_blk, mask_j
            )
        gids = np.arange(self._next_gid, self._next_gid + dm, dtype=np.int64)
        self._next_gid += dm
        self._gid[start:start + dm] = gids
        for i, g in enumerate(gids):
            self._col_of[int(g)] = start + i
        self._Vh[:, start:start + dm] = np.asarray(V_blk)
        self._Fh[:, start:start + dm] = np.asarray(feats).T
        self._dead[start:start + dm] = (
            False if mask is None else ~np.asarray(mask)
        )
        self._m_live = start + dm
        self._stopped_h = False
        record_session_delta("extend", w=self.w, dm=dm)
        return gids

    def rescore(self, ids, scores) -> None:
        """Refresh the relevance scores of existing candidates.

        ``ids (dM,)`` are global ids, ``scores (dM,)`` their new
        scores.  The affected columns are rewritten from the stored raw
        features and re-solved against the current window — already-
        shown (and masked) columns keep their exact old state bit-for-
        bit, so history is never rewritten; a stopped session is
        revived.  Cost is O(w * span) where span is the smallest
        contiguous pool range covering the touched columns."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        scores = np.asarray(scores).reshape(-1)
        if ids.shape != scores.shape:
            raise ValueError(
                f"rescore takes matching ids/scores, got {ids.shape} vs "
                f"{scores.shape}"
            )
        if ids.size == 0:
            return
        try:
            cols = np.array([self._col_of[int(g)] for g in ids])
        except KeyError as e:
            raise ValueError(
                f"rescore: unknown global id {e.args[0]} — ids must come "
                f"from the session's shortlist or from extend()"
            ) from None
        lo, hi = int(cols.min()), int(cols.max()) + 1
        with obs.span("serving.session.rescore", sid=str(self.sid),
                      dm=hi - lo):
            self.store._touch(self)
            self._ensure_resident()
            rel = np.asarray(
                map_relevance(jnp.asarray(scores, jnp.float32),
                              self.cfg.alpha)
            )
            Vb = self._Vh[:, lo:hi].copy()
            Vb[:, cols - lo] = self._Fh[:, cols] * rel[None, :]
            self._state, self._V = greedy_state_rescore(
                self.spec, self._state, self._V, lo, jnp.asarray(Vb)
            )
        live = ~self._dead[cols]
        self._Vh[:, cols[live]] = Vb[:, (cols - lo)[live]]
        self._stopped_h = False
        record_session_delta("rescore", w=self.w, dm=hi - lo)
