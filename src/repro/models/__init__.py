"""Model zoo: LM transformers (dense/MoE/hybrid), GraphCast-style GNN,
CTR/ranking recsys models over a sharded EmbeddingBag."""
