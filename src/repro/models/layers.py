"""Shared neural-net layers (raw JAX; params are plain pytrees).

Conventions:
* ``init_*`` return param dicts; ``*_apply`` are pure functions;
* compute dtype is the input dtype (bf16 in production), norm/softmax
  accumulate in f32;
* activations are sharding-constrained by *logical* names via
  ``repro.distributed.context.constrain`` (no-op outside a mesh).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain


def dense_init(rng, d_in: int, d_out: int, dtype, bias: bool = False, scale=None):
    scale = (d_in ** -0.5) if scale is None else scale
    p = {"w": jax.random.normal(rng, (d_in, d_out), dtype) * scale}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float, dtype=jnp.float32):
    half = d_head // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x (..., S, H, d_head), positions (..., S) -> rotated x."""
    d_head = x.shape[-1]
    half = d_head // 2
    freqs = rope_freqs(d_head, theta)  # (half,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional sliding window) — chunked online-softmax
# so the (S, S) score matrix is never materialized (flash-style in jnp).
# ---------------------------------------------------------------------------


def attention_init(rng, d_model, n_heads, n_kv_heads, d_head, dtype, qkv_bias=False):
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype, qkv_bias),
        "wk": dense_init(ks[1], d_model, n_kv_heads * d_head, dtype, qkv_bias),
        "wv": dense_init(ks[2], d_model, n_kv_heads * d_head, dtype, qkv_bias),
        "wo": dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }


def _chunk_attn(q, k, v, q_pos, kv_pos, window: Optional[int]):
    """One query chunk vs full K/V with online mask.

    q (B, Sq, KV, G, dh); k/v (B, Skv, KV, dh); positions int32.
    Returns (B, Sq, KV, G, dh) f32 un-normalized? -> normalized output.
    """
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum(
        "bqkgd,btkd->bkgqt", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = kv_pos[None, :] <= q_pos[:, None]  # causal (Sq, Skv)
    if window is not None:
        mask &= (q_pos[:, None] - kv_pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p / jnp.maximum(l, 1e-30), v.astype(jnp.float32))
    return o


def gqa_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    window: Optional[int] = None,
    q_offset: int | jnp.ndarray = 0,
    chunk_q: int = 512,
    remat_chunks: bool = False,
):
    """Causal GQA attention, chunked over queries.

    q (B, Sq, H, dh); k, v (B, Skv, KV, dh).  ``q_offset`` is the absolute
    position of q[0] (for decode/prefill-continuation).  Returns
    (B, Sq, H, dh) in q.dtype.
    """
    B, Sq, H, dh = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, dh)
    kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)

    if Sq <= chunk_q:
        q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
        o = _chunk_attn(qg, k, v, q_pos, kv_pos, window)
        return o.reshape(B, Sq, H, dh).astype(q.dtype)

    pad = (-Sq) % chunk_q
    if pad:  # ragged tail: pad queries (outputs sliced off below)
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    Sq_p = Sq + pad
    n_chunks = Sq_p // chunk_q
    qg = qg.reshape(B, n_chunks, chunk_q, KV, G, dh)

    def one(carry, qc_i):
        qc, i = qc_i
        q_pos = q_offset + i * chunk_q + jnp.arange(chunk_q, dtype=jnp.int32)
        o = _chunk_attn(qc, k, v, q_pos, kv_pos, window)
        return carry, o

    if remat_chunks:
        # flash-attention-style: recompute per-chunk scores/probs in the
        # backward pass instead of stacking (n_chunks, ...) f32 residuals
        one = jax.checkpoint(one)

    _, o = jax.lax.scan(
        one,
        None,
        (jnp.moveaxis(qg, 1, 0), jnp.arange(n_chunks, dtype=jnp.int32)),
    )
    o = jnp.moveaxis(o, 0, 1).reshape(B, Sq_p, H, dh)[:, :Sq]
    return o.astype(q.dtype)


def attention_apply(
    p,
    x: jnp.ndarray,
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    rope_theta: float,
    window: Optional[int] = None,
    chunk_q: int = 512,
):
    """Self-attention over x (B, S, d_model) with RoPE; returns (B, S, d)."""
    B, S, _ = x.shape
    q = dense(p["wq"], x).reshape(B, S, n_heads, d_head)
    k = dense(p["wk"], x).reshape(B, S, n_kv_heads, d_head)
    v = dense(p["wv"], x).reshape(B, S, n_kv_heads, d_head)
    pos = jnp.arange(S, dtype=jnp.int32)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    o = gqa_attention(q, k, v, window=window, chunk_q=chunk_q)
    return dense(p["wo"], o.reshape(B, S, n_heads * d_head))


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(rng, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(rng, 3)
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype),
        "wg": dense_init(ks[1], d_model, d_ff, dtype),
        "wo": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wi"], x)
    h = constrain(h, "batch", None, "ff")
    return dense(p["wo"], h)


def mlp_head_init(rng, dims: list[int], dtype, out_dim: int = 1):
    """Plain ReLU MLP tower (recsys / GNN decoders)."""
    ks = jax.random.split(rng, len(dims) + 1)
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        layers.append(dense_init(ks[i], a, b, dtype, bias=True))
    layers.append(dense_init(ks[-1], dims[-1], out_dim, dtype, bias=True))
    return {"layers": layers}


def mlp_head_apply(p, x, final_activation=None):
    h = x
    for layer in p["layers"][:-1]:
        h = jax.nn.relu(dense(layer, h))
    out = dense(p["layers"][-1], h)
    if final_activation is not None:
        out = final_activation(out)
    return out
