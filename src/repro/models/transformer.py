"""Decoder-only transformer LM family (dense, MoE, local:global hybrid).

Covers the five assigned LM architectures:
  * dense GQA + RoPE + SwiGLU (phi3, qwen1.5 [qkv_bias], gemma3);
  * gemma3's 5:1 local:global attention (per-layer sliding window);
  * MoE FFN with expert-parallel all_to_all dispatch (olmoe top-8,
    arctic top-2 + parallel dense residual branch).

Layer stack is ``lax.scan`` over stacked params with per-layer remat so
the HLO stays small at 512-way SPMD and activation memory is O(1) in
depth.  Three lowering entry points:

  * ``train_loss``   — next-token CE (+ MoE aux), full sequence;
  * ``prefill``      — forward + KV-cache collection + last-token logits;
  * ``decode_step``  — one token against the cache (ring-buffer caches
    for sliding-window layers, full caches for global layers).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import layers as L
from repro.models.moe import MoEConfig, moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding window width for local layers
    global_every: Optional[int] = None  # every Nth layer is global (gemma3)
    moe: Optional[MoEConfig] = None
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    chunk_q: int = 512
    aux_loss_coef: float = 0.01
    remat_chunks: bool = False  # flash-style: recompute attn chunks in bwd

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    def layer_windows(self) -> Tuple[Optional[int], ...]:
        """Per-layer attention window; None = full (global) attention."""
        if self.window is None:
            return (None,) * self.n_layers
        ge = self.global_every or 0
        return tuple(
            None if (ge and (i + 1) % ge == 0) else self.window
            for i in range(self.n_layers)
        )

    @property
    def uses_mixed_windows(self) -> bool:
        return len(set(self.layer_windows())) > 1

    def param_count(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_ff * self.moe.n_experts + d * self.moe.n_experts
            if self.moe_dense_residual:
                ffn += 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (self.n_heads * 2 + self.n_kv_heads * 2)
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_ff * self.moe.top_k + d * self.moe.n_experts
            if self.moe_dense_residual:
                ffn += 3 * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        return self.vocab * d * 2 + self.n_layers * (attn + ffn + 2 * d) + d


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(rng, cfg: TransformerConfig):
    ks = jax.random.split(rng, 4)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": L.attention_init(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.dtype, cfg.qkv_bias,
        ),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.moe, cfg.dtype)
        if cfg.moe_dense_residual:
            p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype)
    else:
        p["mlp"] = L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.dtype)
    return p


def init_params(rng, cfg: TransformerConfig):
    k_emb, k_layers, k_out = jax.random.split(rng, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": jax.random.normal(k_emb, (cfg.vocab, cfg.d_model), cfg.dtype) * 0.02,
        "layers": layers,
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "unembed": jax.random.normal(k_out, (cfg.d_model, cfg.vocab), cfg.dtype)
        * (cfg.d_model ** -0.5),
    }


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _block(p_l, x, window, cfg: TransformerConfig, collect_kv: bool = False):
    """One transformer block. ``window``: static int/None, or traced scalar
    (mixed local/global archs scan a per-layer window array; -1 = global).
    Returns (x, aux, (k, v) roped keys/values if collect_kv)."""
    B, S, _ = x.shape
    if isinstance(window, jnp.ndarray):
        window = jnp.where(window > 0, window, jnp.asarray(S + 1, jnp.int32))
    h = L.rmsnorm(p_l["ln1"], x, cfg.norm_eps)

    q = L.dense(p_l["attn"]["wq"], h).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = L.dense(p_l["attn"]["wk"], h).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = L.dense(p_l["attn"]["wv"], h).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    pos = jnp.arange(S, dtype=jnp.int32)
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "heads", None)
    o = L.gqa_attention(q, k, v, window=window, chunk_q=cfg.chunk_q,
                        remat_chunks=cfg.remat_chunks)
    h = L.dense(p_l["attn"]["wo"], o.reshape(B, S, cfg.n_heads * cfg.head_dim))

    x = x + h
    u = L.rmsnorm(p_l["ln2"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe is not None:
        moe_out, aux = moe_apply(p_l["moe"], u, cfg.moe)
        ffn = moe_out + (L.mlp_apply(p_l["mlp"], u) if cfg.moe_dense_residual else 0)
    else:
        ffn = L.mlp_apply(p_l["mlp"], u)
    x = x + ffn
    x = constrain(x, "batch", "seq", None)
    kv = (k, v) if collect_kv else None
    return x, aux, kv


def forward_hidden(
    params, tokens: jnp.ndarray, cfg: TransformerConfig, collect_kv: bool = False
):
    """tokens (B, S) -> (hidden (B, S, d), aux_loss, kv or None).

    ``collect_kv``: also return roped K/V stacked over layers
    (L, B, S, KV, dh) for prefill cache construction."""
    x = params["embed"][tokens]
    x = constrain(x, "batch", "seq", None)

    windows = cfg.layer_windows()
    block = lambda p, y, w: _block(p, y, w, cfg, collect_kv)

    if cfg.uses_mixed_windows:
        w_arr = jnp.asarray(
            [w if w is not None else -1 for w in windows], jnp.int32
        )

        def body(x, xs):
            p_l, w_l = xs
            x, aux, kv = jax.checkpoint(block)(p_l, x, w_l)
            return x, (aux, kv)

        x, (auxs, kvs) = jax.lax.scan(body, x, (params["layers"], w_arr))
    else:
        w = windows[0]

        def body(x, p_l):
            x, aux, kv = jax.checkpoint(lambda p, y: block(p, y, w))(p_l, x)
            return x, (aux, kv)

        x, (auxs, kvs) = jax.lax.scan(body, x, params["layers"])

    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return x, jnp.sum(auxs), kvs


def logits_from_hidden(params, hidden):
    logits = hidden @ params["unembed"]
    return constrain(logits, "batch", None, "vocab")


def train_loss(params, batch, cfg: TransformerConfig):
    """Next-token cross-entropy (f32 logsumexp) + MoE aux loss."""
    tokens = batch["tokens"]
    hidden, aux, _ = forward_hidden(params, tokens, cfg)
    logits = logits_from_hidden(params, hidden[:, :-1]).astype(jnp.float32)
    targets = tokens[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - picked)
    return ce + cfg.aux_loss_coef * aux


# ---------------------------------------------------------------------------
# KV cache: group assignment (single source of truth), prefill, decode
# ---------------------------------------------------------------------------


def layer_cache_plan(cfg: TransformerConfig, max_seq: int):
    """Per-layer (width, group_key, index_in_group); groups keyed by width.

    Local (sliding-window) layers get ring buffers of width ``window``;
    global layers get full ``max_seq`` buffers.  Uniform archs collapse
    to a single group.
    """
    plan: List[Tuple[int, str, int]] = []
    counters: Dict[str, int] = {}
    for w in cfg.layer_windows():
        width = min(w, max_seq) if w is not None else max_seq
        key = str(width)
        idx = counters.get(key, 0)
        counters[key] = idx + 1
        plan.append((width, key, idx))
    return plan


def init_cache(cfg: TransformerConfig, batch: int, max_seq: int):
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    plan = layer_cache_plan(cfg, max_seq)
    sizes: Dict[str, int] = {}
    widths: Dict[str, int] = {}
    for width, key, idx in plan:
        sizes[key] = idx + 1
        widths[key] = width
    groups = {
        key: {
            "k": jnp.zeros((n, batch, widths[key], KV, dh), cfg.dtype),
            "v": jnp.zeros((n, batch, widths[key], KV, dh), cfg.dtype),
        }
        for key, n in sizes.items()
    }
    return {"pos": jnp.zeros((), jnp.int32), "groups": groups}


def cache_max_seq(cfg: TransformerConfig, cache) -> int:
    """Infer the max_seq a cache was built for."""
    widths = [int(k) for k in cache["groups"]]
    non_window = [w for w in widths if w != (cfg.window or -1)]
    return max(non_window) if non_window else widths[0]


def _decode_attn(p_attn, x, kc, vc, pos, is_ring: bool, cfg: TransformerConfig):
    """One-token attention against a (B, W, KV, dh) cache.

    is_ring: ring buffer (slot = pos % W); else linear (slot = pos).
    Returns (out (B, 1, d_model), new_kc, new_vc).
    """
    B = x.shape[0]
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    W = kc.shape[1]
    q = L.dense(p_attn["wq"], x).reshape(B, 1, H, dh)
    k = L.dense(p_attn["wk"], x).reshape(B, 1, KV, dh)
    v = L.dense(p_attn["wv"], x).reshape(B, 1, KV, dh)
    pos_arr = pos[None].astype(jnp.int32)
    q = L.apply_rope(q, pos_arr, cfg.rope_theta)
    k = L.apply_rope(k, pos_arr, cfg.rope_theta)

    slot = pos % W if is_ring else pos
    kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, slot, 0, 0))

    idx = jnp.arange(W, dtype=jnp.int32)
    kv_pos = pos - jnp.mod(pos - idx, W) if is_ring else idx
    mask = (kv_pos >= 0) & (kv_pos <= pos)

    qg = q.reshape(B, KV, H // KV, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, kc.astype(jnp.float32)) * (dh ** -0.5)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p, vc.astype(jnp.float32))
    o = o.reshape(B, 1, H * dh).astype(x.dtype)
    return L.dense(p_attn["wo"], o), kc, vc


def _decode_block(p_l, x, kc, vc, pos, is_ring: bool, cfg: TransformerConfig):
    h = L.rmsnorm(p_l["ln1"], x, cfg.norm_eps)
    h, kc, vc = _decode_attn(p_l["attn"], h, kc, vc, pos, is_ring, cfg)
    x = x + h
    u = L.rmsnorm(p_l["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        moe_out, _ = moe_apply(p_l["moe"], u, cfg.moe)
        ffn = moe_out + (L.mlp_apply(p_l["mlp"], u) if cfg.moe_dense_residual else 0)
    else:
        ffn = L.mlp_apply(p_l["mlp"], u)
    return x + ffn, kc, vc


def decode_step(params, cache, tokens: jnp.ndarray, cfg: TransformerConfig):
    """One decoding step.  tokens (B, 1) -> (logits (B, vocab) f32, cache').

    Single-group archs scan the layer stack (small HLO); mixed-window
    archs (gemma3) process layers in schedule order with per-group
    stacked caches.
    """
    pos = cache["pos"]
    x = params["embed"][tokens[:, :1]]
    max_seq = cache_max_seq(cfg, cache)
    plan = layer_cache_plan(cfg, max_seq)
    windows = cfg.layer_windows()

    if len(cache["groups"]) == 1:
        (key,) = cache["groups"].keys()
        g = cache["groups"][key]
        is_ring = windows[0] is not None

        def body(x, xs):
            p_l, kc, vc = xs
            x, kc, vc = _decode_block(p_l, x, kc, vc, pos, is_ring, cfg)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], g["k"], g["v"]))
        new_groups = {key: {"k": ks, "v": vs}}
    else:
        new_groups = {k: {"k": g["k"], "v": g["v"]} for k, g in cache["groups"].items()}
        for i in range(cfg.n_layers):
            width, key, gidx = plan[i]
            p_l = jax.tree.map(lambda a: a[i], params["layers"])
            g = new_groups[key]
            x, kc, vc = _decode_block(
                p_l, x, g["k"][gidx], g["v"][gidx], pos, windows[i] is not None, cfg
            )
            g["k"] = g["k"].at[gidx].set(kc)
            g["v"] = g["v"].at[gidx].set(vc)

    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    return logits, {"pos": pos + 1, "groups": new_groups}


def prefill(params, tokens: jnp.ndarray, cfg: TransformerConfig, max_seq: int):
    """Prefill: one forward pass over the prompt (collecting roped K/V in
    the layer scan), build the decode cache, return last-token logits."""
    B, S = tokens.shape
    hidden, _, kvs = forward_hidden(params, tokens, cfg, collect_kv=True)
    logits = (hidden[:, -1] @ params["unembed"]).astype(jnp.float32)
    ks, vs = kvs  # each (L, B, S, KV, dh)

    cache = init_cache(cfg, B, max_seq)
    plan = layer_cache_plan(cfg, max_seq)
    for i, (width, key, gidx) in enumerate(plan):
        k_i, v_i = ks[i], vs[i]
        if width >= S:
            k_w = jnp.pad(k_i, ((0, 0), (0, width - S), (0, 0), (0, 0)))
            v_w = jnp.pad(v_i, ((0, 0), (0, width - S), (0, 0), (0, 0)))
        else:
            # ring layout: token t -> slot t % width; last ``width`` survive
            slots = jnp.arange(width, dtype=jnp.int32)
            tok = (S - width) + ((slots - (S - width)) % width)
            k_w, v_w = k_i[:, tok], v_i[:, tok]
        g = cache["groups"][key]
        g["k"] = g["k"].at[gidx].set(k_w.astype(g["k"].dtype))
        g["v"] = g["v"].at[gidx].set(v_w.astype(g["v"].dtype))
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, cache
