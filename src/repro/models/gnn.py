"""GraphCast-style encoder-processor-decoder GNN (arXiv:2212.12794).

Message passing is built from first principles on ``jax.ops.segment_sum``
over an edge-index array (JAX has no sparse SpMM beyond BCOO — the
edge-scatter formulation IS the system, per the assignment notes):

  encoder:    node MLP  d_feat -> d_hidden
  processor:  n_layers rounds of
                 e'_ij = MLP_e([h_i, h_j, e_ij])          (per edge)
                 m_i   = segment_agg_{j->i} e'_ij          (scatter)
                 h'_i  = h_i + MLP_n([h_i, m_i])           (residual)
  decoder:    node MLP  d_hidden -> n_vars

The same apply() serves all four assigned graph shapes: full-batch
(cora/ogbn-products scale), sampled minibatch subgraphs (padded edge
lists + masks from the neighbor sampler), and batched small molecules
(disjoint-union flattening).  Processor layers are scanned (stacked
params) with remat.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int = 16
    d_hidden: int = 512
    d_feat: int = 128
    n_vars: int = 227  # output channels (GraphCast: surface+pressure vars)
    d_edge: int = 16
    aggregator: str = "sum"  # sum | mean | max
    mesh_refinement: int = 6  # recorded from the paper config (data-gen detail)
    dtype: Any = jnp.bfloat16

    def param_count(self) -> int:
        h = self.d_hidden
        enc = self.d_feat * h + h * h
        edge_mlp = (2 * h + self.d_edge) * h + h * self.d_edge
        node_mlp = (h + self.d_edge) * h + h * h
        dec = h * h + h * self.n_vars
        return enc + self.n_layers * (edge_mlp + node_mlp) + dec


def _mlp2_init(rng, d_in, d_mid, d_out, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "l1": L.dense_init(k1, d_in, d_mid, dtype, bias=True),
        "l2": L.dense_init(k2, d_mid, d_out, dtype, bias=True),
    }


def _mlp2(p, x):
    return L.dense(p["l2"], jax.nn.gelu(L.dense(p["l1"], x)))


def _proc_layer_init(rng, cfg: GNNConfig):
    k1, k2 = jax.random.split(rng)
    h, de = cfg.d_hidden, cfg.d_edge
    return {
        "edge": _mlp2_init(k1, 2 * h + de, h, de, cfg.dtype),
        "node": _mlp2_init(k2, h + de, h, h, cfg.dtype),
    }


def init_params(rng, cfg: GNNConfig):
    k_enc, k_embed, k_proc, k_dec = jax.random.split(rng, 4)
    proc_keys = jax.random.split(k_proc, cfg.n_layers)
    return {
        "encoder": _mlp2_init(k_enc, cfg.d_feat, cfg.d_hidden, cfg.d_hidden, cfg.dtype),
        "edge_embed": L.dense_init(k_embed, 2 * cfg.d_hidden, cfg.d_edge, cfg.dtype, bias=True),
        "processor": jax.vmap(lambda k: _proc_layer_init(k, cfg))(proc_keys),
        "decoder": _mlp2_init(k_dec, cfg.d_hidden, cfg.d_hidden, cfg.n_vars, cfg.dtype),
    }


def _aggregate(msgs, dst, n_nodes, how: str):
    if how == "sum":
        return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
    if how == "mean":
        s = jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)
        cnt = jax.ops.segment_sum(jnp.ones_like(dst, msgs.dtype), dst, num_segments=n_nodes)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if how == "max":
        return jax.ops.segment_max(msgs, dst, num_segments=n_nodes)
    raise ValueError(how)


def apply(
    params,
    node_feats: jnp.ndarray,  # (N, d_feat)
    edges: jnp.ndarray,  # (E, 2) int32 [src, dst]
    cfg: GNNConfig,
    edge_mask: Optional[jnp.ndarray] = None,  # (E,) bool — padding edges
):
    """Returns per-node predictions (N, n_vars)."""
    N = node_feats.shape[0]
    src, dst = edges[:, 0], edges[:, 1]
    h = _mlp2(params["encoder"], node_feats.astype(cfg.dtype))
    h = constrain(h, "nodes", None)

    # initial edge features from endpoint embeddings
    e = L.dense(params["edge_embed"], jnp.concatenate([h[src], h[dst]], axis=-1))
    if edge_mask is not None:
        e = e * edge_mask[:, None].astype(e.dtype)

    def layer(carry, p_l):
        h, e = carry

        def body(p_l, h, e):
            msg_in = jnp.concatenate([h[src], h[dst], e], axis=-1)
            e2 = e + _mlp2(p_l["edge"], msg_in)
            if edge_mask is not None:
                e2 = e2 * edge_mask[:, None].astype(e2.dtype)
            m = _aggregate(e2, dst, N, cfg.aggregator)
            h2 = h + _mlp2(p_l["node"], jnp.concatenate([h, m], axis=-1))
            h2 = constrain(h2, "nodes", None)
            return h2, e2

        return jax.checkpoint(body)(p_l, h, e), None

    (h, e), _ = jax.lax.scan(layer, (h, e), params["processor"])
    return _mlp2(params["decoder"], h)


def mse_loss(params, batch, cfg: GNNConfig):
    """batch: node_feats, edges, targets (N, n_vars), node_mask optional."""
    preds = apply(
        params, batch["node_feats"], batch["edges"], cfg,
        edge_mask=batch.get("edge_mask"),
    ).astype(jnp.float32)
    err = (preds - batch["targets"].astype(jnp.float32)) ** 2
    mask = batch.get("node_mask")
    if mask is not None:
        mf = mask.astype(jnp.float32)[:, None]
        return jnp.sum(err * mf) / (jnp.sum(mf) * cfg.n_vars)
    return jnp.mean(err)
