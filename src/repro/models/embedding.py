"""Sharded EmbeddingBag built from jnp.take + segment-sum (no native
EmbeddingBag in JAX — this IS part of the system, per the assignment).

All categorical fields share one fused table (row-offset per field) so a
single row-sharded parameter covers the whole collection.  Lookup of a
(B, F, H) multi-hot id batch (−1 = padding) returns (B, F, D) bag sums.

Two paths:
  * local (no mesh): one gather + masked sum;
  * sharded (mesh installed): ``shard_map`` over the model axis — each
    shard owns a contiguous row range, gathers locally (out-of-range ids
    masked) and the partial bags are ``psum``-combined.  The all-to-all
    variant (exchange ids, return only hit rows) is the §Perf hillclimb.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import context as dctx
from repro.distributed.context import shard_map_compat


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    vocab_sizes: Tuple[int, ...]  # rows per field
    dim: int
    pad_to_multiple: int = 512  # fused rows padded for even row-sharding

    @property
    def offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.vocab_sizes)[:-1]]).astype(np.int64)

    @property
    def total_rows(self) -> int:
        t = int(sum(self.vocab_sizes))
        m = self.pad_to_multiple
        return (t + m - 1) // m * m


def init_table(rng, spec: EmbeddingSpec, dtype=jnp.float32, scale: float = 0.01):
    return jax.random.normal(rng, (spec.total_rows, spec.dim), dtype) * scale


def _flat_ids(ids: jnp.ndarray, spec: EmbeddingSpec):
    """(B, F, H) field-local ids (−1 pad) -> (B, F, H) fused row ids + mask."""
    offs = jnp.asarray(spec.offsets, jnp.int32)[None, :, None]
    valid = ids >= 0
    return jnp.where(valid, ids + offs, 0), valid


def _local_bag(table, flat, valid):
    emb = jnp.take(table, flat.reshape(-1), axis=0)  # (B*F*H, D)
    emb = emb.reshape(flat.shape + (table.shape[1],))
    emb = emb * valid[..., None].astype(emb.dtype)
    return emb.sum(axis=2)  # (B, F, D)


def embedding_bag(
    table: jnp.ndarray, ids: jnp.ndarray, spec: EmbeddingSpec,
    mode: str = "psum",
):
    """table (rows, D) [row-sharded when a mesh is active], ids (B, F, H)
    -> (B, F, D) bag-summed embeddings.

    mode="psum" (baseline): every model shard computes a dense partial
    (B, F, D) and the partials are psum-combined — simple, but moves
    2 x B x F x D x 4 bytes per device regardless of hit density.

    mode="alltoall" (§Perf): DLRM-style id exchange — each device sends
    only its ids to the row owners (tiny) and receives only the hit rows
    back (B_loc x F x H x D once), then bags locally.  Requires the batch
    to be sharded over the token axes; falls back to psum otherwise.
    """
    flat, valid = _flat_ids(ids, spec)
    mesh = dctx.current_mesh()
    model_axis = dctx.model_axis_name()
    if mesh is None or model_axis is None or mesh.shape.get(model_axis, 1) == 1:
        return _local_bag(table, flat, valid)

    n_shards = mesh.shape[model_axis]
    rows_loc = spec.total_rows // n_shards
    dp_axes = dctx.data_axis_names()
    B = ids.shape[0]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    batch_axes = tuple(dict.fromkeys(dp_axes)) if (dp_axes and B % dp_size == 0) else ()
    P = jax.sharding.PartitionSpec
    ids_spec = P(batch_axes if batch_axes else None, None, None)

    if mode == "alltoall" and batch_axes and model_axis in batch_axes:
        # DLRM-style: shard table rows over the FULL (data x model) device
        # grid so embedding grads are exact-local after the reverse a2a —
        # no dense table-grad all-reduce across data replicas.
        ex_axes = batch_axes  # joint exchange group
        n_ex = 1
        for a in ex_axes:
            n_ex *= mesh.shape[a]
        rows_ex = spec.total_rows // n_ex

        def body_a2a(table_loc, flat_loc, valid_loc):
            D = table_loc.shape[1]
            Bl, F, H = flat_loc.shape
            n = Bl * F * H
            req = flat_loc.reshape(-1)
            owner = jnp.clip(req // rows_ex, 0, n_ex - 1)
            # rank of each request within its owner bucket (MoE-style)
            sort_idx = jnp.argsort(owner, stable=True)
            sorted_o = owner[sort_idx]
            counts = jnp.bincount(owner, length=n_ex)
            starts = jnp.cumsum(counts) - counts
            pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[sorted_o]
            pos = jnp.zeros((n,), jnp.int32).at[sort_idx].set(pos_sorted)
            cap = max(8, int(4 * n / n_ex))  # 4x imbalance margin
            pos = jnp.where(pos < cap, pos, cap)
            send = jnp.zeros((n_ex, cap), jnp.int32)
            send = send.at[owner, pos].set(req, mode="drop")
            recv = jax.lax.all_to_all(send[:, None], ex_axes, 0, 0, tiled=False)
            recv = recv.reshape(n_ex, cap)  # requests addressed to me
            me = jnp.zeros((), jnp.int32)
            for a in ex_axes:
                me = me * mesh.shape[a] + jax.lax.axis_index(a)
            local = recv - me * rows_ex
            rows = jnp.take(
                table_loc, jnp.clip(local, 0, rows_ex - 1).reshape(-1), axis=0
            ).reshape(n_ex, cap, D)
            rows = rows * ((local >= 0) & (local < rows_ex))[..., None].astype(rows.dtype)
            back = jax.lax.all_to_all(rows[:, None], ex_axes, 0, 0, tiled=False)
            back = back.reshape(n_ex, cap, D)  # my requests' rows
            got = back.at[owner, pos].get(mode="fill", fill_value=0.0)  # (n, D)
            got = got.reshape(Bl, F, H, D)
            got = got * valid_loc[..., None].astype(got.dtype)
            return got.sum(axis=2)

        return shard_map_compat(
            body_a2a,
            mesh=mesh,
            in_specs=(P(ex_axes, None), ids_spec, ids_spec),
            out_specs=ids_spec,
            check=False,
        )(table, flat, valid)

    def body(table_loc, flat_loc, valid_loc):
        shard = jax.lax.axis_index(model_axis)
        lo = shard * rows_loc
        local = flat_loc - lo
        hit = valid_loc & (local >= 0) & (local < rows_loc)
        emb = jnp.take(table_loc, jnp.clip(local, 0, rows_loc - 1).reshape(-1), axis=0)
        emb = emb.reshape(flat_loc.shape + (table_loc.shape[1],))
        emb = emb * hit[..., None].astype(emb.dtype)
        part = emb.sum(axis=2)
        return jax.lax.psum(part, model_axis)

    # psum path: ids must NOT be sharded over the model axis
    psum_batch = tuple(a for a in batch_axes if a != model_axis)
    ids_spec = P(psum_batch if psum_batch else None, None, None)
    return shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(P(model_axis, None), ids_spec, ids_spec),
        out_specs=ids_spec,
        check=False,
    )(table, flat, valid)


def embedding_bag_ref(table, ids, spec: EmbeddingSpec):
    """Dense one-hot oracle (tests): bag sum == onehot(ids) @ table."""
    flat, valid = _flat_ids(ids, spec)
    B, F, H = ids.shape
    out = jnp.zeros((B, F, table.shape[1]), table.dtype)
    for h in range(H):
        oh = jax.nn.one_hot(flat[:, :, h], table.shape[0], dtype=table.dtype)
        oh = oh * valid[:, :, h, None].astype(table.dtype)
        out = out + jnp.einsum("bfr,rd->bfd", oh, table)
    return out
