"""CTR / ranking model family: DeepFM, xDeepFM, Wide&Deep, AutoInt.

All four share the fused row-sharded EmbeddingBag (embedding.py); they
differ in the feature-interaction stage:

  deepfm    — FM second-order (the fm_interaction Pallas kernel's math)
              + first-order wide term + deep MLP            [1703.04247]
  xdeepfm   — CIN (compressed interaction network) + MLP    [1803.05170]
  wide-deep — linear wide term + deep MLP                   [1606.07792]
  autoint   — multi-head self-attention over field embeddings
              with residual projections                     [1810.11921]

Serving entry points produce (score, item_embedding) pairs so the DPP
re-ranker (repro.core / repro.serving) can diversify slates — the
paper's serving integration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import layers as L
from repro.models.embedding import EmbeddingSpec, embedding_bag, init_table


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    vocab_sizes: Tuple[int, ...]  # one entry per sparse field
    embed_dim: int
    interaction: str  # fm | cin | concat | self-attn
    mlp_dims: Tuple[int, ...] = ()
    cin_layers: Tuple[int, ...] = ()
    attn_layers: int = 0
    attn_heads: int = 0
    d_attn: int = 0
    hot_size: int = 1  # ids per field (multi-hot bags supported)
    item_field: int = 0  # which field is the "item" (retrieval / DPP rerank)
    emb_mode: str = "psum"  # psum (baseline) | alltoall (§Perf profile)
    dtype: Any = jnp.float32

    @property
    def n_fields(self) -> int:
        return len(self.vocab_sizes)

    @property
    def spec(self) -> EmbeddingSpec:
        return EmbeddingSpec(self.vocab_sizes, self.embed_dim)

    def param_count(self) -> int:
        total = self.spec.total_rows * self.embed_dim
        total += self.spec.total_rows  # wide/first-order table
        d_in = self.n_fields * self.embed_dim
        dims = (d_in,) + tuple(self.mlp_dims)
        for a, b in zip(dims[:-1], dims[1:]):
            total += a * b + b
        return total


def init_params(rng, cfg: RecsysConfig):
    ks = jax.random.split(rng, 8)
    spec = cfg.spec
    p = {
        "table": init_table(ks[0], spec, cfg.dtype),
        "wide": init_table(ks[1], EmbeddingSpec(cfg.vocab_sizes, 1), cfg.dtype),
        "bias": jnp.zeros((), cfg.dtype),
    }
    d_in = cfg.n_fields * cfg.embed_dim
    if cfg.mlp_dims:
        p["mlp"] = L.mlp_head_init(ks[2], [d_in] + list(cfg.mlp_dims), cfg.dtype)
    if cfg.interaction == "cin":
        sizes = (cfg.n_fields,) + tuple(cfg.cin_layers)
        keys = jax.random.split(ks[3], len(cfg.cin_layers))
        p["cin"] = [
            jax.random.normal(keys[i], (sizes[i + 1], sizes[i], cfg.n_fields), cfg.dtype)
            * ((sizes[i] * cfg.n_fields) ** -0.5)
            for i in range(len(cfg.cin_layers))
        ]
        p["cin_out"] = L.dense_init(ks[4], sum(cfg.cin_layers), 1, cfg.dtype, bias=True)
    if cfg.interaction == "self-attn":
        d_l = cfg.embed_dim
        layers = []
        keys = jax.random.split(ks[5], cfg.attn_layers)
        for i in range(cfg.attn_layers):
            kq, kk, kv, kr = jax.random.split(keys[i], 4)
            d_out = cfg.attn_heads * cfg.d_attn
            layers.append({
                "wq": L.dense_init(kq, d_l, d_out, cfg.dtype),
                "wk": L.dense_init(kk, d_l, d_out, cfg.dtype),
                "wv": L.dense_init(kv, d_l, d_out, cfg.dtype),
                "wr": L.dense_init(kr, d_l, d_out, cfg.dtype),
            })
            d_l = d_out
        p["attn"] = layers
        p["attn_out"] = L.dense_init(ks[6], cfg.n_fields * d_l, 1, cfg.dtype, bias=True)
    return p


# ---------------------------------------------------------------------------
# interactions
# ---------------------------------------------------------------------------


def fm_second_order(emb: jnp.ndarray) -> jnp.ndarray:
    """(B, F, D) -> (B,)  0.5 * sum_d[(sum_f v)^2 − sum_f v^2]  (ref path;
    the Pallas kernel repro.kernels.fm_interaction computes the same)."""
    s = jnp.sum(emb, axis=1)
    sq = jnp.sum(emb * emb, axis=1)
    return 0.5 * jnp.sum(s * s - sq, axis=1)


def cin(emb: jnp.ndarray, weights, out_proj) -> jnp.ndarray:
    """Compressed Interaction Network (xDeepFM §3). emb (B, F, D) -> (B,)."""
    x0 = emb  # (B, F, D)
    xk = emb
    pooled = []
    for W in weights:  # W (H_next, H_k, F)
        # z[b, h, m, d] = xk[b, h, d] * x0[b, m, d]; contract with W
        xk = jnp.einsum("bhd,bmd,ohm->bod", xk, x0, W)
        pooled.append(jnp.sum(xk, axis=2))  # (B, H_next)
    feat = jnp.concatenate(pooled, axis=1)
    return L.dense(out_proj, feat)[:, 0]


def autoint_layers(emb: jnp.ndarray, layers, heads: int, d_attn: int) -> jnp.ndarray:
    """Stacked multi-head self-attention over fields. (B, F, D) -> (B, F, d')."""
    x = emb
    for p in layers:
        B, F, _ = x.shape
        q = L.dense(p["wq"], x).reshape(B, F, heads, d_attn)
        k = L.dense(p["wk"], x).reshape(B, F, heads, d_attn)
        v = L.dense(p["wv"], x).reshape(B, F, heads, d_attn)
        s = jnp.einsum("bfhd,bghd->bhfg", q, k) * (d_attn ** -0.5)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhfg,bghd->bfhd", a, v).reshape(B, F, heads * d_attn)
        x = jax.nn.relu(o + L.dense(p["wr"], x))
    return x


# ---------------------------------------------------------------------------
# forward / loss / serving
# ---------------------------------------------------------------------------


def forward_logits(params, ids: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    """ids (B, F, H) -> logits (B,)."""
    emb = embedding_bag(params["table"], ids, cfg.spec, mode=cfg.emb_mode)  # (B, F, D)
    emb = constrain(emb, "batch", None, None)
    wide = embedding_bag(params["wide"], ids, EmbeddingSpec(cfg.vocab_sizes, 1),
                         mode=cfg.emb_mode)
    first_order = jnp.sum(wide[..., 0], axis=1)  # (B,)

    logit = params["bias"] + first_order
    flat = emb.reshape(emb.shape[0], -1)
    if cfg.interaction == "fm":
        logit = logit + fm_second_order(emb)
        logit = logit + L.mlp_head_apply(params["mlp"], flat)[:, 0]
    elif cfg.interaction == "cin":
        logit = logit + cin(emb, params["cin"], params["cin_out"])
        logit = logit + L.mlp_head_apply(params["mlp"], flat)[:, 0]
    elif cfg.interaction == "concat":
        logit = logit + L.mlp_head_apply(params["mlp"], flat)[:, 0]
    elif cfg.interaction == "self-attn":
        h = autoint_layers(emb, params["attn"], cfg.attn_heads, cfg.d_attn)
        logit = logit + L.dense(params["attn_out"], h.reshape(h.shape[0], -1))[:, 0]
    else:
        raise ValueError(cfg.interaction)
    return logit.astype(jnp.float32)


def bce_loss(params, batch, cfg: RecsysConfig) -> jnp.ndarray:
    """batch: ids (B, F, H) int32, labels (B,) float."""
    z = forward_logits(params, batch["ids"], cfg)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))


def serve_scores(params, ids: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    return jax.nn.sigmoid(forward_logits(params, ids, cfg))


def item_embeddings(params, item_ids: jnp.ndarray, cfg: RecsysConfig) -> jnp.ndarray:
    """Item-side feature vectors (for DPP similarity). item_ids (M,) local
    ids within the item field -> (M, D) l2-normalized."""
    offs = int(cfg.spec.offsets[cfg.item_field])
    rows = jnp.take(params["table"], item_ids + offs, axis=0)
    return rows / jnp.maximum(jnp.linalg.norm(rows, axis=-1, keepdims=True), 1e-9)
