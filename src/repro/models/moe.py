"""Mixture-of-Experts layer with explicit expert-parallel dispatch.

GShard-style top-k token-choice routing with per-(source-shard, expert)
capacity.  When a mesh is installed (repro.distributed.context) the layer
runs inside ``jax.shard_map``: tokens are data-sharded, experts are
sharded on the "model" axis, and dispatch/return are explicit
``all_to_all`` collectives — the communication pattern is visible to the
roofline pass rather than left to GSPMD's scatter heuristics.

Without a mesh (unit tests / CPU smoke runs) the identical local math
runs with n_expert_shards == 1 and no collectives.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import context as dctx
from repro.distributed.context import shard_map_compat
from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01


def moe_init(rng, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(rng, 4)
    E, F = cfg.n_experts, cfg.d_ff
    s_in, s_out = d_model ** -0.5, F ** -0.5
    return {
        "router": dense_init(ks[0], d_model, E, jnp.float32),
        "wi": jax.random.normal(ks[1], (E, d_model, F), dtype) * s_in,
        "wg": jax.random.normal(ks[2], (E, d_model, F), dtype) * s_in,
        "wo": jax.random.normal(ks[3], (E, F, d_model), dtype) * s_out,
    }


def _local_moe(
    x, p, cfg: MoEConfig, n_shards: int, model_axis: Optional[str],
    psum_mode: bool = False,
):
    """Per-device MoE body. x (T_loc, d). Runs inside shard_map (or plain)."""
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    E_loc = E // n_shards
    cap = max(8, int(cfg.capacity_factor * T * K / E))

    # --- routing (f32) ---
    logits = (x.astype(jnp.float32) @ p["router"]["w"])  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)  # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)  # (E,)
    one_hot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # (T, K, E)
    ce = jnp.mean(one_hot.sum(1), axis=0) / K  # fraction routed per expert
    aux = E * jnp.sum(me * ce)

    # --- dispatch: position of each (token, slot) within its expert ---
    flat_e = top_e.reshape(-1)  # (T*K,)
    sort_idx = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[sort_idx]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts  # exclusive prefix
    pos_sorted = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((T * K,), jnp.int32).at[sort_idx].set(pos_sorted)
    pos = jnp.where(pos < cap, pos, cap)  # cap -> dropped via mode='drop'

    tok_idx = jnp.arange(T * K, dtype=jnp.int32) // K
    buf = jnp.zeros((E, cap, d), x.dtype)
    buf = buf.at[flat_e, pos].set(x[tok_idx], mode="drop")

    # --- expert-parallel compute ---
    if model_axis is not None and n_shards > 1 and not psum_mode:
        # tokens sharded over (dp x model): explicit all_to_all dispatch
        # (E, cap, d) -> (n_shards, E_loc, cap, d) -> a2a -> recv by source
        send = buf.reshape(n_shards, E_loc, cap, d)
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=0, tiled=False)
        expert_in = jnp.moveaxis(recv, 0, 1).reshape(E_loc, n_shards * cap, d)
        h = jnp.einsum("ecd,edf->ecf", expert_in, p["wi"])
        g = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])
        expert_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"])
        back = jnp.moveaxis(expert_out.reshape(E_loc, n_shards, cap, d), 1, 0)
        recv = jax.lax.all_to_all(back, model_axis, split_axis=0, concat_axis=0, tiled=False)
        out_buf = recv.reshape(E, cap, d)
        slot_out = out_buf.at[flat_e, pos].get(mode="fill", fill_value=0.0)
    elif model_axis is not None and n_shards > 1:
        # psum fallback (decode-scale T): tokens replicated over model, each
        # shard computes only its E_loc experts, outputs psum-combined.
        shard = jax.lax.axis_index(model_axis)
        lo = shard * E_loc
        expert_in = jax.lax.dynamic_slice(buf, (lo, 0, 0), (E_loc, cap, d))
        h = jnp.einsum("ecd,edf->ecf", expert_in, p["wi"])
        g = jnp.einsum("ecd,edf->ecf", expert_in, p["wg"])
        expert_out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"])
        loc_e = flat_e - lo  # out-of-range -> dropped by mode='fill'
        slot_out = expert_out.at[loc_e, pos].get(mode="fill", fill_value=0.0)
        slot_out = jax.lax.psum(slot_out, model_axis)
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
        out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"])
        slot_out = out_buf.at[flat_e, pos].get(mode="fill", fill_value=0.0)

    # --- combine: weight slots, sum over K ---
    slot_out = slot_out.reshape(T, K, d) * top_w[..., None].astype(x.dtype)
    return slot_out.sum(axis=1), aux


def moe_apply(p, x: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    With a mesh installed, tokens are re-partitioned over (data x model)
    for dispatch — every device routes its own token slice to the expert
    owners via all_to_all over the model axis (true expert parallelism:
    no duplicated expert FLOPs across the TP group).  GSPMD inserts the
    cheap reshard (slice on entry, all-gather on exit) at the boundary.
    """
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    mesh = dctx.current_mesh()
    model_axis = dctx.model_axis_name()

    if mesh is None or model_axis is None:
        out, aux = _local_moe(xt, p, cfg, 1, None)
        return out.reshape(B, S, d), aux

    n_shards = mesh.shape[model_axis]
    dp_axes = dctx.data_axis_names()
    T = B * S
    P = jax.sharding.PartitionSpec

    # Token partitioning for dispatch, by preference:
    #   (dp x model)  — full expert parallelism (training / prefill scale);
    #   (model)       — small batches (decode) still use a2a dispatch;
    #   replicated+psum — tiny T (decode with B < model size).
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    if T % (dp_size * n_shards) == 0:
        tok_axes: tuple = tuple(dict.fromkeys(tuple(dp_axes) + (model_axis,)))
        psum_mode = False
    elif T % n_shards == 0:
        tok_axes = (model_axis,)
        psum_mode = False
    else:
        tok_axes = ()
        psum_mode = True

    x_spec = P(tok_axes if tok_axes else None, None)
    p_specs = {
        "router": {"w": P(None, None)},
        "wi": P(model_axis, None, None),
        "wg": P(model_axis, None, None),
        "wo": P(model_axis, None, None),
    }
    pmean_axes = tok_axes if tok_axes else (model_axis,)

    def body(xt_loc, p_loc):
        out, aux = _local_moe(
            xt_loc, p_loc, cfg, n_shards,
            model_axis if n_shards > 1 else None, psum_mode,
        )
        aux = jax.lax.pmean(aux, pmean_axes)
        return out, aux

    out, aux = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(x_spec, p_specs),
        out_specs=(x_spec, P()),
        check=False,
    )(xt, p)
    return out.reshape(B, S, d), aux
