"""Sharded candidate-axis greedy MAP: weak-scaling sweep (beyond-paper).

Fixes the per-device shard size M/P and grows the candidate set M with
the device count P.  The claim under test is the sharded subsystem's
per-step structure: O(w M / P) local work plus one tiny
argmax-allreduce and one winner-broadcast — so ``us_per_user_step``
stays roughly flat as M grows with M/P fixed.  Each (mode, P) cell also
gets a B>1 row: a user batch sharing the mesh (loop state (B, Mloc) per
device, collectives batched over B), whose per-user cost should sit
well below B x the single-slate row.  (On a host-device CPU mesh the
"devices" share the same cores, so flatness is approximate there; the
CSV is evidence of the scaling structure, a real multi-chip mesh is
where the wall-clock win lands.)

XLA pins the host device count at first init, so each P runs in a fresh
subprocess (same pattern as tests/test_distributed.py); the parent
collects and prints one CSV row per (mode, P).

  PYTHONPATH=src python -m benchmarks.fig5_sharded [--full | --smoke]
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

from repro.launch.hostdev import force_host_device_flags  # jax-import-free


def _inner(args) -> None:
    """Runs inside the subprocess with the device count already forced."""
    import time

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core.sharded import dpp_greedy_sharded
    from repro.distributed.context import make_mesh_compat
    from repro.kernels.dpp_greedy import VMEM_BUDGET_BYTES, untiled_vmem_bytes

    P = jax.device_count()
    M = args.mloc * P
    mesh = make_mesh_compat((P,), ("data",))
    rng = np.random.default_rng(0)
    Vb = jnp.asarray(
        rng.normal(size=(args.batch, args.dim, M)), jnp.float32
    ) / np.sqrt(args.dim)

    # B=1 single-slate rows plus a B>1 batched row per mode: the batched
    # rows measure the users x candidates composition — B slates share
    # the mesh, per-step collectives batch over B, so us_per_user_step
    # should sit well below B x the single-slate cost.  Each cell also
    # gets a tile_m row (tm<tile> label): the per-device local update
    # streamed through the tiled Pallas pass — past_gate=1 marks shards
    # whose (D, Mloc) working set exceeds the resident kernels' VMEM
    # budget, i.e. the regime the old vmem gate surrendered to jnp.
    for label, window in (("exact", None), (f"w{args.window}", args.window)):
        state_rows = args.slate if window is None else min(window, args.slate)
        past = int(
            untiled_vmem_bytes(args.dim, args.mloc, state_rows)
            > VMEM_BUDGET_BYTES
        )
        for tile in (None, args.tile_m):
            for B in sorted({1, args.batch}):
                V = Vb[0] if B == 1 else Vb[:B]
                fn = lambda: dpp_greedy_sharded(
                    V, args.slate, mesh=mesh, window=window, eps=1e-6,
                    tile_m=tile,
                )
                fn().indices.block_until_ready()  # compile + warm
                best = float("inf")
                for _ in range(args.trials):
                    t0 = time.perf_counter()
                    fn().indices.block_until_ready()
                    best = min(best, time.perf_counter() - t0)
                tl = "" if tile is None else f"_tm{tile}"
                print(
                    f"fig5_sharded_{label}{tl}_B{B}_P{P}_M{M},{best*1e6:.1f},"
                    f"us_per_user_step={best/(args.slate*B)*1e6:.2f};"
                    f"B={B};Mloc={args.mloc};D={args.dim};N={args.slate};"
                    f"tile_m={tile or 0};past_gate={past}"
                )


def run(devices, mloc, dim, slate, window, trials, batch, tile_m):
    rows, failures = [], []
    for P in devices:
        env = dict(os.environ)
        # preserve inherited XLA flags, replacing only the device count
        env["XLA_FLAGS"] = force_host_device_flags(env.get("XLA_FLAGS", ""), P)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(REPO, "src"), env.get("PYTHONPATH", "")) if p
        )
        cmd = [
            sys.executable, "-m", "benchmarks.fig5_sharded", "--inner",
            "--mloc", str(mloc), "--dim", str(dim), "--slate", str(slate),
            "--window", str(window), "--trials", str(trials),
            "--batch", str(batch), "--tile-m", str(tile_m),
        ]
        out = subprocess.run(
            cmd, capture_output=True, text=True, env=env, cwd=REPO, timeout=1200
        )
        if out.returncode != 0:
            tail = out.stderr.strip().splitlines()[-1:] or ["<no stderr>"]
            print(f"fig5_sharded_P{P},0,error={tail[0]}")
            failures.append((P, tail[0]))
            continue
        for line in out.stdout.strip().splitlines():
            if line.startswith("fig5_sharded"):
                print(line)
                rows.append(line)
    if failures:
        # fail loudly so the CI smoke step (and benchmarks.run) go red
        raise RuntimeError(f"fig5_sharded subprocess failures: {failures}")
    return rows


_PRESETS = {
    # fast: tiny shapes + 1/2 devices (CI smoke / benchmarks.run default)
    True: dict(devices=(1, 2), mloc=2048, dim=24, slate=8, window=4, trials=2,
               batch=4, tile_m=512),
    # full: Mloc=65536 at D=32 puts the per-device shard past the
    # resident kernels' VMEM budget (past_gate=1 rows) — the regime the
    # tiled local update exists for
    False: dict(devices=(1, 2, 4, 8), mloc=65536, dim=32, slate=32, window=8,
                trials=3, batch=8, tile_m=8192),
}


def main(fast_mode: bool = True, **overrides):
    cfg = dict(_PRESETS[fast_mode])
    cfg.update({k: v for k, v in overrides.items() if v is not None})
    print("name,us_per_call,derived")
    return run(cfg["devices"], cfg["mloc"], cfg["dim"], cfg["slate"],
               cfg["window"], cfg["trials"], cfg["batch"], cfg["tile_m"])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 1/2 devices (CI)")
    # shape flags: honored by both the outer sweep and --inner; unset
    # values fall back to the --smoke/--full preset
    ap.add_argument("--mloc", type=int, default=None)
    ap.add_argument("--dim", type=int, default=None)
    ap.add_argument("--slate", type=int, default=None)
    ap.add_argument("--window", type=int, default=None)
    ap.add_argument("--trials", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None,
                    help="user-batch B for the B>1 rows (1 = single-slate only)")
    ap.add_argument("--tile-m", type=int, default=None, dest="tile_m",
                    help="tile for the Pallas local-update rows (tm<tile>)")
    args = ap.parse_args()
    fast = args.smoke or not args.full
    if args.inner:
        # the outer sweep passes every shape flag explicitly; direct
        # --inner invocations fall back to the preset here (main() owns
        # the preset merge for the outer path)
        for k, v in _PRESETS[fast].items():
            if k != "devices" and getattr(args, k, None) is None:
                setattr(args, k, v)
        _inner(args)
    else:
        main(fast_mode=fast, mloc=args.mloc, dim=args.dim, slate=args.slate,
             window=args.window, trials=args.trials, batch=args.batch,
             tile_m=args.tile_m)
