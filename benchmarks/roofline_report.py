"""Aggregate dry-run cell JSONs into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun]
      [--mesh pod] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(d: str):
    cells = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def fmt_t(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(cells, mesh: str, markdown: bool):
    rows = []
    for c in cells:
        if c.get("mesh") != mesh:
            continue
        if c.get("profile", "baseline") != "baseline":
            continue  # §Perf profile runs are reported separately
        if c.get("status") == "skipped":
            rows.append((c["arch"], c["shape"], "SKIPPED", "-", "-", "-", "-", "-",
                         c.get("reason", "")[:46]))
            continue
        if c.get("status") != "ok":
            rows.append((c["arch"], c["shape"], c.get("status", "?"),
                         "-", "-", "-", "-", "-", ""))
            continue
        dom = c["bottleneck"]
        rows.append((
            c["arch"], c["shape"], dom,
            fmt_t(c["t_compute"]), fmt_t(c["t_memory"]), fmt_t(c["t_collective"]),
            f"{c['roofline_fraction']:.3f}",
            f"{c['useful_flops_ratio']:.2f}",
            what_moves(c),
        ))
    rows.sort()
    hdr = ("arch", "shape", "bottleneck", "t_comp", "t_mem", "t_coll",
           "roofline", "useful", "what moves the dominant term")
    widths = [max(len(str(r[i])) for r in rows + [hdr]) for i in range(len(hdr))]
    sep = " | " if markdown else "  "
    lines = [sep.join(str(h).ljust(w) for h, w in zip(hdr, widths))]
    if markdown:
        lines.insert(0, "")
        lines.append(sep.join("-" * w for w in widths))
        lines[0], lines[-1] = lines[-1], lines[0]
        lines = [lines[1], lines[0]] + lines[2:]
    for r in rows:
        lines.append(sep.join(str(v).ljust(w) for v, w in zip(r, widths)))
    return "\n".join(lines)


def what_moves(c) -> str:
    """One phrase on what would move the dominant term down."""
    dom = c["bottleneck"]
    kinds = c.get("coll_by_kind", {})
    if dom == "collective":
        big = max(kinds, key=kinds.get) if kinds else "?"
        if big == "all-reduce":
            return "cut TP act all-reduce (seq-par / FSDP-only / a2a emb)"
        if big == "all-gather":
            return "overlap FSDP gathers; bigger per-device shards"
        if big == "all-to-all":
            return "lower MoE capacity factor; fuse a2a"
        return f"reduce {big}"
    if dom == "memory":
        if c["shape"].startswith(("decode", "long")):
            return "KV-cache quant/bf16; fuse decode attn reads"
        return "flash-attn remat policy; bf16 intermediates; fuse"
    return "larger per-chip tiles; reduce remat recompute"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", nargs="+", default=["pod", "multipod"])
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    for m in args.mesh:
        print(f"\n=== mesh: {m} ===")
        print(table(cells, m, args.markdown))


if __name__ == "__main__":
    main()
