"""Paper Figure 1: running time of the original (determinant-based)
greedy MAP vs the proposed Div-DPP acceleration, N = 0..50 step 5,
M = 1000, D = 100 synthetic (paper §5.1 setup exactly).

Also reports the Pallas whole-slate kernel (interpret mode on CPU — the
interpreter adds Python overhead, so its wall time is NOT the TPU story;
it is included for completeness and validated for exactness).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    build_kernel_dense_raw,
    dpp_greedy_dense,
    greedy_map_naive,
    normalize_columns,
    similarity_from_features,
)


def setup(M=1000, D=100, seed=0):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.uniform(size=M), jnp.float32)
    F = normalize_columns(jnp.asarray(rng.uniform(size=(D, M)), jnp.float32))
    S = similarity_from_features(F)
    L = build_kernel_dense_raw(r, S)
    return np.asarray(L, np.float64), L


def run(trials=3, Ns=tuple(range(5, 55, 5)), M=1000, D=100):
    rows = []
    L64, L = setup(M, D)
    for N in Ns:
        # proposed: fast Cholesky greedy (jit; time steady-state)
        dpp_greedy_dense(L, N).indices.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(trials):
            dpp_greedy_dense(L, N).indices.block_until_ready()
        t_fast = (time.perf_counter() - t0) / trials

        # original: determinant per candidate per step (numpy float64,
        # same as the paper's numpy.linalg.det baseline)
        t0 = time.perf_counter()
        naive_idx, _ = greedy_map_naive(L64, N)
        t_naive = time.perf_counter() - t0

        fast_idx = np.asarray(dpp_greedy_dense(L, N).indices)
        same = bool((fast_idx == naive_idx[:N]).all())
        rows.append((N, t_naive, t_fast, t_naive / max(t_fast, 1e-9), same))
    return rows


def main(fast_mode=False):
    trials = 2 if fast_mode else 3
    Ns = (5, 10, 20) if fast_mode else tuple(range(5, 55, 5))
    rows = run(trials=trials, Ns=Ns)
    print("name,us_per_call,derived")
    for N, t_naive, t_fast, speedup, same in rows:
        print(f"fig1_naive_N{N},{t_naive*1e6:.1f},exact_match={same}")
        print(f"fig1_divdpp_N{N},{t_fast*1e6:.1f},speedup={speedup:.1f}x")
    return rows


if __name__ == "__main__":
    main()
