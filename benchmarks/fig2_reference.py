"""Paper Figure 2: running time of MMR, Greedy [3], and Div-DPP on the
same synthetic setup (M = 1000, D = 100) — Div-DPP must be *comparable*
to the O(MN) reference diversifiers."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    build_kernel_dense_raw,
    dpp_greedy_dense,
    greedy_avg_select,
    mmr_select,
    normalize_columns,
    similarity_from_features,
)


def main(fast_mode=False):
    M, D = 1000, 100
    trials = 3 if fast_mode else 10
    Ns = (5, 10, 20) if fast_mode else tuple(range(5, 55, 5))
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.uniform(size=M), jnp.float32)
    F = normalize_columns(jnp.asarray(rng.uniform(size=(D, M)), jnp.float32))
    S = similarity_from_features(F)
    L = build_kernel_dense_raw(r, S)

    def bench(fn):
        fn()  # compile
        t0 = time.perf_counter()
        for _ in range(trials):
            fn()
        return (time.perf_counter() - t0) / trials

    print("name,us_per_call,derived")
    rows = []
    for N in Ns:
        t_mmr = bench(lambda: mmr_select(r, S, N, 0.5).block_until_ready())
        t_grd = bench(lambda: greedy_avg_select(r, S, N, 0.5).block_until_ready())
        t_dpp = bench(lambda: dpp_greedy_dense(L, N).indices.block_until_ready())
        rows.append((N, t_mmr, t_grd, t_dpp))
        print(f"fig2_mmr_N{N},{t_mmr*1e6:.1f},")
        print(f"fig2_greedy_N{N},{t_grd*1e6:.1f},")
        print(f"fig2_divdpp_N{N},{t_dpp*1e6:.1f},ratio_vs_mmr={t_dpp/max(t_mmr,1e-9):.2f}")
    return rows


if __name__ == "__main__":
    main()
