"""Benchmark harness — one module per paper figure/table plus the
roofline report.  Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--full] [--out-dir DIR]

Default is a fast mode sized for CI; ``--full`` reproduces the paper's
exact sweep sizes (M=1000, D=100, N=5..50, all three datasets).

Besides streaming the CSV to stdout, every figure writes a
``BENCH_<fig>.json`` artifact to ``--out-dir`` (default
``benchmarks/results``): the parsed rows, wall-clock elapsed, the gate
outcome (``status``/``error`` — the figures raise on red gates), and
the observability snapshot of everything that ran (kernel dispatch
counts, launched steps, marginal evaluations, jit cache misses) — the
harness keeps a ``repro.obs`` session installed so the telemetry is on
for every figure.  A figure failing its gates does not stop the rest;
the harness exits nonzero at the end if any failed.
"""
from __future__ import annotations

import argparse
import contextlib
import hashlib
import io
import json
import os
import sys
import time

from repro import obs
from repro.obs import ObsConfig


def bench_meta():
    """Device/provenance stamp for every BENCH_<fig>.json: which
    accelerator and jax produced the numbers, plus the tile overrides in
    effect (``DPP_TILE_M`` and the autotune cache file + content hash) —
    enough to tell two artifacts apart without re-running anything.
    Purely best-effort: a field that cannot be determined reads
    "unknown" rather than failing the benchmark that produced it."""
    meta = {
        "device_kind": "unknown", "platform": "unknown",
        "backend": "unknown", "jax": "unknown", "jaxlib": "unknown",
        "dpp_tile_m": os.environ.get("DPP_TILE_M"),
        "autotune_cache": None, "autotune_cache_sha256": None,
    }
    try:
        import jax

        meta["jax"] = jax.__version__
        try:
            import jaxlib

            meta["jaxlib"] = jaxlib.__version__
        except Exception:
            pass
        from repro.kernels.dpp_greedy.autotune import (
            active_cache_path,
            device_fingerprint,
        )

        dk, plat, backend = device_fingerprint()
        meta.update(device_kind=dk, platform=plat, backend=backend)
        path = active_cache_path()
        meta["autotune_cache"] = path
        if os.path.exists(path):
            with open(path, "rb") as f:
                meta["autotune_cache_sha256"] = hashlib.sha256(
                    f.read()
                ).hexdigest()
    except Exception:
        pass
    return meta


class _Tee(io.TextIOBase):
    """Mirror writes to the real stdout while keeping a copy to parse."""

    def __init__(self, real):
        self._real = real
        self._buf = io.StringIO()

    def write(self, s):
        self._real.write(s)
        return self._buf.write(s)

    def flush(self):
        self._real.flush()

    def getvalue(self):
        return self._buf.getvalue()


def _parse_rows(text):
    rows = []
    for line in text.splitlines():
        if line.startswith("#") or line.startswith("name,"):
            continue
        parts = line.split(",", 2)
        if len(parts) != 3:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        rows.append(
            {"name": parts[0], "us_per_call": us, "derived": parts[2]}
        )
    return rows


def run_fig(fig, title, fn, fast, out_dir):
    """Run one figure main, tee its CSV, and write BENCH_<fig>.json.
    Returns True when the figure's gates passed."""
    print(f"# {title}")
    if not obs.enabled():  # a figure may own (and tear down) a session
        obs.enable(ObsConfig(enabled=True))
    tee = _Tee(sys.stdout)
    t0 = time.perf_counter()
    status, error = "ok", None
    try:
        with contextlib.redirect_stdout(tee):
            fn(fast_mode=fast)
    except Exception as e:
        status, error = "failed", f"{type(e).__name__}: {e}"
        print(f"{fig}_gate,0,status=FAILED;{error}")
    doc = {
        "figure": fig,
        "status": status,
        "error": error,
        "fast_mode": fast,
        "elapsed_s": round(time.perf_counter() - t0, 3),
        "meta": bench_meta(),
        "rows": _parse_rows(tee.getvalue()),
    }
    if obs.registry() is not None:
        doc["obs"] = obs.registry().snapshot()
    path = os.path.join(out_dir, f"BENCH_{fig}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    return status == "ok"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out-dir", default="benchmarks/results",
                    help="where BENCH_<fig>.json artifacts land")
    args, _ = ap.parse_known_args()
    fast = not args.full
    os.makedirs(args.out_dir, exist_ok=True)

    from benchmarks import (
        fig1_speedup,
        fig2_reference,
        fig3_tradeoff,
        fig4_windowed,
        fig5_sharded,
        fig6_streaming,
        fig7_serving,
        fig8_observability,
        fig9_autotune,
        fig10_session,
    )

    figures = [
        ("fig1", "Figure 1: original greedy MAP vs Div-DPP (speedup, "
         "exactness)", fig1_speedup.main),
        ("fig2", "Figure 2: MMR / Greedy / Div-DPP runtime",
         fig2_reference.main),
        ("fig3", "Figure 3: accuracy-diversity trade-off",
         fig3_tradeoff.main),
        ("fig4", "Figure 4: sliding-window vs exact, N >> w (per-step cost "
         "flat in N)", fig4_windowed.main),
        ("fig5", "Figure 5: sharded candidate-axis greedy, M/P fixed (weak "
         "scaling)", fig5_sharded.main),
        ("fig6", "Figure 6: streaming slate emission, time-to-first-chunk "
         "vs whole", fig6_streaming.main),
        ("fig7", "Figure 7: continuous-batching router, QPS vs latency "
         "percentiles", fig7_serving.main),
        ("fig8", "Figure 8: observability — pump breakdown and the "
         "recompile ledger", fig8_observability.main),
        ("fig9", "Figure 9: measured autotune cache vs the analytical "
         "VMEM model", fig9_autotune.main),
        ("fig10", "Figure 10: session delta-resume vs full re-rerank "
         "(latency, parity)", fig10_session.main),
    ]
    failed = [
        fig for fig, title, fn in figures
        if not run_fig(fig, title, fn, fast, args.out_dir)
    ]

    print("# Roofline (from dry-run artifacts, if present)")
    try:
        from benchmarks import roofline_report

        cells = roofline_report.load_cells("experiments/dryrun")
        if cells:
            ok = sum(1 for c in cells if c.get("status") == "ok")
            sk = sum(1 for c in cells if c.get("status") == "skipped")
            print(f"roofline_cells,0,ok={ok};skipped={sk};total={len(cells)}")
        else:
            print("roofline_cells,0,none (run repro.launch.run_dryruns)")
    except Exception as e:  # pragma: no cover
        print(f"roofline_cells,0,error={e}")

    obs.disable()
    if failed:
        raise SystemExit(f"figures with failed gates: {failed}")


if __name__ == "__main__":
    main()
