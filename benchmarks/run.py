"""Benchmark harness — one module per paper figure/table plus the
roofline report.  Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--full]

Default is a fast mode sized for CI; ``--full`` reproduces the paper's
exact sweep sizes (M=1000, D=100, N=5..50, all three datasets).
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args, _ = ap.parse_known_args()
    fast = not args.full

    from benchmarks import (
        fig1_speedup,
        fig2_reference,
        fig3_tradeoff,
        fig4_windowed,
        fig5_sharded,
        fig6_streaming,
        fig7_serving,
    )

    print("# Figure 1: original greedy MAP vs Div-DPP (speedup, exactness)")
    fig1_speedup.main(fast_mode=fast)
    print("# Figure 2: MMR / Greedy / Div-DPP runtime")
    fig2_reference.main(fast_mode=fast)
    print("# Figure 3: accuracy-diversity trade-off")
    fig3_tradeoff.main(fast_mode=fast)
    print("# Figure 4: sliding-window vs exact, N >> w (per-step cost flat in N)")
    fig4_windowed.main(fast_mode=fast)
    print("# Figure 5: sharded candidate-axis greedy, M/P fixed (weak scaling)")
    fig5_sharded.main(fast_mode=fast)
    print("# Figure 6: streaming slate emission, time-to-first-chunk vs whole")
    fig6_streaming.main(fast_mode=fast)
    print("# Figure 7: continuous-batching router, QPS vs latency percentiles")
    fig7_serving.main(fast_mode=fast)

    print("# Roofline (from dry-run artifacts, if present)")
    try:
        from benchmarks import roofline_report

        cells = roofline_report.load_cells("experiments/dryrun")
        if cells:
            ok = sum(1 for c in cells if c.get("status") == "ok")
            sk = sum(1 for c in cells if c.get("status") == "skipped")
            print(f"roofline_cells,0,ok={ok};skipped={sk};total={len(cells)}")
        else:
            print("roofline_cells,0,none (run repro.launch.run_dryruns)")
    except Exception as e:  # pragma: no cover
        print(f"roofline_cells,0,error={e}")


if __name__ == "__main__":
    main()
