"""Windowed-vs-exact sweep (beyond-paper; NeurIPS'18 sliding window),
plus the past-the-VMEM-gate kernel sweep.

**N-sweep** — fixes the candidate set M and the window w, then grows
the slate length N up to 8x w.  The claim under test is the incremental
sliding-window implementation's complexity: per-step cost O(w M),
*independent of N* — the Cholesky ring ``C (w, M)`` is fixed-size
state, whereas the exact Algorithm 1 carries O(N M) state whose
per-step matvec grows with N.  Expected CSV shape: ``win_us_per_step``
flat in N (within noise; ``win_step_vs_N<w>`` stays ~1x).

**Gate sweep** — grows M through the resident kernels' VMEM budget.
Rows with ``past_gate=1`` are configs where
``untiled_vmem_bytes(D, M, w) > VMEM_BUDGET_BYTES``: before the tiled
kernels these silently degraded to the pure-jnp path; now the
``TilePolicy`` auto-tiles the candidate axis (``tile_m`` in the derived
column) and the Pallas path keeps running.  Each row cross-checks the
kernel slate against the jnp oracle (``parity=ok``) and reports
``kernel_vs_jnp`` wall-clock (interpret mode on CPU measures structure,
not the TPU win).

  PYTHONPATH=src python -m benchmarks.fig4_windowed [--smoke | --full]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    GreedySpec,
    greedy_map,
    map_relevance,
)


def setup(M, D, seed=0, alpha=2.0):
    rng = np.random.default_rng(seed)
    F = jnp.asarray(rng.normal(size=(D, M)), jnp.float32)
    F = F / jnp.maximum(jnp.linalg.norm(F, axis=0, keepdims=True), 1e-12)
    r = jnp.asarray(rng.uniform(size=M), jnp.float32)
    return F * map_relevance(r, alpha)[None, :]


def _time(fn, trials):
    fn().indices.block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        fn().indices.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(M=1000, D=100, w=8, trials=3):
    V = setup(M, D)
    rows = []
    for N in (w, 2 * w, 4 * w, 8 * w):
        win_spec = GreedySpec(k=N, window=w, eps=1e-6)
        exact_spec = GreedySpec(k=N, eps=1e-6)
        t_win = _time(lambda: greedy_map(win_spec, V=V), trials)
        t_exact = _time(lambda: greedy_map(exact_spec, V=V), trials)
        rows.append((N, w, t_win, t_exact))
    return rows


def run_gate(cells, k, trials):
    """cells: (M, D, w) triples; returns CSV-ready gate-sweep rows."""
    from repro.kernels.dpp_greedy import (
        VMEM_BUDGET_BYTES,
        TilePolicy,
        dpp_greedy,
        untiled_vmem_bytes,
    )

    rows = []
    for M, D, w in cells:
        V = setup(M, D)[None]  # (1, D, M)
        past = int(untiled_vmem_bytes(D, M, w) > VMEM_BUDGET_BYTES)
        mode, tm = TilePolicy().decide(D, M, w, windowed=True)

        def timed(fn):
            sel, _ = fn()
            sel.block_until_ready()  # compile + warm
            best = float("inf")
            for _ in range(trials):
                t0 = time.perf_counter()
                fn()[0].block_until_ready()
                best = min(best, time.perf_counter() - t0)
            return best, sel

        t_k, sel_k = timed(
            lambda: dpp_greedy(V, k, window=w, eps=1e-6, interpret=True)
        )
        t_j, sel_j = timed(
            lambda: dpp_greedy(V, k, window=w, eps=1e-6, force_jnp=True)
        )
        parity = (
            "ok"
            if np.array_equal(np.asarray(sel_k), np.asarray(sel_j))
            else "FAIL"
        )
        rows.append(
            (M, D, w, k, past, mode, tm or 0, t_k, t_j, parity)
        )
    return rows


def main(fast_mode=False):
    M, D, w = (400, 48, 8) if fast_mode else (1000, 100, 8)
    trials = 2 if fast_mode else 5
    rows = run(M=M, D=D, w=w, trials=trials)
    print("name,us_per_call,derived")
    base = rows[0][2] / rows[0][0]
    for N, w, t_win, t_exact in rows:
        print(
            f"fig4_windowed_w{w}_N{N},{t_win*1e6:.1f},"
            f"win_us_per_step={t_win/N*1e6:.2f};"
            f"exact_us_per_step={t_exact/N*1e6:.2f};"
            f"win_step_vs_N{rows[0][0]}={t_win/N/base:.2f}x"
        )

    # gate sweep: one in-gate cell plus at least one past-the-gate cell
    # (the acceptance bar for the tiled kernels: the Pallas path keeps
    # running where the old vmem gate fell back to jnp); N > w so the
    # windowed kernel — eviction included — is what runs past the gate
    if fast_mode:
        cells, k, gtrials = [(4096, 32, 8), (65536, 64, 8)], 16, 1
    else:
        cells, k, gtrials = (
            [(4096, 32, 8), (65536, 64, 8), (131072, 64, 8)],
            16,
            3,
        )
    grows = run_gate(cells, k, gtrials)
    for M, D, w, k_, past, mode, tm, t_k, t_j, parity in grows:
        print(
            f"fig4_gate_M{M}_D{D}_w{w},{t_k*1e6:.1f},"
            f"past_gate={past};mode={mode};tile_m={tm};"
            f"jnp_us={t_j*1e6:.1f};kernel_vs_jnp={t_j/max(t_k, 1e-12):.2f}x;"
            f"parity={parity};N={k_}"
        )
    if any(r[9] != "ok" for r in grows):
        raise RuntimeError(f"fig4 gate sweep parity failure: {grows}")
    return rows, grows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 timing trial (CI)")
    args = ap.parse_args()
    main(fast_mode=args.smoke or not args.full)
