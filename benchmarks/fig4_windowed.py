"""Windowed-vs-exact sweep (beyond-paper; NeurIPS'18 sliding window).

Fixes the candidate set M and the window w, then grows the slate length
N up to 8x w.  The claim under test is the incremental sliding-window
implementation's complexity: per-step cost O(w M), *independent of N* —
the Cholesky ring ``C (w, M)`` is fixed-size state, whereas the exact
Algorithm 1 carries O(N M) state whose per-step matvec grows with N.

Expected shape of the CSV: ``win_us_per_step`` flat in N (within noise;
``win_step_vs_N<w>`` stays ~1x).  The exact path's per-step cost grows
with N asymptotically, though at CPU benchmark sizes it is still
dispatch-overhead-dominated — the structural win the window buys is the
O(w M) state (slate length unbounded, no eps-stop at the kernel rank),
not the small-N constant.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core import (
    GreedySpec,
    greedy_map,
    map_relevance,
)


def setup(M, D, seed=0, alpha=2.0):
    rng = np.random.default_rng(seed)
    F = jnp.asarray(rng.normal(size=(D, M)), jnp.float32)
    F = F / jnp.maximum(jnp.linalg.norm(F, axis=0, keepdims=True), 1e-12)
    r = jnp.asarray(rng.uniform(size=M), jnp.float32)
    return F * map_relevance(r, alpha)[None, :]


def _time(fn, trials):
    fn().indices.block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        fn().indices.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(M=1000, D=100, w=8, trials=3):
    V = setup(M, D)
    rows = []
    for N in (w, 2 * w, 4 * w, 8 * w):
        win_spec = GreedySpec(k=N, window=w, eps=1e-6)
        exact_spec = GreedySpec(k=N, eps=1e-6)
        t_win = _time(lambda: greedy_map(win_spec, V=V), trials)
        t_exact = _time(lambda: greedy_map(exact_spec, V=V), trials)
        rows.append((N, w, t_win, t_exact))
    return rows


def main(fast_mode=False):
    M, D, w = (400, 48, 8) if fast_mode else (1000, 100, 8)
    trials = 2 if fast_mode else 5
    rows = run(M=M, D=D, w=w, trials=trials)
    print("name,us_per_call,derived")
    base = rows[0][2] / rows[0][0]
    for N, w, t_win, t_exact in rows:
        print(
            f"fig4_windowed_w{w}_N{N},{t_win*1e6:.1f},"
            f"win_us_per_step={t_win/N*1e6:.2f};"
            f"exact_us_per_step={t_exact/N*1e6:.2f};"
            f"win_step_vs_N{rows[0][0]}={t_win/N/base:.2f}x"
        )
    return rows


if __name__ == "__main__":
    main()
