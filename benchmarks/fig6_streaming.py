"""Streaming slate emission: time-to-first-chunk vs whole-slate latency
(beyond-paper; the serving shape the NeurIPS'18 sliding window exists
for — repulsion only among nearby items means a long feed can start
rendering after the first chunk instead of blocking on the whole
slate).

For a windowed long-slate config (N >> w) each backend serves the same
request twice: once through whole-slate ``Reranker.rerank`` and once
through ``Reranker.stream`` with ``chunk_size`` items per chunk.
Reported per
row: steady-state time-to-first-chunk (the headline number), the
whole-slate latency it undercuts, the full-stream wall clock (the
price of chunking), and a parity flag — the concatenated chunks must
equal the whole slate index for index, checked every run and failed
red on mismatch.

The pallas row additionally counts the fused multi-step chunk kernel's
``pallas_call`` invocations (``fused_calls_per_chunk``): the chunked
path must make **one** call — one HBM C/d2 round-trip — per chunk,
not one per step (the ROADMAP's sweep-fusion headroom; see
``repro.kernels.dpp_greedy.tiled``).

Interpret mode on CPU measures structure, not the TPU win: the
time-to-first-chunk < whole-slate ordering is asserted (it reflects
executing ``chunk`` greedy steps instead of N before first emission),
the absolute ratios are not.

  PYTHONPATH=src python -m benchmarks.fig6_streaming [--smoke | --full]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.serving import DPPRerankConfig, Reranker, RerankRequest


def setup(M, D, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.maximum(np.linalg.norm(feats, axis=1, keepdims=True), 1e-12)
    scores = rng.uniform(size=M).astype(np.float32)
    return jnp.asarray(scores), jnp.asarray(feats)


def time_whole(scores, feats, cfg, trials):
    rr = Reranker(cfg)
    req = RerankRequest(scores=scores, feats=feats)
    rr.rerank(req)[0].block_until_ready()  # compile + warm
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        slate, _ = rr.rerank(req)
        slate.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(slate)


def time_stream(scores, feats, cfg, trials):
    rr = Reranker(cfg)
    req = RerankRequest(scores=scores, feats=feats)
    for c, _ in rr.stream(req):  # compile + warm
        c.block_until_ready()
    best_first = best_total = float("inf")
    for _ in range(trials):
        chunks = []
        t0 = time.perf_counter()
        t_first = None
        for c, _ in rr.stream(req):
            c.block_until_ready()
            if t_first is None:
                t_first = time.perf_counter() - t0
            chunks.append(np.asarray(c))
        best_total = min(best_total, time.perf_counter() - t0)
        best_first = min(best_first, t_first)
    return best_first, best_total, np.concatenate(chunks)


def count_fused_calls(scores, feats, cfg):
    """HBM C/d2 round-trips per chunk on the fused streaming path,
    counted structurally: trace one chunk advance and count its
    pallas_call eqns with ``tiled.pallas_call_structure``.  The fused
    path must show exactly one, not under any loop — one kernel launch
    (one C/d2 round-trip) per chunk, however many steps the chunk
    spans."""
    from repro.kernels.dpp_greedy.tiled import pallas_call_structure
    from repro.serving.reranker import _shortlist_kernel
    from repro.core.streaming import greedy_chunk, greedy_init

    spec = cfg.greedy_spec()
    V, m_top, _ = _shortlist_kernel(scores, feats, cfg, mask=None)
    state = greedy_init(spec, V=V, mask=m_top)
    jaxpr = jax.make_jaxpr(
        lambda s, v: greedy_chunk(spec, s, V=v,
                                  chunk_size=cfg.chunk_size)
    )(state, V)
    counts = pallas_call_structure(jaxpr)
    if counts["looped"]:
        return float("inf")  # a per-step launch survived inside a loop
    return float(counts["flat"])


def run(M, D, N, w, chunk, trials):
    scores, feats = setup(M, D)
    base = dict(slate_size=N, shortlist=M, alpha=3.0, eps=1e-6, window=w,
                chunk_size=chunk)
    rows = []
    for name, extra in [
        ("jnp", {}),
        ("pallas_tiled", dict(use_kernel=True, tile_m=128)),
    ]:
        cfg = DPPRerankConfig(**base, **extra)
        # whole-slate latency: measure the UNCHUNKED path (chunk_size
        # also switches greedy_map to chunked execution, which is the
        # streaming path's cost, not the blocking baseline's)
        whole_cfg = DPPRerankConfig(
            **{**base, "chunk_size": None}, **extra
        )
        t_whole, slate = time_whole(scores, feats, whole_cfg, trials)
        t_first, t_total, streamed = time_stream(scores, feats, cfg, trials)
        parity = "ok" if np.array_equal(slate, streamed) else "FAIL"
        fused = (
            count_fused_calls(scores, feats, cfg)
            if extra.get("use_kernel") else 0.0
        )
        rows.append(
            (name, M, D, N, w, chunk, t_first, t_whole, t_total, fused,
             parity)
        )
    return rows


def main(fast_mode=False):
    # N >> chunk and M large enough that per-step compute (not per-call
    # dispatch overhead) dominates: time-to-first-chunk then has a
    # structural margin over the whole slate (c of N steps) that
    # survives noisy CI runners
    M, D, N, w, chunk = (
        (2048, 32, 64, 8, 8) if fast_mode else (2048, 32, 96, 8, 8)
    )
    trials = 2 if fast_mode else 5
    rows = run(M, D, N, w, chunk, trials)
    print("name,us_per_call,derived")
    for (name, M_, D_, N_, w_, c_, t_first, t_whole, t_total, fused,
         parity) in rows:
        print(
            f"fig6_stream_{name}_M{M_}_N{N_},{t_first*1e6:.1f},"
            f"whole_us={t_whole*1e6:.1f};stream_total_us={t_total*1e6:.1f};"
            f"first_chunk_vs_whole={t_first/max(t_whole, 1e-12):.2f}x;"
            f"chunk={c_};w={w_};fused_calls_per_chunk={fused:.1f};"
            f"parity={parity}"
        )
    bad = [r for r in rows if r[10] != "ok"]
    if bad:
        raise RuntimeError(f"fig6 streamed-vs-whole parity failure: {bad}")
    slow = [r for r in rows if not r[6] < r[7]]
    if slow:
        raise RuntimeError(
            f"fig6: time-to-first-chunk did not beat whole-slate latency: "
            f"{slow}"
        )
    fused_bad = [r for r in rows if r[0].startswith("pallas") and r[9] > 1]
    if fused_bad:
        raise RuntimeError(
            f"fig6: fused streaming made more than one pallas_call per "
            f"chunk: {fused_bad}"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 2 timing trials (CI)")
    args = ap.parse_args()
    main(fast_mode=args.smoke or not args.full)
