"""Paper Figure 3: accuracy-diversity trade-off of Random/Top, MMR,
Greedy [3], and Div-DPP on three synthetic datasets shaped like
MovieLens / Last.FM / Jester (offline container — see
repro.data.interactions for the generation model), using the paper's
§5.2 protocol: leave-one-out split, SUGGEST-style item-item similarity,
top-K-similar candidate sets, aggregated-similarity relevance, recall +
average/minimum/median dissimilarity.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import (
    build_kernel_dense,
    dpp_greedy_dense,
    greedy_avg_select,
    mean_slate_diversity,
    mmr_select,
    random_top_select,
    recall_at_n,
)
from repro.data import candidates_and_relevance, item_similarity, load_preset

DATASETS = {
    "movielens-like": dict(N=20, K=30),
    "lastfm-like": dict(N=10, K=20),
    "jester-like": dict(N=10, K=20),
}


def eval_algorithm(ds, S, cands, N, select_fn, rng=None):
    """select_fn(cand_ids, rel) -> local indices into cand_ids (N,)."""
    slates, tests = [], []
    for u in range(ds.n_users):
        cand, rel = cands[u]
        if cand.size < N:
            continue
        local = np.asarray(select_fn(cand, rel))
        local = local[local >= 0]
        slates.append(np.pad(cand[local], (0, N - local.size), constant_values=-1))
        tests.append(ds.test[u])
    slates = np.stack(slates)
    rec = recall_at_n(slates, np.asarray(tests))
    div = mean_slate_diversity(slates, S)
    return rec, div


def run_dataset(name, N, K, alphas, thetas, bs, seed=0):
    ds = load_preset(name, seed=seed)
    S = item_similarity(ds)
    cands = candidates_and_relevance(ds, S, top_k_similar=K)
    rng = np.random.default_rng(seed)
    rows = []

    def normalize(rel):
        lo, hi = rel.min(), rel.max()
        return (rel - lo) / max(hi - lo, 1e-9)

    for b in bs:
        rec, div = eval_algorithm(
            ds, S, cands, N,
            lambda cand, rel, b=b: random_top_select(np.asarray(rel), N, b, rng),
        )
        rows.append((f"random_b{b}", rec, div))
    for th in thetas:
        rec, div = eval_algorithm(
            ds, S, cands, N,
            lambda cand, rel, th=th: np.asarray(mmr_select(
                jnp.asarray(normalize(rel)), jnp.asarray(S[np.ix_(cand, cand)]), N, th)),
        )
        rows.append((f"mmr_t{th}", rec, div))
        rec, div = eval_algorithm(
            ds, S, cands, N,
            lambda cand, rel, th=th: np.asarray(greedy_avg_select(
                jnp.asarray(normalize(rel)), jnp.asarray(S[np.ix_(cand, cand)]), N, th)),
        )
        rows.append((f"greedy_t{th}", rec, div))
    for a in alphas:
        def dpp_fn(cand, rel, a=a):
            Ssub = jnp.asarray(S[np.ix_(cand, cand)])
            L = build_kernel_dense(jnp.asarray(normalize(rel)), Ssub, alpha=a)
            return np.asarray(dpp_greedy_dense(L, N, eps=1e-4).indices)
        rec, div = eval_algorithm(ds, S, cands, N, dpp_fn)
        rows.append((f"divdpp_a{a}", rec, div))
    return rows


def main(fast_mode=False):
    alphas = (1.0, 4.0, 64.0) if fast_mode else (1.0, 2.0, 4.0, 16.0, 64.0, 256.0)
    thetas = (0.3, 0.7) if fast_mode else (0.1, 0.3, 0.5, 0.7, 0.9)
    bs = (0, 1) if fast_mode else (0, 1, 2)
    names = ["jester-like"] if fast_mode else list(DATASETS)
    print("name,us_per_call,derived")
    all_rows = {}
    for name in names:
        cfgs = DATASETS[name]
        rows = run_dataset(name, cfgs["N"], cfgs["K"], alphas, thetas, bs)
        all_rows[name] = rows
        for algo, rec, div in rows:
            print(f"fig3_{name}_{algo},0,recall={rec:.4f};avg={div['avg']:.4f};"
                  f"min={div['min']:.4f};median={div['median']:.4f}")
    return all_rows


if __name__ == "__main__":
    main()
