"""Session-aware incremental rerank: delta-resume latency vs full
re-rerank (beyond-paper; the serving consequence of the NeurIPS'18
sliding window — the windowed state *is* the session's conditioning
state, so a scroll event after a candidate-pool delta costs O(w * dM)
for the delta plus O(c) resumed steps, never an O(k * M) replay).

The scenario per backend: a session scrolls through a few chunks, then
``dM`` fresh candidates arrive and the user scrolls again.  The delta
path serves that event as ``extend(dM)`` + ``next_chunk(c)`` on the
warm session; the stateless baseline re-reranks a ``shown + c`` slate
from scratch over the grown pool (what a server without sessions must
do).  Reported per row: best-of-trials delta-event latency (headline),
the full re-rerank latency it undercuts, and a parity flag.

Two gates, red on failure:

* **parity** — every chunk the session emits (including every
  post-delta chunk) must equal, id for id, an independent float64
  from-scratch conditional greedy over the pool *as it stood at that
  scroll event* (per pick: a fresh Cholesky of the window's Gram plus
  a full candidate solve): the delta-updated resume matches the
  from-scratch derivation exactly.  The final pool is not a valid
  reference — a stateless rerun over it could place late-arriving
  candidates in early positions the session never saw them for.
* **latency** — the delta event must be strictly faster than the full
  re-rerank.  Interpret mode on CPU measures structure, not the TPU
  win: the ordering reflects executing c resumed steps instead of
  shown + c from step 0, and O(w * dM) delta work instead of a full
  shortlist + init; the absolute ratio is not asserted.

  PYTHONPATH=src python -m benchmarks.fig10_session [--smoke | --full]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.core import map_relevance
from repro.serving import (
    DPPRerankConfig,
    Reranker,
    RerankRequest,
    SessionConfig,
)


def ref_next_picks(Vf, shown, n, w, eps):
    """From-scratch conditional greedy over pool ``Vf (D, M)`` given the
    ``shown`` history — the independently-derived float64 reference the
    session's delta-updated resume is gated against."""
    Vf = np.asarray(Vf, np.float64)
    L = Vf.T @ Vf
    shown = list(shown)
    dead = np.zeros(L.shape[0], bool)
    dead[shown] = True
    picks = []
    for _ in range(n):
        win = shown[-w:]
        if win:
            F = np.linalg.cholesky(L[np.ix_(win, win)])
            Ci = np.linalg.solve(F, L[np.asarray(win), :])
            d2 = np.diag(L) - np.sum(Ci * Ci, axis=0)
        else:
            d2 = np.diag(L).copy()
        d2[dead] = -np.inf
        j = int(np.argmax(d2))
        if not d2[j] > eps * eps:
            break
        picks.append(j)
        shown.append(j)
        dead[j] = True
    return picks


def setup(M, D, seed=0):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.maximum(np.linalg.norm(feats, axis=1, keepdims=True), 1e-12)
    scores = rng.uniform(size=M).astype(np.float32)
    return scores, feats


def run_backend(name, extra, M, D, w, chunk, dm, warm_chunks, trials):
    scores, feats = setup(M, D)
    # slate_size bounds one scroll burst, not the feed: the session
    # keeps emitting chunks for as long as the user scrolls
    cfg = DPPRerankConfig(slate_size=w + chunk, shortlist=M, alpha=3.0,
                          eps=1e-6, window=w, chunk_size=chunk, **extra)
    cap = M + (trials + 1) * dm
    rr = Reranker(cfg, session_config=SessionConfig(
        budget_bytes=1 << 32, capacity=cap,
    ))
    sess = rr.session(RerankRequest(scores=jnp.asarray(scores),
                                    feats=jnp.asarray(feats)))

    # shortlist=M keeps every candidate, so a session global id is an
    # index into the concatenated (scores, feats) arrays — the parity
    # reference below works directly in id space
    pool_s, pool_f = [scores], [feats]
    parity_ok = True

    def check_parity(before_shown, ids):
        nonlocal parity_ok
        s_all = np.concatenate(pool_s)
        f_all = np.concatenate(pool_f)
        rel = np.asarray(map_relevance(jnp.asarray(s_all), cfg.alpha))
        Vf = (f_all * rel[:, None]).T
        ref = ref_next_picks(Vf, before_shown, len(ids), w, cfg.eps)
        parity_ok = parity_ok and ref == [int(i) for i in ids]

    history = []
    for _ in range(warm_chunks):
        ids, _ = sess.next_chunk(chunk)
        check_parity(history, ids)
        history.extend(int(i) for i in ids)
    shown0 = len(history)
    k_full = shown0 + chunk  # what a stateless server recomputes

    # one warmup delta event compiles the extend/next_chunk geometries
    # (the pool is capacity-padded from init, so every later event hits
    # the same compiled (shape, chunk) — fig8's recompile ledger and the
    # analyzer's session-geometry proof pin that)
    deltas = [setup(dm, D, seed=100 + t)[:2] for t in range(trials + 1)]

    def delta_event(ds, df):
        t0 = time.perf_counter()
        sess.extend(jnp.asarray(ds), jnp.asarray(df))
        ids, _ = sess.next_chunk(chunk)  # materializes host-side
        return time.perf_counter() - t0, ids

    best_delta = float("inf")
    for t, (ds, df) in enumerate(deltas):
        dt, ids = delta_event(ds, df)
        pool_s.append(ds)
        pool_f.append(df)
        check_parity(history, ids)
        history.extend(int(i) for i in ids)
        if t > 0:  # event 0 is the compile warmup
            best_delta = min(best_delta, dt)

    # stateless baseline: re-rerank shown0 + chunk from scratch over the
    # pool as it stood after the first delta (the same scroll event)
    full_scores = np.concatenate([scores, deltas[0][0]])
    full_feats = np.concatenate([feats, deltas[0][1]])
    full_cfg = DPPRerankConfig(slate_size=k_full, shortlist=M + dm,
                               alpha=3.0, eps=1e-6, window=w, **extra)
    full_rr = Reranker(full_cfg)
    full_req = RerankRequest(scores=jnp.asarray(full_scores),
                             feats=jnp.asarray(full_feats))
    np.asarray(full_rr.rerank(full_req)[0])  # compile + warm
    best_full = float("inf")
    for _ in range(max(trials, 2)):
        t0 = time.perf_counter()
        np.asarray(full_rr.rerank(full_req)[0])
        best_full = min(best_full, time.perf_counter() - t0)

    parity = "ok" if parity_ok else "FAIL"
    return (name, M, dm, w, chunk, shown0, best_delta, best_full, parity)


def main(fast_mode=False):
    # warm_chunks sets the shown history the stateless baseline must
    # replay (its slate grows with the feed) while the delta event's
    # cost stays flat — the structural margin the latency gate rides on
    M, D, w, chunk, dm, warm_chunks = (
        (1024, 32, 8, 8, 64, 6) if fast_mode else (4096, 32, 8, 8, 128, 6)
    )
    trials = 2 if fast_mode else 5
    rows = []
    for name, extra in [
        ("jnp", {}),
        ("pallas_tiled", dict(use_kernel=True, tile_m=128)),
    ]:
        rows.append(run_backend(
            name, extra, M, D, w, chunk, dm, warm_chunks, trials
        ))
    print("name,us_per_call,derived")
    for (name, M_, dm_, w_, c_, shown, t_delta, t_full, parity) in rows:
        print(
            f"fig10_session_{name}_M{M_}_dM{dm_},{t_delta*1e6:.1f},"
            f"full_rerank_us={t_full*1e6:.1f};"
            f"delta_vs_full={t_delta/max(t_full, 1e-12):.2f}x;"
            f"dm={dm_};chunk={c_};w={w_};shown={shown};parity={parity}"
        )
    bad = [r for r in rows if r[8] != "ok"]
    if bad:
        raise RuntimeError(
            f"fig10 session-resume vs from-scratch parity failure: {bad}"
        )
    slow = [r for r in rows if not r[6] < r[7]]
    if slow:
        raise RuntimeError(
            f"fig10: delta-resume did not beat the full re-rerank: {slow}"
        )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 2 timing trials (CI)")
    args = ap.parse_args()
    main(fast_mode=args.smoke or not args.full)
