"""Observability end-to-end: per-pump latency breakdown from real spans
and the recompile ledger (beyond-paper; exercises ``repro.obs`` across
the router, streaming and dispatch layers the way an operator would).

One obs session spans two workloads over the same candidate pool:

* **router** — heterogeneous k / mask requests at one fixed candidate
  width through the continuous-batching router.  The slot geometry is
  warmed, the compile monitor ``mark()``-ed, and the measured drive must
  show **zero** jit cache misses — the "router never re-jits" claim as
  an observed counter, not an argument from code structure.  Every
  ``router.pump`` span must decompose into its ``.evict`` / ``.admit``
  / ``.launch`` / ``.materialize`` children (``.sync`` once a chunk is
  in flight), and the reported rows are the mean microseconds each
  phase actually took — admit (host prep + splice) vs launch (async
  dispatch) vs materialize (device sync + trimming).
* **per-k serial streaming** — the counter-example: each distinct slate
  length streams through a fresh whole-request state whose Cholesky
  geometry ``C (M, k)`` folds k into the compiled shape, so the monitor
  must observe **at least one** miss per distinct k.

Gates (fail the run red; the CI --smoke step): zero router misses after
warmup, >= 1 miss per distinct serial k, complete pump decomposition,
a schema-valid Chrome trace export, and nonzero dispatch telemetry
(chunks + marginal evaluations) for the work that ran.

  PYTHONPATH=src python -m benchmarks.fig8_observability [--smoke | --full]
"""
from __future__ import annotations

import argparse

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.obs import ObsConfig, validate_chrome_trace
from repro.serving import (
    DPPRerankConfig,
    Reranker,
    RerankRequest,
    RouterConfig,
)

PUMP_PHASES = ("evict", "admit", "launch", "materialize")


def make_requests(n, M, D, k_lo, k_hi, seed=0):
    """Heterogeneous k and masks at ONE candidate width — the shape mix
    the router serves from a single compiled geometry."""
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(M, D)).astype(np.float32)
    feats /= np.maximum(np.linalg.norm(feats, axis=1, keepdims=True), 1e-12)
    feats = jnp.asarray(feats)
    reqs = []
    for i in range(n):
        scores = rng.uniform(0.05, 1.0, size=M).astype(np.float32)
        mask = None
        if i % 3 == 2:
            m = np.ones(M, bool)
            m[rng.choice(M, size=M // 4, replace=False)] = False
            mask = jnp.asarray(m)
        reqs.append(RerankRequest(
            scores=jnp.asarray(scores), feats=feats,
            slate_size=int(rng.integers(k_lo, k_hi + 1)), mask=mask, rid=i,
        ))
    return reqs


def pump_breakdown(spans):
    """Mean/total microseconds per pump phase from recorded spans.
    Returns ``(counts, mean_us, total_us)`` keyed by phase name."""
    counts, totals = {}, {}
    for s in spans:
        counts[s["name"]] = counts.get(s["name"], 0) + 1
        totals[s["name"]] = totals.get(s["name"], 0.0) + s["dur_us"]
    means = {n: totals[n] / counts[n] for n in counts}
    return counts, means, totals


def run(fast_mode):
    M, D = (192, 16) if fast_mode else (1024, 32)
    shortlist = min(96 if fast_mode else 256, M)
    k_lo, k_hi = (6, 12) if fast_mode else (16, 32)
    slots, chunk = 4, 4
    n_req = 12 if fast_mode else 32

    rows, failures = [], []
    obs.disable()  # a fresh session owns the whole run
    session = obs.enable(ObsConfig(enabled=True))
    cm, tracer, reg = (
        session.compile_monitor, session.tracer, session.registry
    )

    cfg = DPPRerankConfig(slate_size=k_hi, shortlist=shortlist, alpha=3.0,
                          eps=1e-6, chunk_size=chunk)
    rr = Reranker(cfg, router_config=RouterConfig(
        slots=slots, chunk_size=chunk, max_queue=2 * n_req,
        max_candidates=shortlist,
    ))
    reqs = make_requests(n_req, M, D, k_lo, k_hi, seed=3)

    # -- router: warm, mark, drive, expect zero recompiles ------------------
    warm = [rr.submit(r) for r in reqs[:slots]]
    rr.router.drain()
    assert all(h.done for h in warm)
    cm.mark()
    n_spans_before = len(tracer._events)
    handles = [rr.submit(r) for r in reqs[slots:]]
    rr.router.drain()
    if not all(h.done for h in handles):
        failures.append("router drive left unfinished handles")
    router_misses = int(cm.since_mark())
    if router_misses != 0:
        failures.append(
            f"router re-jitted: {router_misses} jit cache misses after "
            f"warmup (expected 0 — per-request k/mask must stay in data)"
        )

    spans = tracer.finished()[n_spans_before:]
    pump_spans = [s for s in spans if s["name"].startswith("router.pump")]
    counts, means, totals = pump_breakdown(pump_spans)
    pumps = counts.get("router.pump", 0)
    if pumps == 0:
        failures.append("no router.pump spans recorded")
    for phase in PUMP_PHASES:
        got = counts.get(f"router.pump.{phase}", 0)
        if got != pumps:
            failures.append(
                f"pump decomposition incomplete: {got} router.pump.{phase} "
                f"spans for {pumps} pumps"
            )
    # sync exists for every pump that had a chunk in flight
    if counts.get("router.pump.sync", 0) < max(pumps - 1, 0):
        failures.append(
            f"expected >= {pumps - 1} router.pump.sync spans, got "
            f"{counts.get('router.pump.sync', 0)}"
        )
    pump_total = max(totals.get("router.pump", 0.0), 1e-9)
    for phase in PUMP_PHASES + ("sync",):
        name = f"router.pump.{phase}"
        rows.append((
            f"fig8_pump_{phase}", means.get(name, 0.0),
            f"pumps={pumps};share={totals.get(name, 0.0) / pump_total:.2f};"
            f"misses_after_warmup={router_misses}",
        ))

    # -- per-k serial streaming: the recompile counter-example --------------
    distinct_k = sorted({r.slate_size for r in reqs})
    cm.mark()
    for k in distinct_k:
        r = reqs[[q.slate_size for q in reqs].index(k)]
        for c, _ in rr.stream(r):
            c.block_until_ready()
    serial_misses = int(cm.since_mark())
    rows.append((
        "fig8_serial_per_k_misses", float(serial_misses),
        f"distinct_k={len(distinct_k)};"
        f"router_misses_after_warmup={router_misses}",
    ))
    if serial_misses < len(distinct_k):
        failures.append(
            f"per-k serial streaming showed {serial_misses} misses for "
            f"{len(distinct_k)} distinct k (expected >= 1 each: k shapes "
            f"the chunk state C (M, k))"
        )

    # -- exports: schema-valid trace, live dispatch telemetry ---------------
    doc = tracer.export_chrome()
    err = validate_chrome_trace(doc)
    if err is not None:
        failures.append(f"chrome trace schema: {err}")
    snap = reg.snapshot()
    chunks = sum(snap["counters"].get("greedy_chunks_total", {}).values())
    evals = sum(snap["counters"].get("marginal_evals_total", {}).values())
    if chunks <= 0 or evals <= 0:
        failures.append(
            f"dispatch telemetry empty: chunks={chunks} evals={evals}"
        )
    rows.append((
        "fig8_trace_export", float(len(doc["traceEvents"])),
        f"schema={'ok' if err is None else 'FAIL'};"
        f"spans_total={tracer.total};dropped={tracer.dropped};"
        f"chunks={int(chunks)};marginal_evals={int(evals)}",
    ))
    # the session stays installed: the harness (benchmarks.run) snapshots
    # it into BENCH_fig8.json and owns the teardown; the next run()'s
    # disable/enable pair gives standalone invocations a clean ledger
    return rows, failures


def main(fast_mode=False):
    rows, failures = run(fast_mode)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        raise RuntimeError(f"fig8 observability gate failures: {failures}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes sized for CI")
    args = ap.parse_args()
    main(fast_mode=args.smoke or not args.full)
