"""Continuous-batching serving: sustained QPS vs latency percentiles
(beyond-paper; the serving shape the slot-batched router exists for —
heterogeneous live requests share one micro-batch instead of queueing
behind each other's whole slates).

Two measurements over the same synthetic open-loop client (Poisson-ish
arrivals of heterogeneous requests — mixed candidate counts, slate
lengths and masks):

* **burst TTFC** — R requests arrive at once; serial request-at-a-time
  streaming serves them one ``Reranker.stream`` after another (request
  i's first chunk waits for slates 0..i-1), the router serves them as
  one continuously-batched micro-batch.  The router's mean
  time-to-first-chunk must not exceed the serial path's — that is the
  continuous-batching claim, and it is asserted.
* **open-loop sweep** — requests offered at a fixed rate; reported per
  rate: completed QPS, p50/p95/p99 completion latency, mean TTFC, batch
  fill ratio and peak slot concurrency.

Every completed router slate is checked index for index against the
per-request ``Reranker.rerank`` on the same inputs — parity failures,
a batch fill ratio below 0.5, or peak concurrency below 4 sustained
heterogeneous requests fail the run red (the CI --smoke gate).

Interpret mode on CPU measures structure, not the TPU win: the ordering
claims are asserted, absolute rates are not.

  PYTHONPATH=src python -m benchmarks.fig7_serving [--smoke | --full]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax.numpy as jnp

from repro.serving import (
    DPPRerankConfig,
    Reranker,
    RerankRequest,
    RouterConfig,
)
from repro.serving.router import RouterQueueFull


def make_requests(n, M_lo, M_hi, D, k_lo, k_hi, seed=0):
    """Heterogeneous request mix: per-request M, k and an occasional
    already-seen mask."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        M = int(rng.integers(M_lo, M_hi + 1))
        feats = rng.normal(size=(M, D)).astype(np.float32)
        feats /= np.maximum(np.linalg.norm(feats, axis=1, keepdims=True),
                            1e-12)
        scores = rng.uniform(0.05, 1.0, size=M).astype(np.float32)
        mask = None
        if i % 3 == 2:  # every third user has seen a slice of the pool
            m = np.ones(M, bool)
            m[rng.choice(M, size=M // 4, replace=False)] = False
            mask = jnp.asarray(m)
        reqs.append(
            RerankRequest(
                scores=jnp.asarray(scores), feats=jnp.asarray(feats),
                slate_size=int(rng.integers(k_lo, k_hi + 1)), mask=mask,
                rid=i,
            )
        )
    return reqs


def expected_slates(rr, reqs):
    return [tuple(np.asarray(x) for x in rr.rerank(r)) for r in reqs]


def check_parity(handles, expect):
    bad = []
    for h, (ei, _) in zip(handles, expect):
        gi, _ = h.slate()
        if not np.array_equal(gi, ei):
            bad.append((h.rid, gi.tolist(), ei.tolist()))
    return bad


def burst_serial_ttfc(rr, reqs):
    """Request-at-a-time: stream each request fully before the next
    starts; TTFC is measured from the shared burst start."""
    t0 = time.perf_counter()
    ttfc = []
    for req in reqs:
        first = None
        for c, _ in rr.stream(req):
            c.block_until_ready()
            if first is None:
                first = time.perf_counter() - t0
        ttfc.append(first)
    return ttfc


def drive_open_loop(rr, reqs, expect, gap_s):
    """Offer one request every ``gap_s`` seconds; pump continuously.
    Returns per-request completion latency, TTFC and the router stats."""
    peak = 0
    t0 = time.perf_counter()
    pending = list(reqs)
    handles, done_at, arrived_at = [], {}, {}
    i = 0
    while pending or any(not h.done for h in handles):
        now = time.perf_counter() - t0
        while pending and i * gap_s <= now:
            try:
                h = rr.submit(pending[0])
            except RouterQueueFull:
                break  # backpressure: retry this arrival next cycle
            arrived_at[id(h)] = now
            handles.append(h)
            pending.pop(0)
            i += 1
        rr.router.pump()
        peak = max(peak, rr.router.stats.slot_occupancy)
        now = time.perf_counter() - t0
        for h in handles:
            if h.done and id(h) not in done_at:
                done_at[id(h)] = now
    lat = [done_at[id(h)] - arrived_at[id(h)] for h in handles]
    ttfc = [h.ttfc for h in handles if h.ttfc is not None]
    bad = check_parity(handles, expect[: len(handles)])
    makespan = max(done_at.values()) if done_at else 1e-12
    return lat, ttfc, peak, bad, makespan


def pct(xs, q):
    return float(np.percentile(np.asarray(xs, float), q)) if xs else 0.0


def run(fast_mode):
    M_lo, M_hi, D = (256, 512, 16) if fast_mode else (1024, 2048, 32)
    k_lo, k_hi = (8, 16) if fast_mode else (16, 32)
    shortlist = 128 if fast_mode else 512
    slots, chunk = 4, 4
    n_burst = 8
    n_open = 12 if fast_mode else 32

    cfg = DPPRerankConfig(slate_size=k_hi, shortlist=shortlist, alpha=3.0,
                          eps=1e-6, chunk_size=chunk)
    rcfg = RouterConfig(slots=slots, chunk_size=chunk, max_queue=64,
                        max_candidates=shortlist)

    rows, failures = [], []

    # -- burst: router TTFC vs serial request-at-a-time streaming ----------
    reqs = make_requests(n_burst, M_lo, M_hi, D, k_lo, k_hi, seed=1)
    rr = Reranker(cfg, router_config=rcfg)
    expect = expected_slates(rr, reqs)
    # warm both paths' compiles out of the measurement
    for c, _ in rr.stream(reqs[0]):
        c.block_until_ready()
    wh = [rr.submit(r) for r in reqs[:slots]]
    rr.router.drain()
    serial = burst_serial_ttfc(rr, reqs)
    handles = [rr.submit(r) for r in reqs]
    rr.router.drain()
    routed = [h.ttfc for h in handles]
    bad = check_parity(handles, expect)
    if bad:
        failures.append(f"burst parity: {bad[:2]}")
    st = rr.router.stats
    rows.append(
        ("fig7_burst_ttfc", np.mean(routed) * 1e6,
         f"serial_mean_us={np.mean(serial)*1e6:.1f};"
         f"router_vs_serial={np.mean(routed)/max(np.mean(serial),1e-12):.2f}x;"
         f"R={n_burst};slots={slots};fill={st.fill_ratio:.2f};"
         f"parity={'FAIL' if bad else 'ok'}")
    )
    if np.mean(routed) > np.mean(serial):
        failures.append(
            f"router burst TTFC {np.mean(routed)*1e3:.1f}ms exceeds serial "
            f"request-at-a-time {np.mean(serial)*1e3:.1f}ms"
        )
    if st.fill_ratio < 0.5:
        failures.append(f"burst batch fill ratio {st.fill_ratio:.2f} < 0.5")

    # -- open-loop sweep: offered rate vs latency percentiles --------------
    # calibrate the offered rates to this machine: gaps around the
    # per-chunk cycle time keep the router busy without unbounded queueing
    t0 = time.perf_counter()
    rr.router.pump()
    cycle = max(time.perf_counter() - t0, 1e-4)
    for rate_name, gap in [("hot", cycle), ("steady", 4 * cycle)]:
        reqs = make_requests(n_open, M_lo, M_hi, D, k_lo, k_hi, seed=7)
        rr = Reranker(cfg, router_config=rcfg)
        expect = expected_slates(rr, reqs)
        wh = [rr.submit(r) for r in reqs[:slots]]  # warm the slot geometry
        rr.router.drain()
        rr2 = Reranker(cfg, router_config=rcfg)
        lat, ttfc, peak, bad, makespan = drive_open_loop(
            rr2, reqs, expect, gap
        )
        if bad:
            failures.append(f"open-loop {rate_name} parity: {bad[:2]}")
        st = rr2.router.stats
        qps = len(lat) / makespan
        rows.append(
            (f"fig7_openloop_{rate_name}", pct(lat, 50) * 1e6,
             f"p95_us={pct(lat, 95)*1e6:.1f};p99_us={pct(lat, 99)*1e6:.1f};"
             f"qps={qps:.1f};ttfc_us={np.mean(ttfc)*1e6:.1f};"
             f"gap_us={gap*1e6:.1f};n={len(lat)};peak_concurrency={peak};"
             f"fill={st.fill_ratio:.2f};"
             f"parity={'FAIL' if bad else 'ok'}")
        )
        if rate_name == "hot":
            if peak < 4:
                failures.append(
                    f"hot open-loop peak concurrency {peak} < 4 "
                    f"heterogeneous requests"
                )
            if st.fill_ratio < 0.5:
                failures.append(
                    f"hot open-loop batch fill ratio {st.fill_ratio:.2f} "
                    f"< 0.5"
                )
    return rows, failures


def main(fast_mode=False):
    rows, failures = run(fast_mode)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        raise RuntimeError(f"fig7 serving gate failures: {failures}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes sized for CI")
    args = ap.parse_args()
    main(fast_mode=args.smoke or not args.full)
