"""Autotuned kernel geometry vs the analytical VMEM model (beyond-paper;
validates the measured autotune cache end-to-end at serving shapes).

Every seam family's smoke geometry (the exact grid
``python -m repro.kernels.autotune --smoke`` measures) runs three ways:

* ``tile_m="auto"`` — the measured cache winner for this device, with
  the analytical model as fallback;
* the model default (``tile_m=None``) — the widest model-fitting tile;
* the jnp oracle — the index-for-index correctness reference.

The cache comes from ``$DPP_AUTOTUNE_CACHE`` when it already exists
(the CI autotune lane pre-builds it with the sweep CLI); otherwise the
smoke sweep runs first into a temp file, so the figure is
self-contained.

Gates (fail the run red; the CI --smoke step):

* **tolerance** — the autotuned geometry is no slower than the model
  default beyond a noise tolerance (interpret-mode timings wobble; the
  tuner must never *lose* to the model it prefilters with);
* **cache hits** — the ``tile_m="auto"`` dispatches actually consulted
  the cache (``autotune_cache_hits_total`` >= 1: the figure measures
  the measured path, not a silent model fallback);
* **no recompiles** — zero jit cache misses after warmup on the
  repeated cache-hit path (a cache lookup happens at trace time and
  must not perturb the compiled geometry);
* **parity** — index-for-index slate equality vs the jnp oracle for
  every tuner-selected geometry.

  PYTHONPATH=src python -m benchmarks.fig9_autotune [--smoke | --full]
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.obs import ObsConfig
from repro.kernels.dpp_greedy import TilePolicy, bucket_m, run_sweep
from repro.kernels.dpp_greedy.autotune import (
    CACHE_ENV,
    lookup_tile,
    smoke_cases,
)
from repro.kernels.dpp_greedy.ops import (
    dpp_greedy,
    dpp_greedy_stream_chunk,
    dpp_greedy_stream_init,
    dpp_greedy_stream_pad,
)

EPS = 1e-6


def make_inputs(D, M, seed=0):
    """Normalized features x relevance, (1, D, M) — the sweep's own
    deterministic input recipe, so the figure times what was tuned."""
    rng = np.random.default_rng(seed)
    F = rng.normal(size=(D, M)).astype(np.float32)
    F /= np.maximum(np.linalg.norm(F, axis=0, keepdims=True), 1e-12)
    rel = 1.0 + rng.uniform(size=M).astype(np.float32)
    return jnp.asarray(F * rel[None, :])[None]


def _time(fn, trials):
    jax.block_until_ready(fn())  # compile + warm
    best = float("inf")
    for _ in range(max(trials, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _step_runner(V, k, window, policy):
    return lambda: dpp_greedy(
        V, k, eps=EPS, window=window, tile_policy=policy
    )


def _chunk_runner(V, k, window, chunk, policy):
    """One fused-chunk launch on a pre-built state (what the sweep
    times); the state/padded-V pair is rebuilt per policy because the
    tile decides the padded candidate-axis geometry."""
    state = dpp_greedy_stream_init(V, k, window=window, tile_policy=policy)
    Vp = dpp_greedy_stream_pad(V, state)
    return lambda: dpp_greedy_stream_chunk(
        Vp, state, chunk, eps=EPS, tile_policy=policy
    )


def _chunk_slate(V, k, window, chunk, policy):
    """Full slate through the resumable chunk path."""
    state = dpp_greedy_stream_init(V, k, window=window, tile_policy=policy)
    Vp = dpp_greedy_stream_pad(V, state)
    sels = []
    for _ in range((k + chunk - 1) // chunk):
        state, sel, _ = dpp_greedy_stream_chunk(
            Vp, state, chunk, eps=EPS, tile_policy=policy
        )
        sels.append(np.asarray(sel))
    return np.concatenate(sels, axis=-1)[..., :k]


def run(fast_mode):
    trials = 1 if fast_mode else 3
    tolerance = 2.0 if fast_mode else 1.25

    rows, failures = [], []
    obs.disable()  # a fresh session owns the whole run
    session = obs.enable(ObsConfig(enabled=True))
    cm, reg = session.compile_monitor, session.registry

    # -- the cache: reuse the lane's pre-built file, else sweep now ---------
    path = os.environ.get(CACHE_ENV)
    env_was = path
    if not path:
        path = os.path.join(
            tempfile.mkdtemp(prefix="fig9_autotune_"), "cache.json"
        )
    os.environ[CACHE_ENV] = path
    try:
        built = "reused"
        if not os.path.exists(path):
            built = "swept"
            run_sweep(
                smoke_cases(), trials=trials,
                limit=3 if fast_mode else None, path=path,
            )

        auto_policy = TilePolicy(tile_m="auto")
        model_policy = TilePolicy()
        hits0 = reg.counter("autotune_cache_hits_total").total()

        for case in smoke_cases():
            V = make_inputs(case.D, bucket_m(case.M))
            window = case.state_rows if case.windowed else None
            k = 2 * case.state_rows if case.windowed else case.state_rows

            cached = lookup_tile(
                D=case.D, M=bucket_m(case.M), state_rows=case.state_rows,
                windowed=case.windowed, chunked=case.chunked, path=path,
            )
            _, tile_auto = auto_policy.decide(
                case.D, bucket_m(case.M), case.state_rows, case.windowed,
                case.chunked,
            )
            _, tile_model = model_policy.decide(
                case.D, bucket_m(case.M), case.state_rows, case.windowed,
                case.chunked,
            )

            if case.chunked:
                fn_auto = _chunk_runner(V, k, window, case.chunk, auto_policy)
                fn_model = _chunk_runner(
                    V, k, window, case.chunk, model_policy
                )
                sel_auto = _chunk_slate(V, k, window, case.chunk, auto_policy)
            else:
                fn_auto = _step_runner(V, k, window, auto_policy)
                fn_model = _step_runner(V, k, window, model_policy)
                sel_auto = np.asarray(fn_auto()[0])

            t_auto = _time(fn_auto, trials)
            t_model = _time(fn_model, trials)

            # warmed above — the repeated cache-hit path must not re-jit
            cm.mark()
            jax.block_until_ready(fn_auto())
            misses = int(cm.since_mark())
            if misses != 0:
                failures.append(
                    f"{case.family}: {misses} jit cache misses on the "
                    f"warmed tile_m='auto' path (expected 0)"
                )

            sel_ref = np.asarray(dpp_greedy(
                V, k, eps=EPS, window=window, force_jnp=True
            )[0])
            parity = bool(np.array_equal(sel_auto, sel_ref))
            if not parity:
                failures.append(
                    f"{case.family}: tuner-selected tile {tile_auto} "
                    f"diverged from the jnp oracle "
                    f"({sel_auto.tolist()} vs {sel_ref.tolist()})"
                )

            ratio = t_auto / max(t_model, 1e-9)
            if ratio > tolerance:
                # One fresh timing pair before failing: a single interpret-mode
                # sample on a contended CI host can wobble past tolerance even
                # when the tuned tile is fine steady-state.
                t_auto = min(t_auto, _time(fn_auto, trials))
                t_model = min(t_model, _time(fn_model, trials))
                ratio = t_auto / max(t_model, 1e-9)
            if ratio > tolerance:
                failures.append(
                    f"{case.family}: autotuned tile {tile_auto} is "
                    f"{ratio:.2f}x the model default {tile_model} "
                    f"(tolerance {tolerance}x)"
                )
            rows.append((
                f"fig9_{case.family}", t_auto,
                f"tile_auto={tile_auto};tile_model={tile_model};"
                f"cached={cached};model_us={t_model:.0f};"
                f"ratio={ratio:.2f};misses_after_warmup={misses};"
                f"parity={'ok' if parity else 'FAIL'}",
            ))

        hits = reg.counter("autotune_cache_hits_total").total() - hits0
        if hits < 1:
            failures.append(
                "tile_m='auto' never hit the cache (autotune_cache_hits_"
                "total unchanged) — the figure measured the model fallback"
            )
        rows.append((
            "fig9_cache", float(hits),
            f"cache={built};path={path};"
            f"misses={int(reg.counter('autotune_cache_misses_total').total())}",
        ))
    finally:
        if env_was is None:
            os.environ.pop(CACHE_ENV, None)
        else:
            os.environ[CACHE_ENV] = env_was
    return rows, failures


def main(fast_mode=False):
    rows, failures = run(fast_mode)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        raise RuntimeError(f"fig9 autotune gate failures: {failures}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny preset sized for CI")
    args = ap.parse_args()
    main(fast_mode=args.smoke or not args.full)
